"""Distribution utilities: sharding rules for params, activations and IO."""
from repro.dist import sharding  # noqa: F401
