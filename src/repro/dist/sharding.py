"""Sharding rules (DESIGN.md §4): one module owns every GSPMD annotation.

Three layers of API, all name-rule based so model code never mentions mesh
axes directly:

* **Activation constraints** — ``activation_constraints(cfg, mesh, dp_axes)``
  installs a thread-local table mapping *logical activation names*
  ("residual", "kv_cache", "attn_scores_full", ...) to PartitionSpecs;
  ``constrain(x, name)`` applied inside the forwards looks the name up and
  becomes a no-op outside the context (single-device tests trace with no
  context at all, so smoke runs carry zero sharding overhead).

* **Parameter rules** — ``param_specs`` / ``param_shardings`` walk a param
  pytree and assign megatron-style specs by leaf name: column-parallel
  up-projections, row-parallel down-projections, vocab-sharded embedding
  tables, EP- or TP-sharded expert banks (mirroring
  ``mixed_moe._bank_specs``, plus the stacked leading layer dim).

* **IO specs** — ``input_specs`` / ``cache_specs`` build the abstract
  (ShapeDtypeStruct) inputs and their shardings for the dry-run driver.

Every rule degrades to replication when a dim does not divide the mesh
axis — a spec must never make a program fail to compile.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"

_ACTIVE = threading.local()          # .rules: Dict[str, P] | None, .mesh


# ---------------------------------------------------------------------------
# Small helpers
# ---------------------------------------------------------------------------

def _axis_size(mesh, axis: str) -> int:
    try:
        return int(mesh.shape[axis]) if axis in mesh.shape else 1
    except TypeError:
        return 1


def _dp_entry(dp_axes: Tuple[str, ...]):
    """The PartitionSpec entry for the batch/token dim."""
    if not dp_axes:
        return None
    return dp_axes if len(dp_axes) > 1 else dp_axes[0]


def batch_axes(mesh, global_batch: int) -> Tuple[str, ...]:
    """Data-parallel axes for this (mesh, batch): the ("pod","data") prefix
    whose total size divides the global batch; drops axes (pod first) until
    it does — long_500k's batch=1 shards over nothing."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    while axes:
        n = int(np.prod([_axis_size(mesh, a) for a in axes]))
        if n and global_batch % n == 0:
            break
        axes.pop(0)
    return tuple(axes)


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------

def _activation_rules(cfg, mesh, dp_axes: Tuple[str, ...],
                      train: bool = False) -> Dict[str, P]:
    dp = _dp_entry(dp_axes)
    m = MODEL_AXIS
    msize = _axis_size(mesh, m)
    # Pure-EP serving mesh (DESIGN.md §16): every batch-ish axis has
    # size 1, so the "model" axis exists only to shard expert banks.
    # Attention stays fully replicated there — sharding heads would turn
    # the wo projection into a cross-device partial-sum contraction and
    # decode would no longer be bit-identical to the single-device
    # engine (the §16 parity guarantee).
    ep_only = cfg.moe is not None and not train and all(
        _axis_size(mesh, a) <= 1 for a in ("pod", "data"))
    shard_m = msize > 1 and not ep_only
    h = cfg.attention.num_heads if cfg.attention else 0
    heads_ok = h > 0 and shard_m and h % msize == 0
    ssm_h = 0
    if cfg.ssm is not None:
        di = cfg.ssm.expand * cfg.d_model if cfg.ssm.kind == "mamba2" \
            else cfg.d_model
        ssm_h = di // cfg.ssm.head_dim
    ssm_ok = ssm_h > 0 and shard_m and ssm_h % msize == 0
    rules = {
        # (B, S, d): residual stream shards over tokens only — the d dim
        # stays replicated so norms/routers need no collective.
        "residual": P(dp, None, None),
        # (B, W, hkv, hd): ring-buffer KV shards over batch.
        "kv_cache": P(dp, None, None, None),
        # (B, H, Sq, Sk): flat scores shard heads when they divide.
        "attn_scores_full": P(dp, m if heads_ok else None, None, None),
        # (B, Hkv, G, Sq, Sk): grouped scores (taken when heads can NOT
        # shard) shard the query blocks instead (§Perf smollm).
        "attn_scores_full_g": P(dp, None, None,
                                m if shard_m else None, None),
        # decode reads the window-sharded-free cache; batch-only (sharding
        # Sk would psum every softmax — DESIGN.md §4).
        "attn_scores_cache_g": P(dp, None, None, None, None),
        "attn_scores_cache": P(dp, None, None, None),
        # (B, S, H, P) rwkv/mamba inner activations.
        "ssm_inner": P(dp, None, m if ssm_ok else None, None),
    }
    return rules


@contextlib.contextmanager
def activation_constraints(cfg, mesh, dp_axes: Tuple[str, ...],
                           train: bool = False):
    """Install the named-constraint table for the duration of a trace."""
    prev = (getattr(_ACTIVE, "rules", None), getattr(_ACTIVE, "mesh", None))
    _ACTIVE.rules = _activation_rules(cfg, mesh, dp_axes, train=train)
    _ACTIVE.mesh = mesh
    try:
        yield
    finally:
        _ACTIVE.rules, _ACTIVE.mesh = prev


def _effective_spec(spec: P, mesh) -> Optional[P]:
    """``spec`` with size-1 mesh axes stripped; None when nothing is left.
    Sharding over a size-1 axis is replication, but the CONSTRAINT is not
    free: it anchors GSPMD propagation and can repartition surrounding
    contractions (different partial-sum order => decode on a (1, ep) EP
    serving mesh would no longer be bit-identical to the single-device
    engine, DESIGN.md §16). Dropping trivial constraints is semantically
    identity and keeps production meshes (axis sizes > 1) unchanged."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if _axis_size(mesh, a) > 1)
        out.append(axes if len(axes) > 1
                   else (axes[0] if axes else None))
    if all(e is None for e in out):
        return None
    return P(*out)


def constrain(x, name: str):
    """Apply the active sharding rule for ``name`` (no-op outside an
    ``activation_constraints`` context or for unknown/mismatched names)."""
    rules = getattr(_ACTIVE, "rules", None)
    if not rules:
        return x
    spec = rules.get(name)
    if spec is None or len(spec) > x.ndim:
        return x
    spec = _effective_spec(spec, _ACTIVE.mesh)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACTIVE.mesh, spec))


def full_grouped_ok(h: int, hkv: int) -> bool:
    """Should the FULL-attention path use the grouped GQA contraction?

    Measured rule (§Perf): when heads shard evenly over the model axis the
    flat+head-sharded path wins (grouped 5D layouts inflate collectives);
    when they don't (e.g. 15-head smollm), grouped+q-sharded wins. Outside
    a mesh context (single-device smoke) grouped wins on memory: K/V are
    never expanded G-fold."""
    mesh = getattr(_ACTIVE, "mesh", None)
    if hkv == h:
        return False
    if mesh is None:
        return True
    msize = _axis_size(mesh, MODEL_AXIS)
    return not (h % msize == 0)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# 2D weights sharded on the OUTPUT dim (column-parallel)
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "ffn_k", "w_r",
                 "w_k", "w_v", "w_g", "w_in", "ffn_r"}
# 2D weights sharded on the INPUT (reduction) dim (row-parallel)
_ROW_PARALLEL = {"wo", "w_down", "ffn_v", "w_out", "w_o"}
# Embedding/unembedding tables: vocab-sharded (padded_vocab divides)
_VOCAB_SHARDED = {"table"}


def _expert_spec(path: str, shape, msize: int) -> P:
    """Spec for a (stacked) expert-bank leaf: (L, E, ...) arrays, the
    QTensor ``q``/``scales`` included. EP shards E when it divides the
    model axis, otherwise TP shards the d_ff dim (dim -2 for w_down and
    its scales, dim -1 for up/gate) — mirrors ``mixed_moe._bank_specs``."""
    if len(shape) < 3:
        return P(*([None] * len(shape)))
    e = shape[1]
    spec = [None] * len(shape)
    if msize > 1 and e % msize == 0:
        spec[1] = MODEL_AXIS                           # EP over experts
        return P(*spec)
    fdim = len(shape) - 2 if "w_down" in path else len(shape) - 1
    if msize > 1 and shape[fdim] % msize == 0:
        spec[fdim] = MODEL_AXIS                        # TP over d_ff
    return P(*spec)


def _leaf_spec(path: str, shape, msize: int) -> P:
    """Megatron-style spec by leaf name; stacked (L, ...) leaves get a
    leading None automatically (layer dims are never sharded)."""
    parts = [p for p in path.split("/") if p]
    last = parts[-1] if parts else ""
    ndim = len(shape)
    if msize <= 1 or ndim == 0:
        return P(*([None] * ndim))
    if "moe" in parts and last != "router":
        return _expert_spec(path, shape, msize)
    if last in _VOCAB_SHARDED and ndim == 2:
        return P(MODEL_AXIS if shape[0] % msize == 0 else None, None)
    # find the trailing 2D weight inside a possibly stacked leaf
    if last in _COL_PARALLEL and ndim >= 2:
        spec = [None] * ndim
        if shape[-1] % msize == 0:
            spec[-1] = MODEL_AXIS
        return P(*spec)
    if last in _ROW_PARALLEL and ndim >= 2:
        spec = [None] * ndim
        if shape[-2] % msize == 0:
            spec[-2] = MODEL_AXIS
        return P(*spec)
    return P(*([None] * ndim))


def _walk_specs(tree, msize: int, path: str = ""):
    if isinstance(tree, dict):
        return {k: _walk_specs(v, msize, f"{path}/{k}")
                for k, v in tree.items()}
    if tree is None:
        return None
    # QTensor and other registered containers: map over their array leaves
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if len(leaves) != 1 or leaves[0] is not tree:
        specs = [_leaf_spec(path, leaf.shape, msize) for leaf in leaves]
        return jax.tree_util.tree_unflatten(treedef, specs)
    return _leaf_spec(path, tree.shape, msize)


def param_specs(cfg, mesh, tree) -> Any:
    """PartitionSpec pytree for a (train- or serve-layout) param tree."""
    return _walk_specs(tree, _axis_size(mesh, MODEL_AXIS))


def param_shardings(cfg, mesh, tree) -> Any:
    """NamedSharding pytree (same structure as ``tree``)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(cfg, mesh, tree),
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# IO specs for the dry-run driver
# ---------------------------------------------------------------------------

def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape, mesh):
    """(abstract inputs, NamedShardings) for one dry-run cell."""
    import jax.numpy as jnp
    dp = batch_axes(mesh, shape.global_batch)
    lead = _dp_entry(dp)
    b, s = shape.global_batch, shape.seq_len
    ns = lambda spec: NamedSharding(mesh, spec)
    if shape.kind == "decode":
        inp = {"tokens": _sds((b, 1), jnp.int32),
               "positions": _sds((b,), jnp.int32)}
        sh = {"tokens": ns(P(lead, None)), "positions": ns(P(lead))}
        return inp, sh
    inp = {"tokens": _sds((b, s), jnp.int32),
           "labels": _sds((b, s), jnp.int32)}
    sh = {"tokens": ns(P(lead, None)), "labels": ns(P(lead, None))}
    if cfg.family == "encdec":
        # precomputed frontend frame embeddings (B, S_src, d)
        inp["src"] = _sds((b, cfg.frontend_len or s, cfg.d_model),
                          jnp.dtype(cfg.dtype))
        sh["src"] = ns(P(lead, None, None))
    if cfg.frontend == "vision":
        inp["frontend"] = _sds((b, cfg.frontend_len, cfg.d_model),
                               jnp.dtype(cfg.dtype))
        sh["frontend"] = ns(P(lead, None, None))
    return inp, sh


def cache_specs(cfg, shape, mesh):
    """(abstract decode cache, NamedShardings). Caches shard over the batch
    dim only — window/state dims stay local (DESIGN.md §4)."""
    from repro.models.model import init_cache  # deferred: avoids cycle
    dp = batch_axes(mesh, shape.global_batch)
    lead = _dp_entry(dp)
    b = shape.global_batch
    cache = init_cache(cfg, b, shape.seq_len, abstract=True)

    def spec_of(leaf):
        sh = leaf.shape
        spec = [None] * len(sh)
        if len(sh) >= 2 and sh[1] == b:
            spec[1] = lead                 # (L, B, ...) stacks
        elif len(sh) >= 1 and sh[0] == b:
            spec[0] = lead                 # (B, ...) e.g. enc_out
        return NamedSharding(mesh, P(*spec))

    return cache, jax.tree_util.tree_map(spec_of, cache)
