"""Shared neural layers (pure functions over param pytrees, no flax).

Conventions:
  * params are nested dicts keyed by the names in ModelConfig.param_shapes()
  * activations are bf16, reductions/norms/softmax in f32
  * attention supports GQA (kv<heads), MQA (kv=1), sliding-window (ring
    buffer KV cache), qk-norm, cross-attention, causal & bidirectional
  * decode caches carry explicit absolute-position tags so SWA ring buffers
    mask correctly
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.dist.sharding import constrain


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------------
# KV cache: fixed-size ring buffer (window = sliding_window or max length),
# slots tagged with absolute positions (-1 = empty).
# --------------------------------------------------------------------------

def init_kv_cache(batch: int, window: int, num_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, window, num_kv, head_dim), dtype),
        "v": jnp.zeros((batch, window, num_kv, head_dim), dtype),
        "pos": jnp.full((batch, window), -1, jnp.int32),
    }


def _update_cache(cache, k_new, v_new, positions):
    """Insert S_new entries at slots ``position % window`` (vectorized)."""
    window = cache["k"].shape[1]
    slots = positions % window                                 # (B, S_new)
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    k = constrain(cache["k"].at[b_idx, slots].set(k_new), "kv_cache")
    v = constrain(cache["v"].at[b_idx, slots].set(v_new), "kv_cache")
    pos = cache["pos"].at[b_idx, slots].set(positions)
    return {"k": k, "v": v, "pos": pos}


def _spec_update_cache(cache, k_new, v_new, positions):
    """Ring-buffer insert that DROPS rows tagged position<0.

    The speculative paths (draft + batched verify, DESIGN.md §17) carry
    right-padded draft tails and idle decode slots as position=-1; the
    plain modulo scatter would alias them onto slot ``(-1) % window ==
    window - 1`` and clobber a live entry. Masked rows are redirected to
    the out-of-range slot ``window`` and silently dropped by the scatter
    (same sentinel trick as the paged-KV table scatter)."""
    window = cache["k"].shape[1]
    live = positions >= 0                                      # (B, S_new)
    slots = jnp.where(live, positions % window, window)
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    k = constrain(cache["k"].at[b_idx, slots].set(k_new, mode="drop"),
                  "kv_cache")
    v = constrain(cache["v"].at[b_idx, slots].set(v_new, mode="drop"),
                  "kv_cache")
    pos = cache["pos"].at[b_idx, slots].set(positions, mode="drop")
    return {"k": k, "v": v, "pos": pos}


def _prefill_cache(cache, k_new, v_new, positions):
    """Prefill-from-empty cache write WITHOUT a scatter.

    Positions are contiguous 0..S-1, so the ring-buffer content is a
    (rolled) slice of k/v — a reshape GSPMD partitions for free, vs. the
    general scatter which all-gathers the whole cache per layer."""
    b, s, hkv, hd = k_new.shape
    window = cache["k"].shape[1]
    if s >= window:
        shift = (s - window) % window      # slot of the first kept entry
        cut = lambda a: jnp.roll(a[:, -window:], shift, axis=1)
        k, v, pos = cut(k_new), cut(v_new), cut(positions)
    else:
        pad = [(0, 0), (0, window - s)] + [(0, 0)] * (k_new.ndim - 2)
        k = jnp.pad(k_new, pad)
        v = jnp.pad(v_new, pad)
        pos = jnp.pad(positions, [(0, 0), (0, window - s)],
                      constant_values=-1)
    # NOTE: no sharding constraint here — constraining would CSE with the
    # in-context attention's k/v and drag a seq-gather into every layer;
    # the stacked cache output is resharded once at the jit boundary.
    return {"k": k.astype(cache["k"].dtype),
            "v": v.astype(cache["v"].dtype),
            "pos": pos.astype(jnp.int32)}


_Q_CHUNK = 512      # query-block size for long-sequence attention


def _sdpa_block(q, k, v, mask, scale, score_name):
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = constrain(logits, score_name)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _sdpa_grouped_block(q, k, v, mask, scale, score_name: str) -> jax.Array:
    """GQA without materializing repeated K/V: queries are reshaped to
    (B, Sq, Hkv, G, hd) and contract the SHARED kv head dim directly —
    the K/V cache is read once, not G times (§Perf kimi-decode iter 3:
    the expand+transpose copy was the decode-path's dominant HBM term,
    and the dK/dV all-reduce shrinks G-fold in training backward).
    """
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q5 = q.reshape(b, sq, hkv, g, hd)
    logits = jnp.einsum("bqcgd,bkcd->bcgqk", q5, k,
                        preferred_element_type=jnp.float32) * scale
    logits = constrain(logits, score_name)
    logits = jnp.where(mask[:, :, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bcgqk,bkcd->bqcgd", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa(q, k, v, mask, score_name: str, grouped: bool = True
          ) -> jax.Array:
    """q: (B,Sq,H,hd) k,v: (B,Sk,Hkv,hd) mask: (B,1,Sq,Sk) bool.

    GQA (hkv < h) always runs the grouped contraction — K/V are never
    expanded. Long queries are processed in blocks of _Q_CHUNK (scan) so
    the score tensor is O(chunk x Sk), never O(Sq x Sk) — flash-style
    memory bound, exact softmax (each block sees all of K)."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    grouped = grouped and hkv != h
    if not grouped and hkv != h:
        # flat + head-sharded path (heads_ok archs): expand K/V; the
        # expansion shards over "model" with the scores
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    block = _sdpa_grouped_block if grouped else _sdpa_block
    name = score_name + ("_g" if grouped else "")
    scale = hd ** -0.5
    if sq <= 2 * _Q_CHUNK or sq % _Q_CHUNK:
        return block(q, k, v, mask, scale, name)
    nb = sq // _Q_CHUNK
    qs = q.reshape(b, nb, _Q_CHUNK, h, hd).transpose(1, 0, 2, 3, 4)
    ms = mask.reshape(b, 1, nb, _Q_CHUNK, -1).transpose(2, 0, 1, 3, 4)

    def body(_, qm):
        qb, mb = qm
        return None, block(qb, k, v, mb, scale, name)

    _, out = jax.lax.scan(body, None, (qs, ms))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attention(p: Dict[str, Any], x: jax.Array, acfg: AttentionConfig, *,
              positions: jax.Array,
              cache: Optional[Dict[str, jax.Array]] = None,
              kv_x: Optional[jax.Array] = None,
              use_rope: bool = True,
              spec: bool = False,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention.

    x: (B, S, d); positions: (B, S) absolute positions of x.
    cache=None -> full attention over (kv_x or x) with causal/SWA mask.
    cache given -> decode/prefill-with-cache: new k/v are written into the
    ring buffer, attention runs over the buffer with position-tag masking.
    kv_x -> cross-attention (no causal mask, no rope on kv side by default).
    spec -> speculative multi-token decode (DESIGN.md §17): S>=1 new
    tokens extend a LIVE cache (never the prefill-from-empty rewrite) and
    rows tagged position=-1 are dropped instead of aliased by the modulo.
    """
    b, s, d = x.shape
    h, hkv, hd = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    src = kv_x if kv_x is not None else x

    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (src @ p["wk"]).reshape(b, src.shape[1], hkv, hd)
    v = (src @ p["wv"]).reshape(b, src.shape[1], hkv, hd)

    if acfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    cross = kv_x is not None
    if use_rope and not cross:
        q = rope(q, positions, acfg.rope_theta)
        k = rope(k, positions, acfg.rope_theta)

    # Full-attention paths: grouped GQA only when heads can't shard over
    # "model" — measured (§Perf): flat+head-sharded beats grouped's 5D
    # layout transitions for heads_ok archs (mixtral train 833->3213 GB
    # collectives with grouped), while grouped+q-sharded wins 8x for
    # 15-head smollm. Decode always groups (K/V never expanded).
    from repro.dist.sharding import full_grouped_ok
    g_full = full_grouped_ok(h, hkv)

    new_cache = None
    if cross:
        # bidirectional over the (precomputed) source; mask only padding-free
        mask = jnp.ones((b, 1, s, src.shape[1]), bool)
        out = _sdpa(q, k, v, mask, "attn_scores_full", grouped=g_full)
    elif cache is not None and s > 1 and not spec:
        # prefill-from-empty: attend over the in-context k/v directly
        # (heads-sharded, zero extra comm) and write the ring buffer for
        # the decode steps that follow. Attending *through* the window-
        # sharded cache would psum every softmax (see DESIGN.md §4).
        new_cache = _prefill_cache(cache, k, v, positions)
        qpos = positions
        # key validity: right-padded slot prefills tag pads with pos=-1;
        # they must never be attended (and their ring-buffer entries stay
        # tagged invalid for the decode steps that follow)
        mask = (qpos[:, None, :, None] >= qpos[:, None, None, :]) \
            & (qpos[:, None, None, :] >= 0)
        if acfg.sliding_window:
            mask &= (qpos[:, None, :, None] - qpos[:, None, None, :]
                     < acfg.sliding_window)
        out = _sdpa(q, k, v, mask, "attn_scores_full", grouped=g_full)
    elif cache is not None:
        # decode (S==1) or speculative draft/verify (spec=True, S>=1):
        # the position-tag mask below is already exact for S>1 queries —
        # each query row attends its own causal window over the buffer.
        writer = _spec_update_cache if spec else _update_cache
        new_cache = writer(cache, k, v, positions)
        kpos = new_cache["pos"]                                  # (B, W)
        qpos = positions                                         # (B, S)
        valid = kpos[:, None, None, :] >= 0
        causal = kpos[:, None, None, :] <= qpos[:, None, :, None]
        mask = valid & causal
        if acfg.sliding_window:
            mask &= (qpos[:, None, :, None] - kpos[:, None, None, :]
                     < acfg.sliding_window)
        out = _sdpa(q, new_cache["k"], new_cache["v"], mask,
                    "attn_scores_cache", grouped=True)
    else:
        qpos = positions
        mask = qpos[:, None, :, None] >= qpos[:, None, None, :] \
            if acfg.causal else jnp.ones((b, 1, s, s), bool)
        if acfg.causal and acfg.sliding_window:
            mask &= (qpos[:, None, :, None] - qpos[:, None, None, :]
                     < acfg.sliding_window)
        out = _sdpa(q, k, v, mask, "attn_scores_full", grouped=g_full)

    return out.reshape(b, s, h * hd) @ p["wo"], new_cache


def mlp(p: Dict[str, Any], x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if act == "gelu":
        return jax.nn.gelu(x @ p["w_up"], approximate=True) @ p["w_down"]
    if act == "relu_sq":
        return jnp.square(jax.nn.relu(x @ p["w_up"])) @ p["w_down"]
    raise ValueError(act)


def embed(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def unembed(table: jax.Array, x: jax.Array) -> jax.Array:
    """(B,S,d) @ (V,d)^T -> (B,S,V) logits in f32 for a stable softmax."""
    return jnp.einsum("bsd,vd->bsv", x, table,
                      preferred_element_type=jnp.float32)


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 vocab_size: int) -> jax.Array:
    """Mean NLL with padded-vocab masking (positions with label<0 ignored)."""
    v_pad = logits.shape[-1]
    if v_pad > vocab_size:
        pad_mask = jnp.arange(v_pad) >= vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return (nll * valid).sum() / jnp.maximum(valid.sum(), 1)
