"""build_model(cfg) -> Model: init / train loss / prefill / decode_step,
uniform across all 10 architectures (+ the paper's mixtral-mop serving
config). Frontend stubs (audio/vision) consume precomputed embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import mixed_moe
from repro.core.precision_plan import PrecisionPlan
from repro.models import layers as L
from repro.models.encdec import encdec_forward, encoder_forward
from repro.models.transformer import FORWARDS, _ffn_or_moe, _hybrid_layout


# ---------------------------------------------------------------------------
# Parameter init (name-rule based; shapes from cfg.param_shapes())
# ---------------------------------------------------------------------------

def _init_one(key, name: str, shape, dtype):
    last = name.rsplit("/", 1)[-1]
    if last in ("scale", "norm", "ln_x", "D"):
        return jnp.ones(shape, dtype)
    if last == "A_log":
        # mamba2: A in [1, 16]
        return jnp.log(jnp.linspace(1.0, 16.0, shape[0])).astype(dtype)
    if last == "dt_bias":
        u = jax.random.uniform(key, shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)  # inv softplus
    if last in ("mix", "ffn_mix"):
        return jnp.full(shape, 0.5, dtype)
    if last == "decay_base":
        return jnp.zeros(shape, dtype)
    if last == "bonus":
        return (jax.random.normal(key, shape, jnp.float32) * 0.1
                ).astype(dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def nest(flat: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for name, v in flat.items():
        node = out
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    shapes = cfg.param_shapes()
    keys = jax.random.split(key, len(shapes))
    flat = {}
    for k, (name, shape) in zip(keys, shapes):
        flat[name] = _init_one(k, name, shape, dtype)
    return nest(flat)


def abstract_params(cfg: ModelConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    dtype = jnp.dtype(cfg.dtype)
    return nest({name: jax.ShapeDtypeStruct(shape, dtype)
                 for name, shape in cfg.param_shapes()})


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> Any:
    """Decode caches; ``abstract=True`` returns ShapeDtypeStructs."""
    dt = jnp.dtype(cfg.dtype)
    mk = (lambda s, d=dt: jax.ShapeDtypeStruct(s, d)) if abstract \
        else (lambda s, d=dt: jnp.zeros(s, d) if d != jnp.int32
              else jnp.full(s, -1, d))

    def kv(n, window):
        return {"k": mk((n, batch, window, cfg.attention.num_kv_heads,
                         cfg.attention.head_dim)),
                "v": mk((n, batch, window, cfg.attention.num_kv_heads,
                         cfg.attention.head_dim)),
                "pos": mk((n, batch, window), jnp.int32)}

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        window = min(max_len, cfg.attention.sliding_window or max_len)
        return kv(cfg.num_layers, window)
    if fam == "encdec":
        window = max_len
        return {"self": kv(cfg.num_layers, window),
                "enc_out": mk((batch, cfg.frontend_len, cfg.d_model))}
    if fam == "ssm":   # rwkv6
        h = cfg.d_model // cfg.ssm.head_dim
        n = cfg.num_layers
        return {"state": mk((n, batch, h, cfg.ssm.head_dim,
                             cfg.ssm.head_dim), jnp.float32),
                "x_att": mk((n, batch, cfg.d_model)),
                "x_ffn": mk((n, batch, cfg.d_model))}
    if fam == "hybrid":
        di = cfg.ssm.expand * cfg.d_model
        h = di // cfg.ssm.head_dim
        n = cfg.num_layers
        full, g, rem = _hybrid_layout(cfg)
        n_attn = full + 1
        window = min(max_len, cfg.attention.sliding_window or max_len)
        conv_ch = di + 2 * cfg.ssm.state_dim
        return {
            "mamba": {"state": mk((n, batch, h, cfg.ssm.head_dim,
                                   cfg.ssm.state_dim), jnp.float32),
                      "conv": mk((n, batch, 3, conv_ch))},
            "attn": kv(n_attn, window),
        }
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Paged KV cache (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The slot cache above allocates every slot its full ring window up front;
# a short request strands (window - len) entries of HBM for its whole
# lifetime. The paged cache keeps ONE pool of fixed-size pages plus a
# host-side per-slot page table: slot ``b``'s ring buffer is the
# concatenation of its mapped pages, chunk ``j`` of the ring living in
# physical page ``table[b, j]``. Pages are mapped on demand as positions
# advance and freed on retire, so allocated KV bytes track actual tokens
# (per page), not slots x window.
#
# Page 0 is the reserved NULL page: all position tags -1, never allocated,
# never written (scatters remap null entries to an out-of-range sentinel
# and drop them). An unmapped chunk therefore gathers as an all-invalid
# ring segment — masked to exactly 0 contribution by the attention's
# position tags — which makes decode through the paged cache BIT-IDENTICAL
# to the slot cache for the same stream (tested): the gathered ring is
# sliced to exactly the window width, so every attention sees the same
# operand tensors in the same order.

@dataclasses.dataclass(frozen=True)
class PagedKVMeta:
    """Static layout of a paged KV pool."""
    window: int           # logical ring width per slot (== slot-cache W)
    page_size: int        # tokens per page
    chunks_per_slot: int  # ceil(window / page_size)
    num_pages: int        # physical pages incl. the reserved null page 0


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     page_size: int = 16,
                     num_pages: Optional[int] = None,
                     abstract: bool = False
                     ) -> Tuple[Any, PagedKVMeta]:
    """Paged decode cache: (pool, meta). ``num_pages=None`` sizes the pool
    at worst case (every slot fully windowed) + the null page; a smaller
    pool reclaims HBM for the frontier's residency axis (the engine caps
    admission so allocation can never dead-end mid-flight)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"family {cfg.family} has no paged KV path")
    window = min(max_len, cfg.attention.sliding_window or max_len)
    chunks = -(-window // page_size)
    if num_pages is None:
        num_pages = batch * chunks + 1
    if num_pages < chunks + 1:
        raise ValueError(f"pool of {num_pages} pages cannot hold even one "
                         f"full window ({chunks} pages)")
    dt = jnp.dtype(cfg.dtype)
    hkv, hd = cfg.attention.num_kv_heads, cfg.attention.head_dim
    n = cfg.num_layers
    mk = (lambda s, d=dt: jax.ShapeDtypeStruct(s, d)) if abstract \
        else (lambda s, d=dt: jnp.zeros(s, d) if d != jnp.int32
              else jnp.full(s, -1, d))
    pool = {"k": mk((n, num_pages, page_size, hkv, hd)),
            "v": mk((n, num_pages, page_size, hkv, hd)),
            "pos": mk((n, num_pages, page_size), jnp.int32)}
    return pool, PagedKVMeta(window=window, page_size=page_size,
                             chunks_per_slot=chunks, num_pages=num_pages)


def _scatter_table(pt: jax.Array, num_pages: int) -> jax.Array:
    """Unmapped chunks (null page 0) -> out-of-range sentinel so scatters
    with mode="drop" never write the null page."""
    return jnp.where(pt == 0, num_pages, pt)


def _gather_paged(pool, pt, window: int):
    """pool + page table (B, nc) -> the standard ring cache (L, B, W, ...)
    the attention layers consume. The page view is sliced to exactly
    ``window`` so attention operands (and thus logits) are bit-identical
    to the slot cache's."""
    nc = pt.shape[1]

    def g(a):
        x = a[:, pt]                           # (L, B, nc, ps, ...)
        l, b, _, ps = x.shape[:4]
        return x.reshape((l, b, nc * ps) + x.shape[4:])[:, :, :window]

    return {"k": g(pool["k"]), "v": g(pool["v"]), "pos": g(pool["pos"])}


def _scatter_paged(pool, pt, ring, window: int):
    """Write a (possibly updated) ring cache back into its pages. Null
    chunks are dropped (their ring segment is all-invalid by
    construction), so the null page is never dirtied."""
    nc = pt.shape[1]
    ps = pool["pos"].shape[2]
    spt = _scatter_table(pt, pool["pos"].shape[1])
    pad = nc * ps - window
    out = {}
    for key in ("k", "v", "pos"):
        r = ring[key]
        if pad:
            cfgp = [(0, 0), (0, 0), (0, pad)] + [(0, 0)] * (r.ndim - 3)
            r = jnp.pad(r, cfgp,
                        constant_values=-1 if key == "pos" else 0)
        l, b = r.shape[0], r.shape[1]
        rr = r.reshape((l, b, nc, ps) + r.shape[3:])
        out[key] = pool[key].at[:, spt].set(rr, mode="drop")
    return out


def _gather_paged_layer(pool, pt, window: int, layer):
    """Single-layer gather for the per-layer decode pipeline; ``layer``
    is a traced scalar."""
    nc = pt.shape[1]

    def g(a):
        x = a[layer][pt]                       # (B, nc, ps, ...)
        b, _, ps = x.shape[:3]
        return x.reshape((b, nc * ps) + x.shape[3:])[:, :window]

    return {"k": g(pool["k"]), "v": g(pool["v"]), "pos": g(pool["pos"])}


def _scatter_paged_layer(pool, pt, ring, window: int, layer):
    nc = pt.shape[1]
    ps = pool["pos"].shape[2]
    spt = _scatter_table(pt, pool["pos"].shape[1])
    pad = nc * ps - window
    out = {}
    for key in ("k", "v", "pos"):
        r = ring[key]
        if pad:
            cfgp = [(0, 0), (0, pad)] + [(0, 0)] * (r.ndim - 2)
            r = jnp.pad(r, cfgp,
                        constant_values=-1 if key == "pos" else 0)
        b = r.shape[0]
        rr = r.reshape((b, nc, ps) + r.shape[2:])
        out[key] = pool[key].at[layer, spt].set(rr, mode="drop")
    return out


def _scatter_prefill_paged(pool, page_row, ring, window: int):
    """Scatter one slot's freshly prefilled ring (L, W, ...) into its
    mapped pages; ``page_row`` is the slot's (nc,) page-table row (null
    chunks dropped — they hold no written entries)."""
    nc = page_row.shape[0]
    ps = pool["pos"].shape[2]
    spt = _scatter_table(page_row, pool["pos"].shape[1])
    pad = nc * ps - window
    out = {}
    for key in ("k", "v", "pos"):
        r = ring[key]                          # (L, W, ...)
        if pad:
            cfgp = [(0, 0), (0, pad)] + [(0, 0)] * (r.ndim - 2)
            r = jnp.pad(r, cfgp,
                        constant_values=-1 if key == "pos" else 0)
        l = r.shape[0]
        rr = r.reshape((l, nc, ps) + r.shape[2:])
        out[key] = pool[key].at[:, spt].set(rr, mode="drop")
    return out


def paged_reset_pages(pool, pages: jax.Array):
    """Invalidate freed pages' position tags (tags only — k/v bytes are
    dead once every tag is -1, same as ``reset_slot``). ``pages`` is a
    fixed-size (chunks_per_slot,) id vector padded with 0 (the null page,
    remapped to the drop sentinel)."""
    spt = _scatter_table(pages, pool["pos"].shape[1])
    ps = pool["pos"].shape[2]
    fill = jnp.full((pool["pos"].shape[0], pages.shape[0], ps), -1,
                    jnp.int32)
    return dict(pool, pos=pool["pos"].at[:, spt].set(fill, mode="drop"))


# ---------------------------------------------------------------------------
# The Model bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable          # (params, batch) -> (loss, metrics)
    prefill: Callable          # (params, batch, cache) -> (logits, cache)
    decode_step: Callable      # (params, cache, tokens, positions) -> (logits, cache)
    init_cache: Callable
    # Slot-based serving API (continuous batching, DESIGN.md §3); None for
    # families whose decode cache is not the plain ring-buffer KV dict.
    prefill_into_slot: Optional[Callable] = None
    # (params, cache, tokens (1,S), positions (1,S), slot, last_idx)
    #   -> (last-token logits (1,V), cache with slot row replaced)
    decode_step_routed: Optional[Callable] = None
    # (params, cache, tokens, positions) -> (logits, cache, route_ids)
    reset_slot: Optional[Callable] = None
    # (cache, slot) -> cache with the slot's position tags invalidated
    # Per-layer decode hooks (DESIGN.md §12): the engine's async overlap
    # pipeline drives the stack ONE layer at a time so expert transfers
    # for layer L+1 can stage while layer L computes. Splitting the
    # scanned step into embed -> layer^L -> logits is numerically
    # IDENTICAL to decode_step_routed (same primitive sequence; tested
    # bit-exact), it only changes dispatch granularity. None for
    # families without the slot-cache MoE decode path.
    decode_embed: Optional[Callable] = None
    # (params, tokens (B,1)) -> x (B,1,d)
    decode_layer_routed: Optional[Callable] = None
    # (params, cache, x, positions (B,), layer) ->
    #   (x', cache with layer's KV row replaced, route_ids (B, top_k))
    decode_logits: Optional[Callable] = None
    # (params, x (B,1,d)) -> logits (B,V)
    # Paged KV cache (DESIGN.md §13): same serving surface over a page
    # pool + per-slot page table instead of fully-windowed slot rows.
    # Decode through these hooks is bit-identical to the slot-cache path
    # (the gathered page view IS the ring buffer — tested).
    init_paged_cache: Optional[Callable] = None
    # (batch, max_len, *, page_size, num_pages) -> (pool, PagedKVMeta)
    paged_prefill_into_slot: Optional[Callable] = None
    # (params, pool, page_row (nc,), tokens (1,S), positions (1,S),
    #  last_idx, *, window) -> (logits (1,V), pool)
    paged_decode_step_routed: Optional[Callable] = None
    # (params, pool, page_table (B,nc), tokens, positions, *, window)
    #   -> (logits, pool, route_ids)
    paged_decode_layer_routed: Optional[Callable] = None
    # (params, pool, page_table, x, positions, layer, *, window)
    #   -> (x', pool, route_ids (B, top_k))
    paged_reset_pages: Optional[Callable] = None
    # (pool, pages (nc,)) -> pool with the pages' position tags cleared
    # Self-speculative decode (DESIGN.md §17): one multi-token step serves
    # BOTH the draft pass (S=1, draft-rung params) and the batched verify
    # forward (S=K+1, serving params) — rows tagged position=-1 are
    # dropped, the MoE dispatch is drop-free, and attention masks each
    # query to its own causal window, so verify logits at position p are
    # bit-identical to a plain decode step at p.
    spec_step_routed: Optional[Callable] = None
    # (params, cache, tokens (B,S), positions (B,S))
    #   -> (logits (B,S,V), cache, route_ids (L, B*S, top_k))
    paged_spec_step_routed: Optional[Callable] = None
    # (params, pool, page_table (B,nc), tokens (B,S), positions (B,S),
    #  *, window) -> (logits (B,S,V), pool, route_ids)
    rollback_slots: Optional[Callable] = None
    # (cache, keep (B,)) -> cache with tags > keep[b] invalidated per slot
    paged_rollback: Optional[Callable] = None
    # (pool, page_table (B,nc), keep (B,)) -> pool, same contract


def _embed_inputs(params, cfg: ModelConfig, batch):
    """tokens (+ frontend embeddings) -> (x (B,S,d), positions (B,S))."""
    tok = batch["tokens"]
    x = L.embed(params["embed"]["table"], tok) \
        * jnp.asarray(math.sqrt(cfg.d_model), params["embed"]["table"].dtype)
    if cfg.frontend == "vision":
        x = jnp.concatenate([batch["frontend"].astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    return x, positions


def _forward_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_forward
    return FORWARDS[cfg.family]


def build_model(cfg: ModelConfig, mesh=None, *,
                dp_axes: Tuple[str, ...] = ("data",),
                use_kernel: bool = False) -> Model:
    """mesh=None builds a single-device (1,1) mesh (CPU tests)."""
    import contextlib

    from repro.dist import sharding as SH
    if mesh is None:
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = jax.sharding.Mesh(dev, ("data", "model"))
        dp_axes = ("data",)
    # token-gather EP (DESIGN.md §4 / §Perf kimi-decode): the "data" axis
    # doubles as the experts' d_ff (FSDP) shard axis; mixed_moe gathers
    # tokens over it instead of re-gathering 1T-scale weights per layer.
    fsdp_axis = "data" if "data" in mesh.shape else None
    par = mixed_moe.MoEParallelism(mesh=mesh, dp_axes=dp_axes,
                                   fsdp_axis=fsdp_axis)
    fwd = _forward_fn(cfg)
    multi_dev = int(np.prod(mesh.devices.shape)) > 1
    act_ctx = (lambda: SH.activation_constraints(cfg, mesh, dp_axes)) \
        if multi_dev else contextlib.nullcontext
    act_ctx_train = (lambda: SH.activation_constraints(
        cfg, mesh, dp_axes, train=True)) if multi_dev \
        else contextlib.nullcontext

    def loss_fn(params, batch):
        with act_ctx_train():
            x, positions = _embed_inputs(params, cfg, batch)
            kw = dict(par=par, train=True, use_kernel=False)
            if cfg.family == "encdec":
                kw["src"] = batch["src"]
            y, _, aux = fwd(params, cfg, x, positions, caches=None, **kw)
            y = L.rms_norm(y, params["final_norm"]["scale"])
            if cfg.frontend == "vision":   # loss over the text tail only
                y = y[:, cfg.frontend_len:]
            logits = L.unembed(params["lm_head"]["table"], y)
            loss = L.softmax_xent(logits, batch["labels"], cfg.vocab_size)
            metrics = {"nll": loss}
            for k, v in aux.items():
                loss = loss + v
                metrics[k] = v
            metrics["loss"] = loss
            return loss, metrics

    def prefill(params, batch, cache):
        with act_ctx():
            x, positions = _embed_inputs(params, cfg, batch)
            kw = dict(par=par, train=False, use_kernel=use_kernel)
            if cfg.family == "encdec":
                kw["src"] = batch["src"]
            y, new_cache, _ = fwd(params, cfg, x, positions, caches=cache,
                                  **kw)
            y = L.rms_norm(y[:, -1:], params["final_norm"]["scale"])
            logits = L.unembed(params["lm_head"]["table"], y)
            return logits[:, 0], new_cache

    def _decode_step(params, cache, tokens, positions, collect_routes):
        """tokens (B,1); positions (B,) absolute position of the token.

        Idle slots pass position=-1: their ring-buffer write lands with an
        invalid (-1) tag, so a retired slot never pollutes its cache row."""
        with act_ctx():
            x = L.embed(params["embed"]["table"], tokens) \
                * jnp.asarray(math.sqrt(cfg.d_model),
                              params["embed"]["table"].dtype)
            pos2 = positions[:, None]
            kw = dict(par=par, train=False, use_kernel=use_kernel)
            if cfg.family == "encdec":
                kw["enc_out"] = cache["enc_out"]
            if collect_routes:
                kw["collect_routes"] = True
            y, new_cache, aux = fwd(params, cfg, x, pos2, caches=cache, **kw)
            y = L.rms_norm(y, params["final_norm"]["scale"])
            logits = L.unembed(params["lm_head"]["table"], y)
            if collect_routes:
                return logits[:, 0], new_cache, aux["route_ids"]
            return logits[:, 0], new_cache

    def decode_step(params, cache, tokens, positions):
        return _decode_step(params, cache, tokens, positions, False)

    slot_api = cfg.family in ("dense", "moe", "vlm") \
        and cfg.frontend == "none"

    def decode_step_routed(params, cache, tokens, positions):
        """decode_step that also returns the per-layer routed expert ids
        (L, B, top_k) in bank order — the engine's expert-cache feed."""
        return _decode_step(params, cache, tokens, positions, True)

    def prefill_into_slot(params, cache, tokens, positions, slot, last_idx):
        """Prefill ONE request into decode slot ``slot`` of a live batch
        cache without touching the other slots (continuous batching,
        DESIGN.md §3).

        tokens/positions: (1, S) RIGHT-padded; pad positions are -1 (the
        attention mask and the ring-buffer tags treat them as invalid).
        ``slot`` and ``last_idx`` (index of the last real token) are traced
        scalars — one compile per padded length, none per slot. Returns
        (next-token logits (1, V), cache with slot row replaced)."""
        window = cache["k"].shape[2]
        with act_ctx():
            x = L.embed(params["embed"]["table"], tokens) \
                * jnp.asarray(math.sqrt(cfg.d_model),
                              params["embed"]["table"].dtype)
            n, _, _, hkv, hd = cache["k"].shape
            sub = {"k": jnp.zeros((n, 1, window, hkv, hd),
                                  cache["k"].dtype),
                   "v": jnp.zeros((n, 1, window, hkv, hd),
                                  cache["v"].dtype),
                   "pos": jnp.full((n, 1, window), -1, jnp.int32)}
            y, new_sub, _ = fwd(params, cfg, x, positions, caches=sub,
                                par=par, train=False, use_kernel=use_kernel)
            y_last = jnp.take(y, last_idx, axis=1, mode="clip")[:, None]
            y_last = L.rms_norm(y_last, params["final_norm"]["scale"])
            logits = L.unembed(params["lm_head"]["table"], y_last)
            merged = {key: cache[key].at[:, slot].set(new_sub[key][:, 0])
                      for key in ("k", "v", "pos")}
            return logits[:, 0], merged

    def reset_slot(cache, slot):
        """Invalidate a retired slot's ring buffer (tags only — k/v bytes
        are dead once every tag is -1)."""
        return dict(cache, pos=cache["pos"].at[:, slot].set(-1))

    # -- per-layer decode (async overlap pipeline, DESIGN.md §12) ----------
    def decode_embed(params, tokens):
        """tokens (B,1) -> embedded x (B,1,d); the pipeline's front."""
        return L.embed(params["embed"]["table"], tokens) \
            * jnp.asarray(math.sqrt(cfg.d_model),
                          params["embed"]["table"].dtype)

    def decode_layer_routed(params, cache, x, positions, layer):
        """One decoder block of the stacked params at index ``layer`` (a
        TRACED scalar — one compile serves every layer). Returns the
        block output, the cache with that layer's KV row replaced, and
        the layer's routed expert ids (B, top_k) in bank order. The body
        is the same block as ``decoder_forward`` — the scanned and the
        per-layer spellings produce identical values."""
        with act_ctx():
            p = jax.tree_util.tree_map(lambda v: v[layer],
                                       params["layers"])
            c = {k: cache[k][layer] for k in ("k", "v", "pos")}
            pos2 = positions[:, None]
            token_valid = pos2 >= 0
            h, new_kv = L.attention(
                p["attn"], L.rms_norm(x, p["attn_norm"]["scale"]),
                cfg.attention, positions=pos2, cache=c)
            x = L.constrain(x + h, "residual")
            xn = L.rms_norm(x, p["ffn_norm"]["scale"])
            h, _, ids = _ffn_or_moe(p, xn, cfg, par, False, use_kernel,
                                    {}, token_valid=token_valid)
            x = L.constrain(x + h, "residual")
            merged = {k: cache[k].at[layer].set(new_kv[k])
                      for k in ("k", "v", "pos")}
            return x, merged, ids

    def decode_logits(params, x):
        """Pipeline tail: final norm + unembed of the last block output."""
        y = L.rms_norm(x, params["final_norm"]["scale"])
        return L.unembed(params["lm_head"]["table"], y)[:, 0]

    # -- paged KV serving hooks (DESIGN.md §13) ------------------------
    def paged_prefill_into_slot(params, pool, page_row, tokens, positions,
                                last_idx, *, window):
        """Paged spelling of ``prefill_into_slot``: same fresh sub-cache
        forward (prefill attends over the in-context k/v, so the logits
        are independent of the cache layout), then the written ring is
        scattered chunk-wise into the slot's mapped pages."""
        with act_ctx():
            x = L.embed(params["embed"]["table"], tokens) \
                * jnp.asarray(math.sqrt(cfg.d_model),
                              params["embed"]["table"].dtype)
            n, _, _, hkv, hd = pool["k"].shape
            sub = {"k": jnp.zeros((n, 1, window, hkv, hd),
                                  pool["k"].dtype),
                   "v": jnp.zeros((n, 1, window, hkv, hd),
                                  pool["v"].dtype),
                   "pos": jnp.full((n, 1, window), -1, jnp.int32)}
            y, new_sub, _ = fwd(params, cfg, x, positions, caches=sub,
                                par=par, train=False, use_kernel=use_kernel)
            y_last = jnp.take(y, last_idx, axis=1, mode="clip")[:, None]
            y_last = L.rms_norm(y_last, params["final_norm"]["scale"])
            logits = L.unembed(params["lm_head"]["table"], y_last)
            ring = {key: new_sub[key][:, 0] for key in ("k", "v", "pos")}
            return logits[:, 0], _scatter_prefill_paged(pool, page_row,
                                                        ring, window)

    def paged_decode_step_routed(params, pool, page_table, tokens,
                                 positions, *, window):
        """Paged ``decode_step_routed``: gather the page view into the
        standard ring cache, run the identical decode step, scatter the
        updated ring back. Bit-identical logits (tested)."""
        ring = _gather_paged(pool, page_table, window)
        logits, new_ring, route_ids = _decode_step(
            params, ring, tokens, positions, True)
        return logits, _scatter_paged(pool, page_table, new_ring,
                                      window), route_ids

    def paged_decode_layer_routed(params, pool, page_table, x, positions,
                                  layer, *, window):
        """Paged spelling of ``decode_layer_routed`` for the overlap
        pipeline; one layer's page view gathered/scattered per call."""
        with act_ctx():
            p = jax.tree_util.tree_map(lambda v: v[layer],
                                       params["layers"])
            c = _gather_paged_layer(pool, page_table, window, layer)
            pos2 = positions[:, None]
            token_valid = pos2 >= 0
            h, new_kv = L.attention(
                p["attn"], L.rms_norm(x, p["attn_norm"]["scale"]),
                cfg.attention, positions=pos2, cache=c)
            x = L.constrain(x + h, "residual")
            xn = L.rms_norm(x, p["ffn_norm"]["scale"])
            h, _, ids = _ffn_or_moe(p, xn, cfg, par, False, use_kernel,
                                    {}, token_valid=token_valid)
            x = L.constrain(x + h, "residual")
            merged = _scatter_paged_layer(pool, page_table, new_kv,
                                          window, layer)
            return x, merged, ids

    # -- self-speculative decode hooks (DESIGN.md §17) -----------------
    def spec_step_routed(params, cache, tokens, positions):
        """Multi-token cached step: tokens/positions (B, S), positions
        RIGHT-padded with -1 past each slot's live span (idle slots are
        all -1). Returns the FULL (B, S, V) logits — the verify path
        scores every position — plus the updated cache and the routed
        expert ids (L, B*S, top_k) with padded rows remapped to the
        sentinel ``num_experts``."""
        with act_ctx():
            x = L.embed(params["embed"]["table"], tokens) \
                * jnp.asarray(math.sqrt(cfg.d_model),
                              params["embed"]["table"].dtype)
            y, new_cache, aux = fwd(params, cfg, x, positions,
                                    caches=cache, par=par, train=False,
                                    use_kernel=use_kernel,
                                    collect_routes=True, spec=True)
            y = L.rms_norm(y, params["final_norm"]["scale"])
            logits = L.unembed(params["lm_head"]["table"], y)
            return logits, new_cache, aux["route_ids"]

    def paged_spec_step_routed(params, pool, page_table, tokens, positions,
                               *, window):
        """Paged spelling of ``spec_step_routed``: gather page view ->
        identical step -> scatter back (bit-identical logits)."""
        ring = _gather_paged(pool, page_table, window)
        logits, new_ring, route_ids = spec_step_routed(
            params, ring, tokens, positions)
        return logits, _scatter_paged(pool, page_table, new_ring,
                                      window), route_ids

    def rollback_slots(cache, keep):
        """Invalidate ring entries past ``keep[b]`` (the last ACCEPTED
        absolute position per slot) — rejected speculative tokens become
        dead tags, exactly like ``reset_slot`` but position-bounded.
        Slots not in the speculative batch pass a large keep value."""
        pos = cache["pos"]
        return dict(cache,
                    pos=jnp.where(pos > keep[None, :, None], -1, pos))

    def paged_rollback(pool, page_table, keep):
        """Paged ``rollback_slots``: the per-slot page view's tags are
        gathered, bounded, and scattered back (null chunks dropped)."""
        pos = pool["pos"][:, page_table]            # (L, B, nc, ps)
        pos = jnp.where(pos > keep[None, :, None, None], -1, pos)
        spt = _scatter_table(page_table, pool["pos"].shape[1])
        return dict(pool,
                    pos=pool["pos"].at[:, spt].set(pos, mode="drop"))

    layered_api = slot_api and cfg.moe is not None

    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=functools.partial(init_cache, cfg),
        prefill_into_slot=prefill_into_slot if slot_api else None,
        decode_step_routed=decode_step_routed if cfg.moe is not None
        else None,
        reset_slot=reset_slot if slot_api else None,
        decode_embed=decode_embed if layered_api else None,
        decode_layer_routed=decode_layer_routed if layered_api else None,
        decode_logits=decode_logits if layered_api else None,
        init_paged_cache=functools.partial(init_paged_cache, cfg)
        if slot_api else None,
        paged_prefill_into_slot=paged_prefill_into_slot if slot_api
        else None,
        paged_decode_step_routed=paged_decode_step_routed
        if slot_api and cfg.moe is not None else None,
        paged_decode_layer_routed=paged_decode_layer_routed
        if layered_api else None,
        paged_reset_pages=paged_reset_pages if slot_api else None,
        spec_step_routed=spec_step_routed
        if slot_api and cfg.moe is not None else None,
        paged_spec_step_routed=paged_spec_step_routed
        if slot_api and cfg.moe is not None else None,
        rollback_slots=rollback_slots if slot_api else None,
        paged_rollback=paged_rollback if slot_api else None,
    )


# ---------------------------------------------------------------------------
# Applying a MoP PrecisionPlan to trained params (serve layout)
# ---------------------------------------------------------------------------

def apply_precision_plan(params, cfg: ModelConfig, plan: PrecisionPlan):
    """Convert train-layout MoE params into N-bank serve layout: one
    bank per ladder rung (ascending-bits order, e.g. [q4 | q8 | f16]) +
    router column permutation (DESIGN.md §11).

    Works on stacked (L, ...) params; per-layer rung counts are equal by
    construction (balanced plan) so banks stack cleanly."""
    assert cfg.moe is not None
    moe_p = params["layers"]["moe"]
    l = cfg.num_layers
    banks_per_layer = []
    routers = []
    for li in range(l):
        layer_p = {k: moe_p[k][li] for k in ("w_gate", "w_up", "w_down")}
        banks, order = mixed_moe.build_ladder_banks(
            layer_p, plan.bits[li], ladder=plan.ladder,
            group_size=plan.group_size)
        banks_per_layer.append(banks)
        routers.append(jnp.take(moe_p["router"][li], order, axis=1))
    stacked = {}
    for bank in banks_per_layer[0]:
        if banks_per_layer[0][bank] is None:
            stacked[bank] = None
        else:
            stacked[bank] = jax.tree_util.tree_map(
                lambda *a: jnp.stack(a),
                *[b[bank] for b in banks_per_layer])
    new_params = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy
    new_params["layers"] = dict(params["layers"])
    new_params["layers"]["moe"] = {
        "router": jnp.stack(routers),
        "banks": stacked,
    }
    return new_params
