"""SSM blocks: Mamba2 (SSD, chunked) and RWKV6 (Finch, data-dependent decay).

Both are *chunked linear attention* so the sequence dim parallelizes onto the
MXU (DESIGN.md: TPU adaptation — the CUDA selective-scan kernel becomes a
chunked matmul formulation):

  Mamba2 state:  S_t = a_t * S_{t-1} + (dt_t x_t) B_t^T           (a scalar/head)
  RWKV6 state:   S_t = diag(w_t) S_{t-1} + k_t v_t^T              (w vector/key)

Within a chunk of Q tokens all pairwise decay products are exponentials of
cumulative-log-decay differences: for Mamba the exponents are always <= 0
(segsum form, no overflow); for RWKV's per-channel decay the factored matmul
form needs exp(-cumsum) on the key side, so the per-token log decay is
clamped to >= -DECAY_CLAMP and the chunk kept small enough that
exp(DECAY_CLAMP * Q) stays in f32 range. The decode path and the test oracle
use the *same* clamped decay, so chunked == recurrent exactly.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig

DECAY_CLAMP = 1.8      # |log w| cap; exp(1.8 * 32) < f32 max


# ===========================================================================
# Mamba2 SSD core
# ===========================================================================

def ssd_chunked(u: jax.Array, logdecay: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int,
                s0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """u: (B,S,H,P) inputs (dt*x); logdecay: (B,S,H) <=0; b,c: (B,S,N).

    Returns y (B,S,H,P), final state (B,H,P,N)."""
    bsz, s_orig, h, p = u.shape
    pad = (-s_orig) % chunk
    if pad:   # no-op tail: decay=1 (log 0), zero inputs -> state unchanged
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        u, logdecay, b, c = map(zpad, (u, logdecay, b, c))
    bsz, s, h, p = u.shape
    n = b.shape[-1]
    nc = s // chunk
    uc = u.reshape(bsz, nc, chunk, h, p)
    ld = logdecay.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)
    cum = jnp.cumsum(ld, axis=2)                       # inclusive (B,nc,Q,H)

    # intra-chunk: att[b,t,h,i,j] = (c_i . b_j) exp(cum_i - cum_j), j<=i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    dec = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("btin,btjn->btij", cc, bc)             # (B,nc,Q,Q)
    att = cb[..., None] * dec                              # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("btijh,btjhp->btihp", att, uc.astype(jnp.float32))

    # chunk-level state recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)
    # state injected by chunk t: sum_j exp(cum_last - cum_j) u_j b_j^T
    w_in = jnp.exp(cum[:, :, -1:, :] - cum)                # (B,nc,Q,H)
    s_in = jnp.einsum("btjh,btjhp,btjn->bthpn",
                      w_in, uc.astype(jnp.float32), bc)    # (B,nc,H,P,N)

    def scan_fn(s_prev, inp):
        dec_t, sin_t = inp
        s_new = s_prev * dec_t[..., None, None] + sin_t
        return s_new, s_prev

    init = jnp.zeros((bsz, h, p, n), jnp.float32) if s0 is None \
        else s0.astype(jnp.float32)
    s_last, s_starts = jax.lax.scan(
        scan_fn, init,
        (chunk_decay.swapaxes(0, 1), s_in.swapaxes(0, 1)))
    s_starts = s_starts.swapaxes(0, 1)                     # (B,nc,H,P,N)

    # carry-in contribution: y_i += (c_i exp(cum_i)) . S_start
    w_carry = jnp.exp(cum)                                 # (B,nc,Q,H)
    y_carry = jnp.einsum("btin,btih,bthpn->btihp",
                         cc, w_carry, s_starts)
    y = (y_intra + y_carry).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(u.dtype), s_last


def ssd_step(s_prev: jax.Array, u_t: jax.Array, logdecay_t: jax.Array,
             b_t: jax.Array, c_t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. s_prev (B,H,P,N); u_t (B,H,P); ld (B,H);
    b_t,c_t (B,N)."""
    a = jnp.exp(logdecay_t.astype(jnp.float32))[..., None, None]
    s_new = s_prev * a + jnp.einsum("bhp,bn->bhpn", u_t.astype(jnp.float32),
                                    b_t.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", s_new, c_t.astype(jnp.float32))
    return y.astype(u_t.dtype), s_new


def ssd_recurrent_ref(u, logdecay, b, c, s0=None):
    """Naive per-token oracle for ssd_chunked (tests)."""
    bsz, s, h, p = u.shape
    n = b.shape[-1]
    state = jnp.zeros((bsz, h, p, n), jnp.float32) if s0 is None else s0

    def step(st, inp):
        u_t, ld_t, b_t, c_t = inp
        y, st = ssd_step(st, u_t, ld_t, b_t, c_t)
        return st, y

    _, ys = jax.lax.scan(step, state,
                         (u.swapaxes(0, 1), logdecay.swapaxes(0, 1),
                          b.swapaxes(0, 1), c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)


# ===========================================================================
# RWKV6 linear-attention core
# ===========================================================================

def rwkv_chunked(r, k, v, logw, bonus, chunk,
                 s0: Optional[jax.Array] = None):
    """r,k: (B,S,H,K); v: (B,S,H,V); logw: (B,S,H,K) in [-DECAY_CLAMP,0];
    bonus u: (H,K). Returns y (B,S,H,V), final state (B,H,K,V).

    y_i = r_i . S_{i-1} + (r_i . (u*k_i)) v_i ;  S_i = diag(w_i) S_{i-1}
          + k_i v_i^T
    """
    bsz, s_orig, h, dk = r.shape
    pad = (-s_orig) % chunk
    if pad:   # no-op tail: decay=1, zero r/k/v -> state unchanged
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        r, k, v, logw = map(zpad, (r, k, v, logw))
    bsz, s, h, dk = r.shape
    dv = v.shape[-1]
    nc = s // chunk
    rc = r.reshape(bsz, nc, chunk, h, dk).astype(jnp.float32)
    kc = k.reshape(bsz, nc, chunk, h, dk).astype(jnp.float32)
    vc = v.reshape(bsz, nc, chunk, h, dv).astype(jnp.float32)
    lw = logw.reshape(bsz, nc, chunk, h, dk)
    cum = jnp.cumsum(lw, axis=2)                            # (B,nc,Q,H,K)
    cum_prev = cum - lw                                     # exclusive: c_{i-1}

    r_dec = rc * jnp.exp(cum_prev)                          # r_i * e^{c_{i-1}}
    k_dec = kc * jnp.exp(-cum)                              # k_j * e^{-c_j}
    att = jnp.einsum("btihk,btjhk->bthij", r_dec, k_dec)    # j<i strict
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    att = jnp.where(mask[None, None, None], att, 0.0)
    diag = jnp.einsum("btihk,hk,btihk->bthi", rc, bonus.astype(jnp.float32),
                      kc)
    att += jnp.eye(chunk)[None, None, None] * diag[..., None]
    y_intra = jnp.einsum("bthij,btjhv->btihv", att, vc)

    chunk_decay = jnp.exp(cum[:, :, -1])                    # (B,nc,H,K)
    w_in = jnp.exp(cum[:, :, -1:, :, :] - cum)              # (B,nc,Q,H,K)
    s_in = jnp.einsum("btjhk,btjhv->bthkv", kc * w_in, vc)  # (B,nc,H,K,V)

    def scan_fn(s_prev, inp):
        dec_t, sin_t = inp
        return s_prev * dec_t[..., None] + sin_t, s_prev

    init = jnp.zeros((bsz, h, dk, dv), jnp.float32) if s0 is None \
        else s0.astype(jnp.float32)
    s_last, s_starts = jax.lax.scan(
        scan_fn, init, (chunk_decay.swapaxes(0, 1), s_in.swapaxes(0, 1)))
    s_starts = s_starts.swapaxes(0, 1)                      # (B,nc,H,K,V)

    y_carry = jnp.einsum("btihk,bthkv->btihv", r_dec, s_starts)
    y = (y_intra + y_carry).reshape(bsz, s, h, dv)[:, :s_orig]
    return y.astype(r.dtype), s_last


def rwkv_step(s_prev, r_t, k_t, v_t, logw_t, bonus):
    """Decode step. s_prev (B,H,K,V); r,k (B,H,K); v (B,H,V); logw (B,H,K)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r_t, k_t, v_t))
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf,
                   s_prev + bonus.astype(jnp.float32)[None, :, :, None] * kv)
    s_new = s_prev * jnp.exp(logw_t.astype(jnp.float32))[..., None] + kv
    return y.astype(r_t.dtype), s_new


def rwkv_recurrent_ref(r, k, v, logw, bonus, s0=None):
    bsz, s, h, dk = r.shape
    dv = v.shape[-1]
    state = jnp.zeros((bsz, h, dk, dv), jnp.float32) if s0 is None else s0

    def step(st, inp):
        r_t, k_t, v_t, lw_t = inp
        y, st = rwkv_step(st, r_t, k_t, v_t, lw_t, bonus)
        return st, y

    _, ys = jax.lax.scan(step, state,
                         (r.swapaxes(0, 1), k.swapaxes(0, 1),
                          v.swapaxes(0, 1), logw.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)


# ===========================================================================
# Full blocks (pre-norm residual wrappers live in transformer.py)
# ===========================================================================

def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv, window W. x (B,S,C); w (W,C).
    state (B,W-1,C) from previous tokens; returns (y, new_state)."""
    win = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], win - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)               # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(win))
    return y, xp[:, -(win - 1):]


def mamba2_block(p: Dict, x: jax.Array, scfg: SSMConfig,
                 cache: Optional[Dict] = None):
    """x: (B,S,d). cache (decode): {"state": (B,H,P,N), "conv": (B,3,C)}."""
    bsz, s, d = x.shape
    di = scfg.expand * d
    n = scfg.state_dim
    h = di // scfg.head_dim
    proj = x @ p["w_in"]                                    # (B,S,2di+2N+h)
    xin, z, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,) < 0
    logdecay = jnp.maximum(dt * a, -DECAY_CLAMP * 4)
    u = xin.reshape(bsz, s, h, scfg.head_dim) * dt[..., None].astype(x.dtype)

    if cache is not None and s == 1:      # decode step
        y, s_new = ssd_step(cache["state"], u[:, 0], logdecay[:, 0],
                            bmat[:, 0], cmat[:, 0])
        y = y[:, None]
    else:                                 # train / prefill (chunked)
        s0 = cache["state"] if cache is not None else None
        y, s_new = ssd_chunked(u, logdecay, bmat, cmat,
                               min(scfg.chunk_size, s), s0=s0)
    new_cache = {"state": s_new, "conv": new_conv}
    y = y + xin.reshape(bsz, s, h, scfg.head_dim) \
        * p["D"].astype(x.dtype)[:, None]
    y = y.reshape(bsz, s, di) * jax.nn.silu(z)
    # final rms norm over the inner dim (mamba2 gated norm)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm"]).astype(x.dtype)
    return y @ p["w_out"], new_cache


def _token_shift(x: jax.Array, prev: Optional[jax.Array]):
    """xx_t = x_{t-1}; prev (B,d) is the last token of the previous call."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1), x[:, -1]


def rwkv6_timemix(p: Dict, x: jax.Array, scfg: SSMConfig,
                  cache: Optional[Dict] = None):
    bsz, s, d = x.shape
    hd = scfg.head_dim
    h = d // hd
    prev = cache["x_att"] if cache is not None else None
    xx, last = _token_shift(x, prev)
    mix = p["mix"]                                           # (5, d)
    xr, xk, xv, xg, xw = (x + mix[i] * (xx - x) for i in range(5))
    from repro.dist.sharding import constrain
    r = constrain((xr @ p["w_r"]).reshape(bsz, s, h, hd), "ssm_inner")
    k = constrain((xk @ p["w_k"]).reshape(bsz, s, h, hd), "ssm_inner")
    v = constrain((xv @ p["w_v"]).reshape(bsz, s, h, hd), "ssm_inner")
    g = jax.nn.silu(xg @ p["w_g"])
    # data-dependent decay (LoRA): logw in [-DECAY_CLAMP, 0)
    lora = jnp.tanh(xw @ p["decay_lora_a"]) @ p["decay_lora_b"]
    logw = -DECAY_CLAMP * jax.nn.sigmoid(
        (p["decay_base"] + lora).astype(jnp.float32))
    logw = logw.reshape(bsz, s, h, hd)

    if cache is not None and s == 1:      # decode step
        y, s_new = rwkv_step(cache["state"], r[:, 0], k[:, 0], v[:, 0],
                             logw[:, 0], p["bonus"])
        y = y[:, None]
    else:                                 # train / prefill (chunked)
        s0 = cache["state"] if cache is not None else None
        y, s_new = rwkv_chunked(r, k, v, logw, p["bonus"],
                                min(scfg.chunk_size, 32, s), s0=s0)
    yf = y.reshape(bsz, s, d).astype(jnp.float32)
    # per-head group norm (ln_x)
    yf = yf.reshape(bsz, s, h, hd)
    yf = (yf - yf.mean(-1, keepdims=True)) \
        * jax.lax.rsqrt(yf.var(-1, keepdims=True) + 1e-5)
    yf = yf.reshape(bsz, s, d) * p["ln_x"].astype(jnp.float32)
    out = (yf.astype(x.dtype) * g) @ p["w_o"]
    new_cache = {"state": s_new, "x_att": last}
    return out, new_cache


def rwkv6_channelmix(p: Dict, x: jax.Array,
                     cache: Optional[Dict] = None):
    prev = cache["x_ffn"] if cache is not None else None
    xx, last = _token_shift(x, prev)
    mix = p["ffn_mix"]
    xk = x + mix[0] * (xx - x)
    xr = x + mix[1] * (xx - x)
    k = jnp.square(jax.nn.relu(xk @ p["ffn_k"]))
    r = jax.nn.sigmoid(xr @ p["ffn_r"])
    return r * (k @ p["ffn_v"]), {"x_ffn": last}
