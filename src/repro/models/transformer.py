"""Decoder stacks: dense/MoE transformer, RWKV6, and the Zamba2 hybrid.

All stacks scan over stacked layer params (O(1) HLO in depth — DESIGN.md §4)
and share the same cache protocol:

    forward(params, x, positions, caches=None) -> (y, new_caches, aux)

``caches=None``  -> full-sequence (train / no-cache prefill)
``caches`` given -> cached attention (prefill writes, decode S==1 reads)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import mixed_moe
from repro.models import layers as L
from repro.models import ssm as S


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return fn


def _scan_or_loop(body, x, xs, cfg: ModelConfig):
    """lax.scan over stacked layer params, or a python loop (hillclimb knob:
    unrolled HLO lets XLA overlap across layer boundaries)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x, y = body(x, jax.tree_util.tree_map(lambda a: a[i], xs))
        ys.append(y)
    stack = None if ys[0] is None else jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *ys)
    return x, stack


# ---------------------------------------------------------------------------
# Dense / MoE transformer
# ---------------------------------------------------------------------------

def _ffn_or_moe(p, xn, cfg: ModelConfig, par, train, use_kernel, aux_acc,
                token_valid=None, moe_capacity=None):
    """Returns (y, aux_acc, route_ids|None) — ids are the (T, k) routed
    expert slots in BANK order (serve layout permutes experts q4-first).

    ``token_valid`` (B, S) bool masks idle decode slots / prefill pads out
    of the dispatch: their ids are remapped to the out-of-range sentinel
    ``num_experts`` (dropped by ``_local_slot``) so they never occupy
    expert capacity and displace real tokens."""
    if cfg.moe is None:
        return L.mlp(p["mlp"], xn, cfg.act), aux_acc, None
    b, s, d = xn.shape
    x2 = xn.reshape(b * s, d)
    weights, ids, aux = mixed_moe.route(p["moe"]["router"], x2, cfg.moe,
                                        train=train)
    if token_valid is not None:
        v = token_valid.reshape(b * s)
        ids = jnp.where(v[:, None], ids, cfg.moe.num_experts)
        weights = jnp.where(v[:, None], weights, 0.0)
    banks = p["moe"].get("banks")
    if banks is None:
        banks = mixed_moe.train_banks(p["moe"])
    y = mixed_moe.moe_apply(banks, x2, weights, ids, cfg.moe, par,
                            act=cfg.act, use_kernel=use_kernel,
                            capacity=moe_capacity)
    for k, v in aux.items():
        aux_acc[k] = aux_acc.get(k, 0.0) + v
    return y.reshape(b, s, d), aux_acc, ids


def decoder_forward(params, cfg: ModelConfig, x, positions, *,
                    caches=None, par=None, train=False, use_kernel=False,
                    enc_out=None, collect_routes=False, spec=False):
    """x: (B,S,d) embedded input. Returns (y, new_caches, aux).

    ``collect_routes=True`` (MoE serving) additionally stacks the per-layer
    routed expert ids into ``aux["route_ids"]`` (L, T, k) so the engine can
    drive the runtime expert cache (DESIGN.md §3).

    ``spec=True`` (speculative decode, DESIGN.md §17) runs S>=1 new tokens
    through the LIVE-cache attention path (masked ring writes, no
    prefill-from-empty rewrite) and pins the MoE dispatch capacity at the
    full token count so the batched verify forward is drop-free — plain
    decode and verify then score identical distributions."""
    if collect_routes and cfg.moe is None:
        raise ValueError("collect_routes needs routed experts")
    # scan carries must have a fixed structure: pre-seed the aux keys
    zero = jnp.zeros((), jnp.float32)
    aux_total: Dict[str, Any] = \
        {"load_balance": zero, "router_z": zero} if (cfg.moe and train) \
        else {}
    # Serving paths carry pad/idle rows tagged position=-1; keep them out
    # of the MoE dispatch (train positions are always valid — skip the op).
    token_valid = (positions >= 0) if (caches is not None
                                       and cfg.moe is not None) else None
    # drop-free capacity for the speculative paths: per-expert routed
    # assignments are bounded by T = B*S, so cap >= T can never displace
    # a token (the formula's cap scales with T and would otherwise drop
    # DIFFERENT tokens at draft vs verify widths, breaking exactness)
    moe_capacity = x.shape[0] * x.shape[1] if spec else None

    def block(carry, xs):
        x, aux = carry
        p, cache = xs
        h, new_kv = L.attention(
            p["attn"], L.rms_norm(x, p["attn_norm"]["scale"]),
            cfg.attention, positions=positions, cache=cache, spec=spec)
        x = L.constrain(x + h, "residual")
        if enc_out is not None:
            h, _ = L.attention(
                p["cross_attn"],
                L.rms_norm(x, p["cross_attn_norm"]["scale"]),
                cfg.attention, positions=positions, kv_x=enc_out)
            x = L.constrain(x + h, "residual")
        xn = L.rms_norm(x, p["ffn_norm"]["scale"])
        h, aux, ids = _ffn_or_moe(p, xn, cfg, par, train, use_kernel, aux,
                                  token_valid=token_valid,
                                  moe_capacity=moe_capacity)
        ys = (new_kv, ids) if collect_routes else new_kv
        return (L.constrain(x + h, "residual"), aux), ys

    body = _maybe_remat(block, cfg)
    (x, aux_total), ys = _scan_or_loop(
        body, (x, aux_total), (params["layers"], caches), cfg)
    if collect_routes:
        new_caches, route_ids = ys
        aux_total = dict(aux_total, route_ids=route_ids)
    else:
        new_caches = ys
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# RWKV6 stack
# ---------------------------------------------------------------------------

def rwkv_forward(params, cfg: ModelConfig, x, positions, *, caches=None,
                 **_):
    def block(carry, xs):
        x, _ = carry
        p, cache = xs
        tm_cache = None if cache is None else \
            {"state": cache["state"], "x_att": cache["x_att"]}
        h, tm_new = S.rwkv6_timemix(
            p["rwkv"], L.rms_norm(x, p["attn_norm"]["scale"]), cfg.ssm,
            tm_cache)
        x = x + h
        cm_cache = None if cache is None else {"x_ffn": cache["x_ffn"]}
        h, cm_new = S.rwkv6_channelmix(
            p["rwkv"], L.rms_norm(x, p["ffn_norm"]["scale"]), cm_cache)
        new_cache = {**tm_new, **cm_new}
        return (x + h, None), new_cache

    body = _maybe_remat(block, cfg)
    (x, _), new_caches = _scan_or_loop(
        body, (x, None), (params["layers"], caches), cfg)
    return x, new_caches, {}


# ---------------------------------------------------------------------------
# Zamba2 hybrid: [shared-attn, 6x mamba2] x 13 + [shared-attn, 3x mamba2]
# ---------------------------------------------------------------------------

def _hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(num_full_groups, group_size, remainder_layers)."""
    g = cfg.attn_every
    full = cfg.num_layers // g
    rem = cfg.num_layers - full * g
    if rem == 0:           # keep >=1 layer in the tail for the final attn
        full -= 1
        rem = g
    return full, g, rem


def _shared_attn_block(shared, cfg, x, positions, cache):
    h, new_kv = L.attention(
        shared["attn"], L.rms_norm(x, shared["attn_norm"]["scale"]),
        cfg.attention, positions=positions, cache=cache)
    x = x + h
    x = x + L.mlp(shared["mlp"],
                  L.rms_norm(x, shared["ffn_norm"]["scale"]), cfg.act)
    return x, new_kv


def hybrid_forward(params, cfg: ModelConfig, x, positions, *, caches=None,
                   **_):
    full, g, rem = _hybrid_layout(cfg)
    shared = params["shared"]
    mamba_p = params["layers"]
    take = lambda t, a, b: jax.tree_util.tree_map(lambda v: v[a:b], t)
    head_p = take(mamba_p, 0, full * g)
    head_p = jax.tree_util.tree_map(
        lambda v: v.reshape((full, g) + v.shape[1:]), head_p)
    tail_p = take(mamba_p, full * g, cfg.num_layers)

    m_caches = None if caches is None else caches["mamba"]
    a_caches = None if caches is None else caches["attn"]
    head_c = tail_c = a_head_c = a_tail_c = None
    if caches is not None:
        head_c = jax.tree_util.tree_map(
            lambda v: v[:full * g].reshape((full, g) + v.shape[1:]),
            m_caches)
        tail_c = take(m_caches, full * g, cfg.num_layers)
        a_head_c = take(a_caches, 0, full)
        a_tail_c = take(a_caches, full, full + 1)

    def mamba_body(x, xs):
        p, cache = xs
        h, new_c = S.mamba2_block(
            p["mamba"], L.rms_norm(x, p["attn_norm"]["scale"]), cfg.ssm,
            cache)
        return x + h, new_c

    mamba_body = _maybe_remat(mamba_body, cfg)

    def group_body(x, xs):
        p_g, mc_g, ac_g = xs
        x, new_kv = _shared_attn_block(shared, cfg, x, positions, ac_g)
        x, new_mc = jax.lax.scan(mamba_body, x, (p_g, mc_g))
        return x, (new_mc, new_kv)

    x, (new_head_mc, new_head_ac) = jax.lax.scan(
        group_body, x, (head_p, head_c, a_head_c))

    # tail: one more shared-attn application + remaining mamba layers
    tail_ac = None if a_tail_c is None else jax.tree_util.tree_map(
        lambda v: v[0], a_tail_c)
    x, new_tail_ac = _shared_attn_block(shared, cfg, x, positions, tail_ac)
    x, new_tail_mc = jax.lax.scan(mamba_body, x, (tail_p, tail_c))

    new_caches = None
    if caches is not None:
        flat_mc = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate(
                [a.reshape((full * g,) + a.shape[2:]), b]),
            new_head_mc, new_tail_mc)
        flat_ac = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b[None]]),
            new_head_ac, new_tail_ac)
        new_caches = {"mamba": flat_mc, "attn": flat_ac}
    return x, new_caches, {}


FORWARDS = {
    "dense": decoder_forward,
    "moe": decoder_forward,
    "vlm": decoder_forward,
    "ssm": rwkv_forward,
    "hybrid": hybrid_forward,
}
