"""Encoder-decoder stack (SeamlessM4T backbone).

The speech frontend is a stub per spec: the encoder consumes precomputed
frame embeddings (B, S_src, d). The decoder is the shared decoder_forward
with cross-attention; at prefill the encoder output is computed once and
carried in the cache (cross-K/V are recomputed per call — simple and cheap
relative to self-attention; caching them is a recorded optimization).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import _maybe_remat, _scan_or_loop, \
    decoder_forward


def encoder_forward(params, cfg: ModelConfig, src: jax.Array):
    """src: (B, S_src, d) frontend embeddings -> (B, S_src, d)."""
    positions = jnp.broadcast_to(jnp.arange(src.shape[1]), src.shape[:2])
    acfg = cfg.attention.__class__(**{**cfg.attention.__dict__,
                                      "causal": False})

    def block(x, p):
        h, _ = L.attention(p["attn"],
                           L.rms_norm(x, p["attn_norm"]["scale"]),
                           acfg, positions=positions)
        x = x + h
        h = L.mlp(p["mlp"], L.rms_norm(x, p["ffn_norm"]["scale"]), cfg.act)
        return x + h, None

    body = _maybe_remat(block, cfg)
    x, _ = _scan_or_loop(body, src, params["encoder"], cfg)
    return L.rms_norm(x, params["encoder_norm"]["scale"])


def encdec_forward(params, cfg: ModelConfig, x, positions, *,
                   caches=None, enc_out=None, src=None, **kw):
    """Decoder over embedded targets ``x`` with cross-attention to
    ``enc_out`` (or freshly encoded ``src``)."""
    if enc_out is None:
        assert src is not None, "enc-dec needs src embeddings or enc_out"
        enc_out = encoder_forward(params, cfg, src)
    dec_caches = None if caches is None else caches["self"]
    y, new_self, aux = decoder_forward(
        params, cfg, x, positions, caches=dec_caches, enc_out=enc_out, **kw)
    new_caches = None
    if caches is not None:
        new_caches = {"self": new_self, "enc_out": enc_out}
    return y, new_caches, aux
