"""Parse collective ops out of compiled (post-SPMD, per-device) HLO text.

``compiled.cost_analysis()`` does not report collective traffic, and counts
a while-loop (lax.scan) body ONCE. This parser:

  * attributes every collective op to its enclosing computation,
  * recovers while-loop trip counts from the loop-condition comparison
    constant (scan lowers to ``while`` with an induction-variable bound),
  * multiplies per-body collective bytes by the trip count (nested loops
    multiply through),
  * returns bytes per collective kind — the roofline's collective term.

Byte convention (per device, per step): the *wire payload* — max(result
bytes, summed operand bytes). all-gather counts the gathered result,
reduce-scatter counts the pre-scatter operand, all-reduce counts the
(equal-sized) buffer once.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.+?)\s+("
    + "|".join(COLLECTIVES) + r")(-start|-done)?\(([^)]*)\)")

_COMP_OPEN_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$|"
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*\{\s*$")


def shape_bytes(text: str) -> float:
    """Total bytes of every typed shape literal in ``text``."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> body lines.

    HLO computation headers start at column 0 and end with '{' (their param
    lists may contain nested parens, so we don't parse them); body lines are
    indented; '}' at column 0 closes."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and not line.startswith((" ", "}")):
                m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
        else:
            comps[cur].append(line)
    return comps


def _while_edges(comps: Dict[str, List[str]]):
    """[(parent, body, cond, trip_count|None)] for every while op.

    XLA annotates scan-derived loops with backend_config
    known_trip_count — preferred; else fall back to the condition const."""
    edges = []
    for parent, lines in comps.items():
        for ln in lines:
            if re.search(r"\bwhile\(", ln):
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                mt = re.search(r'known_trip_count[^0-9]*?"(\d+)"', ln)
                if mb and mc:
                    edges.append((parent, mb.group(1), mc.group(1),
                                  int(mt.group(1)) if mt else None))
    return edges


def _trip_count(cond_lines: List[str]) -> int:
    """Loop bound from the condition's comparison constant (scan pattern)."""
    consts = {}
    for ln in cond_lines:
        m = re.search(r"%?([\w\.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)",
                      ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if "compare(" in ln:
            for name, val in consts.items():
                if re.search(rf"%?{re.escape(name)}\b", ln):
                    return max(val, 1)
    if consts:
        return max(consts.values())
    return 1


def comp_multipliers(hlo: str) -> Dict[str, int]:
    """computation -> times executed per step (while nesting, fixpoint)."""
    comps = _split_computations(hlo)
    edges = _while_edges(comps)
    mult: Dict[str, int] = defaultdict(lambda: 1)
    for _ in range(8):
        changed = False
        for parent, body, cond, trip in edges:
            t = trip if trip is not None else \
                _trip_count(comps.get(cond, []))
            want = mult[parent] * t
            if mult[body] != want:
                mult[body] = want
                changed = True
            mult[cond] = want
        if not changed:
            break
    return dict(mult)


def _symbol_table(lines: List[str]) -> Dict[str, str]:
    """op name -> result type string (within one computation)."""
    table = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            table[m.group(1)] = m.group(2)
    return table


_CALLS_RE = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# Ops whose container-level appearance is NOT an HBM read/write (control
# flow, aliasing, or already accounted inside their body computation).
_TRAFFIC_SKIP = ("parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "conditional", "after-all",
                 "add-dependency", "partition-id", "replica-id")


def _call_edges(comps: Dict[str, List[str]]):
    """[(parent, child)] for fusion/reduce/branch-called computations."""
    edges = []
    for parent, lines in comps.items():
        for ln in lines:
            if re.search(r"\bwhile\(", ln):
                continue  # body=/condition= handled by _while_edges
            for child in _CALLS_RE.findall(ln):
                edges.append((parent, child))
            mb = _BRANCH_RE.search(ln)
            if mb:
                for child in re.findall(r"%?([\w\.\-]+)", mb.group(1)):
                    edges.append((parent, child))
    return edges


def comp_multipliers_full(hlo: str) -> Tuple[Dict[str, List[str]],
                                             Dict[str, int], set]:
    """(computations, multiplier incl. call-propagation, called-set).

    ``called`` = computations reached via calls=/to_apply=/branches — their
    bodies are *inside* a container op, so container-level traffic must not
    walk them (but FLOP counting must, at the propagated multiplier)."""
    comps = _split_computations(hlo)
    wmult = comp_multipliers(hlo)
    mult: Dict[str, int] = defaultdict(lambda: 1)
    mult.update(wmult)
    calls = _call_edges(comps)
    called = {c for _, c in calls}
    for _ in range(8):
        changed = False
        for parent, child in calls:
            if mult[child] != mult[parent]:
                mult[child] = mult[parent]
                changed = True
        if not changed:
            break
    return comps, dict(mult), called


def _dot_flops(ln: str, table: Dict[str, str]) -> float:
    """2 * prod(result dims) * prod(lhs contracting sizes)."""
    m = _DEF_RE.match(ln)
    if not m:
        return 0.0
    result_type = m.group(2)
    dims_m = _SHAPE_RE.search(result_type)
    if not dims_m:
        return 0.0
    out_n = 1
    if dims_m.group(2):
        for d in dims_m.group(2).split(","):
            out_n *= int(d)
    cm = _LHS_CONTRACT_RE.search(ln)
    operands = re.findall(r"%([\w\.\-]+)", ln.split("dot(", 1)[1])
    if not cm or not operands:
        return 0.0
    lhs_type = table.get(operands[0], "")
    lm = _SHAPE_RE.search(lhs_type)
    if not lm:
        return 0.0
    lhs_dims = [int(d) for d in lm.group(2).split(",")] if lm.group(2) else []
    k = 1
    for ci in cm.group(1).split(","):
        if ci != "" and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * out_n * k


def _operand_names(ln: str) -> List[str]:
    """Operand op-names of an HLO instruction line (metadata refs come
    after the closing paren and are filtered by the symbol-table lookup)."""
    inner = ln.split("(", 1)
    if len(inner) < 2:
        return []
    return re.findall(r"%([\w\.\-]+)", inner[1].split(")", 1)[0])


# Ops that consume only a *window* of their big operand — counting the full
# operand would charge a scanned weight stack once per iteration.
_SLICING_OPS = ("dynamic-slice", "slice", "gather")


def _op_traffic(ln: str, op: str, table: Dict[str, str],
                body: Optional[List[str]] = None) -> float:
    """HBM bytes for one container-level instruction.

    Conventions (matching XLA's utilization-aware bytes-accessed):
      * slicing ops read+write the slice, not the whole operand;
      * dynamic-update-slice reads+writes the update region (the target
        buffer is aliased in place);
      * scatter reads+writes the update region (+indices, ignored);
      * fusion: walk the body — a parameter consumed only by slicing ops
        contributes the slice bytes; a root that is a DUS (or tuple of
        DUSes) contributes update bytes, not the whole aliased buffer.
    """
    dm = _DEF_RE.match(ln)
    res_b = shape_bytes(dm.group(2))
    names = _operand_names(ln)
    if op in _SLICING_OPS:
        return 2 * res_b
    if op == "dynamic-update-slice":
        upd = shape_bytes(table.get(names[1], "")) if len(names) > 1 else 0.0
        return 2 * upd
    if op == "scatter":
        upd = shape_bytes(table.get(names[2], "")) if len(names) > 2 else res_b
        return 2 * upd + res_b  # read region + write + read target row
    if op != "fusion" or body is None:
        return res_b + sum(shape_bytes(table.get(n, "")) for n in names)
    return _fusion_traffic(res_b, names, table, body)


# Pass-through ops an in-place update chain may route through — on the TPU
# target these do not break input/output buffer aliasing.
_PASSTHROUGH = ("convert", "bitcast", "copy", "reshape", "transpose")


def _fusion_traffic(res_b: float, names: List[str], table: Dict[str, str],
                    body: List[str]) -> float:
    """Utilization-aware traffic of one fusion op (see _op_traffic)."""
    btable = _symbol_table(body)
    defs: Dict[str, Tuple[str, List[str]]] = {}
    params: Dict[int, str] = {}
    for bl in body:
        bm = _DEF_RE.match(bl)
        if not bm:
            continue
        defs[bm.group(1)] = (bm.group(3), _operand_names(bl))
        if bm.group(3) == "parameter":
            pm = re.search(r"parameter\((\d+)\)", bl)
            if pm:
                params[int(pm.group(1))] = bm.group(1)

    def resolve(name: str) -> str:
        """Follow single-operand pass-through chains to the source op."""
        seen = 0
        while name in defs and defs[name][0] in _PASSTHROUGH \
                and defs[name][1] and seen < 16:
            name = defs[name][1][0]
            seen += 1
        return name

    # ---- in-place update roots: DUS / scatter alias their target param
    aliased: Dict[str, float] = {}   # param name -> read bytes to charge
    upd_write = 0.0
    has_update_root = False
    for bl in body:
        bm = _DEF_RE.match(bl)
        if not bm or bm.group(3) not in ("dynamic-update-slice", "scatter"):
            continue
        kind, ons = bm.group(3), _operand_names(bl)
        if not ons:
            continue
        src = resolve(ons[0])
        upd_idx = 1 if kind == "dynamic-update-slice" else 2
        upd_name = resolve(ons[upd_idx]) if len(ons) > upd_idx else ""
        upd_b = shape_bytes(btable.get(ons[upd_idx], "")) \
            if len(ons) > upd_idx else 0.0
        if upd_b == 0.0 and upd_name in btable:
            upd_b = shape_bytes(btable.get(upd_name, ""))
        if src in params.values():
            # scatter-add reads the touched region before writing it
            aliased[src] = aliased.get(src, 0.0) + (
                upd_b if kind == "scatter" else 0.0)
            has_update_root = True
            upd_write += upd_b

    read = 0.0
    for idx, name in enumerate(names):
        full = shape_bytes(table.get(name, ""))
        pname = params.get(idx)
        if pname is None:
            read += full
            continue
        if pname in aliased:
            read += min(aliased[pname], full)
            continue
        consumed = 0.0
        sliced_only = True
        for bname, (kind, ons) in defs.items():
            if pname not in ons:
                continue
            if kind in _SLICING_OPS:
                consumed += shape_bytes(btable.get(bname, ""))
            elif kind in ("dynamic-update-slice", "scatter") \
                    and ons and resolve(ons[0]) == pname:
                continue
            else:
                sliced_only = False
        read += min(consumed, full) if sliced_only else full

    write = min(upd_write, res_b) if has_update_root else res_b
    return read + write


_PURE_BODY_OPS = set(_PASSTHROUGH) | {"parameter", "constant"}


def _pure_convert_fusions(comps: Dict[str, List[str]]
                          ) -> Tuple[set, Dict[str, float]]:
    """(pure, sliced) fusion-body classification for the TPU adjustment.

    ``pure``: bodies that only convert/relayout — fold into the consumer
    on TPU (the MXU reads bf16 natively; these exist because the CPU
    backend computes dots in f32). Charged 0; resolution passes through.

    ``sliced``: convert bodies that also dynamic-slice (the per-layer
    weight slice of a scanned stack, converted for the CPU dot). On TPU
    the consumer reads the bf16 slice directly: maps body name -> slice
    bytes AT SOURCE DTYPE."""
    pure = set()
    sliced: Dict[str, float] = {}
    for comp, lines in comps.items():
        ops = []
        for ln in lines:
            bm = _DEF_RE.match(ln)
            if bm:
                ops.append((bm.group(3), bm.group(2)))
        kinds = {k for k, _ in ops}
        if not ops:
            continue
        if kinds <= _PURE_BODY_OPS:
            pure.add(comp)
            continue
        if kinds <= (_PURE_BODY_OPS | {"dynamic-slice", "slice"}):
            # slice bytes at the narrowest dtype seen in the body (the
            # source param dtype before any widening convert)
            widths = [_DTYPE_BYTES[m.group(1)]
                      for _, t in ops
                      for m in [_SHAPE_RE.search(t)] if m]
            narrow = min(widths) if widths else 2
            b = 0.0
            for k, t in ops:
                if k in ("dynamic-slice", "slice"):
                    m = _SHAPE_RE.search(t)
                    if m:
                        n = 1
                        for dim in (m.group(2).split(",")
                                    if m.group(2) else []):
                            n *= int(dim)
                        b += n * narrow
            sliced[comp] = b
    return pure, sliced


def cost_summary(hlo: str, tpu_adjusted: bool = False) -> Dict[str, float]:
    """Trip-count-corrected FLOPs and HBM-traffic estimate (per device,
    per step) from compiled post-SPMD HLO.

    * flops — matmul FLOPs: every ``dot`` op in every computation (fusion
      bodies included), weighted by how many times its computation runs.
      Elementwise/reduce FLOPs are excluded (matmuls dominate; compare
      against cost_analysis()['flops'] for the residual).
    * bytes_accessed — container-level traffic model: for each op in a
      computation that is NOT a fusion/reduce body (i.e. entry, while
      bodies, branch bodies), count utilization-aware operand + result
      bytes (see _op_traffic), trip-count weighted.

    ``tpu_adjusted=True`` removes the CPU-backend f32-promotion artifacts
    for the TPU roofline: pure dtype/layout-convert fusions are charged 0
    (the MXU consumes bf16 operands directly), and dot operands that are
    f32 views of narrower tensors are charged at the SOURCE dtype.
    """
    comps, mult, called = comp_multipliers_full(hlo)
    pure, sliced = _pure_convert_fusions(comps) if tpu_adjusted \
        else (set(), {})
    flops = 0.0
    dot_count = 0
    traffic = 0.0
    for comp, lines in comps.items():
        m = mult.get(comp, 1)
        table = _symbol_table(lines)
        kinds: Dict[str, Tuple[str, List[str]]] = {}
        if tpu_adjusted:
            for ln in lines:
                bm = _DEF_RE.match(ln)
                if bm:
                    kinds[bm.group(1)] = (bm.group(3), _operand_names(ln))

        def source_bytes(name: str) -> float:
            """Bytes of ``name`` charged at its pre-convert source."""
            seen = 0
            while seen < 16 and name in kinds:
                kind, ons = kinds[name]
                if kind in _PASSTHROUGH and ons:
                    name = ons[0]
                elif kind == "fusion":
                    cm2 = _CALLS_RE.search(
                        next(l for l in lines
                             if re.match(rf"\s*(?:ROOT\s+)?%?"
                                         rf"{re.escape(name)}\s*=", l)))
                    if cm2 and cm2.group(1) in sliced:
                        return sliced[cm2.group(1)]
                    if cm2 and cm2.group(1) in pure and ons:
                        name = max(ons, key=lambda n: shape_bytes(
                            table.get(n, "")))
                    else:
                        break
                else:
                    break
                seen += 1
            return shape_bytes(table.get(name, ""))

        for ln in lines:
            if re.search(r"\bdot\(", ln):
                flops += _dot_flops(ln, table) * m
                dot_count += m
        if comp in called:
            continue  # fusion/reduce body: traffic counted at call site
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            op = dm.group(3)
            if op in _TRAFFIC_SKIP:
                continue
            body = None
            if op == "fusion":
                cm = _CALLS_RE.search(ln)
                if cm:
                    if cm.group(1) in pure or cm.group(1) in sliced:
                        continue        # folds into the consumer on TPU
                    body = comps.get(cm.group(1))
            if tpu_adjusted and op == "dot":
                res_b = shape_bytes(dm.group(2)) / 2.0   # f32 out -> bf16
                names = _operand_names(ln)
                traffic += (res_b + sum(
                    min(source_bytes(n), shape_bytes(table.get(n, "")))
                    for n in names)) * m
                continue
            traffic += _op_traffic(ln, op, table, body) * m
    return {"flops": flops, "bytes_accessed": traffic,
            "dot_count": dot_count}


def collective_summary(hlo: str, tpu_adjusted: bool = False
                       ) -> Dict[str, float]:
    """bytes per collective kind, trip-count weighted (per device, per
    step), plus op counts.

    ``tpu_adjusted``: a collective whose operand is a pure f32 view of a
    bf16 tensor (CPU dots compute in f32) is charged at the source dtype
    — the TPU graph reduces the bf16 tensor directly."""
    comps = _split_computations(hlo)
    mult = comp_multipliers(hlo)
    pure = _pure_convert_fusions(comps)[0] if tpu_adjusted else set()
    bytes_by_kind: Dict[str, float] = defaultdict(float)
    count_by_kind: Dict[str, int] = defaultdict(int)
    for comp, lines in comps.items():
        m = mult.get(comp, 1)
        table = _symbol_table(lines)
        kinds: Dict[str, Tuple[str, List[str]]] = {}
        if tpu_adjusted:
            for ln in lines:
                bm = _DEF_RE.match(ln)
                if bm:
                    kinds[bm.group(1)] = (bm.group(3), _operand_names(ln))

        def op_bytes(name: str) -> float:
            full = shape_bytes(table.get(name, ""))
            if not tpu_adjusted:
                return full
            seen = 0
            while seen < 16 and name in kinds:
                kind, ons = kinds[name]
                is_pure_fusion = False
                if kind == "fusion":
                    ln2 = next((l for l in lines if re.match(
                        rf"\s*(?:ROOT\s+)?%?{re.escape(name)}\s*=", l)), "")
                    cm2 = _CALLS_RE.search(ln2)
                    is_pure_fusion = bool(cm2) and cm2.group(1) in pure
                if (kind in _PASSTHROUGH or is_pure_fusion) and ons:
                    name = max(ons, key=lambda n: shape_bytes(
                        table.get(n, "")))
                    seen += 1
                else:
                    break
            return min(full, shape_bytes(table.get(name, "")) or full)

        for ln in lines:
            cm = _COLL_RE.match(ln)
            if not cm:
                continue
            result_type, kind, suffix, operands = cm.groups()
            if suffix == "-done":
                continue              # payload counted at -start
            names = re.findall(r"%([\w\.\-]+)", operands)
            op_b_raw = sum(shape_bytes(table.get(n, "")) for n in names)
            op_b = sum(op_bytes(n) for n in names)
            res_b = shape_bytes(result_type)
            if tpu_adjusted and op_b_raw > 0:
                res_b *= op_b / op_b_raw      # result narrows with operands
            bytes_by_kind[kind] += max(res_b, op_b) * m
            count_by_kind[kind] += m
    out: Dict[str, float] = {}
    for k in COLLECTIVES:
        out[f"{k}_bytes"] = round(bytes_by_kind.get(k, 0.0), 1)
        out[f"{k}_count"] = count_by_kind.get(k, 0)
    out["total_bytes"] = round(sum(bytes_by_kind.values()), 1)
    return out
