"""Three-term roofline from the dry-run's compiled artifacts (DESIGN.md §7).

This container is CPU-only; TPU v5e is the *target*. Per (arch × shape ×
mesh) cell we derive, from ``results/dryrun/*.json``:

    compute term    = HLO_FLOPs_per_dev / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_dev / HBM_bw               [s]
    collective term = collective_bytes_per_dev / ICI_link_bw   [s]

HLO_FLOPs / HLO_bytes are the *trip-count-corrected* parses of the
compiled post-SPMD HLO (``hlo_cost`` in the JSON — ``cost_analysis()``
counts a scanned layer body once, see ``roofline/hlo_parse.py``);
collective bytes are likewise trip-weighted wire payloads per device.

We also report the analytic MODEL_FLOPS (6·N·D train / 2·N_active·D
inference, D = tokens processed by the cell) and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs — remat recompute, redundant gathers and padding
show up as ratio < 1.

The *bound* on step time is max(terms); the achievable MFU bound is
t_model / bound.  The perf loop (EXPERIMENTS.md §Perf) iterates on
whichever term dominates.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


@dataclasses.dataclass(frozen=True)
class Hardware:
    """TPU v5e per-chip constants (spec)."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # B/s
    ici_bw: float = 45e9            # B/s per link, bidirectional once
    hbm_bytes: float = 16e9


V5E = Hardware()

MESH_CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    tokens: float                # tokens processed per step (global)
    t_compute: float             # [s]
    t_memory: float              # TPU-adjusted bytes (see hlo_parse)
    t_collective: float
    t_model: float               # MODEL_FLOPS/(chips*peak): ideal step time
    model_flops: float           # global analytic FLOPs per step
    hlo_flops: float             # per-device, trip-corrected
    hlo_bytes: float             # TPU-adjusted
    hlo_bytes_raw: float         # as-compiled (CPU backend, f32 dots)
    coll_bytes: float
    useful_ratio: float          # model_flops/chips / hlo_flops
    peak_gib: float

    @property
    def bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def mfu_bound(self) -> float:
        return self.t_model / self.bound if self.bound else 0.0

    def advice(self) -> str:
        d = self.dominant
        if d == "collective":
            return ("cut collective volume: fewer/smaller all-gathers "
                    "(weight-stationary layout, reduce-scatter grads, "
                    "overlap with compute)")
        if d == "memory":
            if self.useful_ratio < 0.5:
                return ("HBM-bound with low useful ratio: reduce remat "
                        "recompute / padding; quantized weights cut "
                        "weight-read bytes 4x")
            return ("HBM-bound: raise arithmetic intensity (bigger batch "
                    "per device, fused dequant-matmul, KV-cache layout)")
        if self.useful_ratio < 0.5:
            return ("compute-bound but <50% useful FLOPs: remove remat or "
                    "redundant compute before anything else")
        return ("compute-bound near roofline: only kernel-level wins left "
                "(MXU-aligned tiles, fusion)")


def tokens_for(shape: str, rec: dict) -> float:
    """Tokens processed per step (decode: one per sequence)."""
    seq = {"train_4k": 4096, "prefill_32k": 32768,
           "decode_32k": 1, "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32,
             "decode_32k": 128, "long_500k": 1}[shape]
    return float(seq * batch)


def model_flops_for(shape: str, rec: dict) -> float:
    """Analytic MODEL_FLOPS per step: 6·N·D (train) / 2·N_active·D (inf)."""
    n_active = rec["active_params_b"] * 1e9
    d = tokens_for(shape, rec)
    mult = 6.0 if shape.startswith("train") else 2.0
    return mult * n_active * d


def load_cell(path: Path, hw: Hardware = V5E) -> Optional[CellRoofline]:
    rec = json.loads(path.read_text())
    if not rec.get("ok"):
        return None
    chips = MESH_CHIPS[rec["mesh"]]
    hc = rec.get("hlo_cost") or rec.get("cost_analysis", {})
    hlo_flops = float(hc.get("flops", 0.0))
    raw_bytes = float(hc.get("bytes_accessed",
                             rec.get("cost_analysis", {})
                             .get("bytes accessed", 0.0)))
    hlo_bytes = float(rec.get("hlo_cost_tpu", {})
                      .get("bytes_accessed", raw_bytes))
    coll = float(rec.get("collectives", {}).get("total_bytes", 0.0))
    mf = model_flops_for(rec["shape"], rec)
    return CellRoofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        tokens=tokens_for(rec["shape"], rec),
        t_compute=hlo_flops / hw.peak_flops,
        t_memory=hlo_bytes / hw.hbm_bw,
        t_collective=coll / hw.ici_bw,
        t_model=mf / (chips * hw.peak_flops),
        model_flops=mf,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, hlo_bytes_raw=raw_bytes,
        coll_bytes=coll,
        useful_ratio=(mf / chips) / hlo_flops if hlo_flops else 0.0,
        peak_gib=rec.get("memory", {}).get("peak_per_device_gib", 0.0),
    )


def load_all(results: Path = RESULTS, mesh: Optional[str] = None,
             tag: str = "") -> List[CellRoofline]:
    cells = []
    for p in sorted(results.glob(f"*__*{tag}.json")):
        stem_parts = p.stem.split("__")
        if len(stem_parts) != 3 or (tag and not stem_parts[2].endswith(tag)):
            continue
        if tag == "" and not stem_parts[2].startswith("pod"):
            continue
        if tag == "" and stem_parts[2] not in MESH_CHIPS:
            continue  # skip tagged perf-variant files in the baseline table
        c = load_cell(p)
        if c and (mesh is None or c.mesh == mesh):
            cells.append(c)
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def markdown_table(cells: List[CellRoofline]) -> str:
    hdr = ("| arch | shape | mesh | t_comp | t_mem | t_coll | bound "
           "| dominant | MFU-bound | useful | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in sorted(cells, key=lambda c: (c.arch, c.shape, c.mesh)):
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {_fmt_s(c.t_compute)} "
            f"| {_fmt_s(c.t_memory)} | {_fmt_s(c.t_collective)} "
            f"| {_fmt_s(c.bound)} | {c.dominant} | {c.mfu_bound:.1%} "
            f"| {c.useful_ratio:.2f} | {c.peak_gib:.1f} |")
    return hdr + "\n".join(rows) + "\n"


def pick_hillclimb_cells(cells: List[CellRoofline]) -> Dict[str, CellRoofline]:
    """The three §Perf targets: worst MFU-bound, most collective-bound,
    and the paper-representative cell (mixtral decode — the paper's own
    serving workload).

    The worst-fraction pick is restricted to TRAIN cells: decode steps have
    t_model ~ 2*N_active*B/(chips*peak) = microseconds against a mandatory
    one-HBM-pass-of-the-weights memory floor, so their MFU-bound is ~0 by
    construction and not a defect signal. For decode cells the defect
    signal is t_mem vs the analytic weight+cache read floor instead."""
    single = [c for c in cells if c.mesh == "pod16x16"]
    train = [c for c in single if c.shape.startswith("train")] or single
    worst = min(train, key=lambda c: c.mfu_bound)
    coll = max(single, key=lambda c: (c.t_collective / c.bound
                                      if c.bound else 0.0))
    paper = next((c for c in single
                  if c.arch == "mixtral-8x7b" and c.shape == "decode_32k"),
                 single[0])
    return {"worst-mfu": worst, "most-collective": coll,
            "paper-representative": paper}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=list(MESH_CHIPS), default=None)
    ap.add_argument("--md", type=Path, default=None,
                    help="write markdown table here")
    ap.add_argument("--pick", action="store_true",
                    help="print the three hillclimb targets")
    args = ap.parse_args()
    cells = load_all(mesh=args.mesh)
    table = markdown_table(cells)
    print(table)
    if args.md:
        args.md.write_text(table)
    if args.pick:
        for why, c in pick_hillclimb_cells(cells).items():
            print(f"{why:22s} {c.arch} {c.shape} dominant={c.dominant} "
                  f"mfu_bound={c.mfu_bound:.1%}")


if __name__ == "__main__":
    main()
