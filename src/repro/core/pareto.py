"""Pareto frontier over the MoP configuration space (DESIGN.md §9, §11).

The paper's planner exposes the *mechanism* — (Num_E4, residency) knobs —
but a serving deployment declares *targets*: "at least X tokens/s, at most
Y% perplexity loss, inside Z bytes of HBM". This module is the bridge:

* :class:`ParetoFrontier` enumerates the (counts-per-ladder-rung ×
  residency split) configuration space through the analytic cost model
  ONCE per (model, hardware, batch) — the enumeration is what the paper
  calls the fine-grained configuration space of Figs. 2+3, generalized
  from the boolean Num_E4 axis to one count axis per quantized ladder
  rung — and keeps the dominant set in the three QoS axes (tokens/s ↑,
  quality_proxy ↓, device bytes ↓). Binary ladders enumerate the full
  per-layer grid (bit-identical to the legacy (Num_E4 × residency)
  space); multi-rung ladders prune the count grid to a stride lattice
  (always containing 0 and E per rung) sized so the enumeration stays
  under ``max_enum_points`` — the §11 tractability rule.
* :class:`QoSTarget` is the declarative constraint a caller states instead
  of knob values; :meth:`ParetoFrontier.select` resolves it to one
  :class:`FrontierPoint` with deterministic tie-breaking: among points
  meeting the target, prefer quality, then the lowest device footprint.
* the runtime :class:`~repro.serving.qos.QoSController` walks *adjacent*
  frontier points when the measured QoS drifts outside the target band.

Every ``FrontierPoint`` carries the concrete ``PrecisionPlan`` so applying
a point is exactly the planner's ``plan(device_bytes, "quality", nq)``
result — the frontier and the imperative path can never disagree.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core import cost_model
from repro.core.cost_model import HardwareModel, QoSEstimate
from repro.core.precision_plan import (PrecisionPlan, balanced_ladder_plan,
                                       quantized_rungs, validate_ladder)

__all__ = [
    "QoSTarget", "FrontierPoint", "ParetoFrontier", "InfeasibleTarget",
]


class InfeasibleTarget(ValueError):
    """No enumerated configuration satisfies the target's hard constraints."""


def _fmt_bytes(n: float) -> str:
    return (f"{n / 2**30:.2f}GiB" if n >= 2**30
            else f"{n / 2**20:.2f}MiB")


@dataclasses.dataclass(frozen=True)
class QoSTarget:
    """Declarative service-level objective for one serving deployment.

    All fields are optional; unset means unconstrained. ``min_tokens_per_s``
    is a *soft* objective (the controller chases it; ``select`` falls back
    to the fastest feasible point when nothing meets it — best effort),
    while ``mem_budget_bytes`` and ``max_quality_loss`` are *hard*
    constraints (a point violating them is never selected).

    ``min_tokens_per_s=math.inf`` is the idiom for "as fast as possible
    under the constraints" (the old ``preference="throughput"``).
    """
    min_tokens_per_s: Optional[float] = None
    # max tolerated perplexity increase vs all-16-bit, fractional:
    # 0.05 == "at most +5% perplexity" (quality_proxy <= 1.05).
    max_quality_loss: Optional[float] = None
    mem_budget_bytes: Optional[float] = None
    # p95 per-request latency ceiling; no analytic predictor exists for it,
    # so only the runtime QoSController acts on this field.
    max_p95_latency_s: Optional[float] = None

    def describe(self) -> str:
        parts = []
        if self.min_tokens_per_s is not None:
            parts.append("tok/s>=inf" if math.isinf(self.min_tokens_per_s)
                         else f"tok/s>={self.min_tokens_per_s:g}")
        if self.max_quality_loss is not None:
            parts.append(f"ppl<=x{1.0 + self.max_quality_loss:.3f}")
        if self.mem_budget_bytes is not None:
            parts.append(f"mem<={_fmt_bytes(self.mem_budget_bytes)}")
        if self.max_p95_latency_s is not None:
            parts.append(f"p95<={self.max_p95_latency_s * 1e3:.0f}ms")
        return " ".join(parts) or "unconstrained"

    def with_kv_reclaimed(self, reclaimed_bytes: float) -> "QoSTarget":
        """The same target with KV savings credited to the expert-
        residency budget (DESIGN.md §13): the paged cache prices KV per
        mapped page, so HBM the slot cache would have stranded as bucket
        padding widens ``mem_budget_bytes`` instead. No-op when no budget
        is declared (unconstrained stays unconstrained) or nothing was
        reclaimed."""
        if not reclaimed_bytes or self.mem_budget_bytes is None \
                or not math.isfinite(self.mem_budget_bytes):
            return self
        return dataclasses.replace(
            self, mem_budget_bytes=self.mem_budget_bytes
            + float(reclaimed_bytes))


# eq=False: the embedded PrecisionPlan holds ndarrays, so generated
# dataclass equality would be ambiguous — identity semantics are correct
# here (frontier points are interned singletons of their frontier).
@dataclasses.dataclass(frozen=True, eq=False)
class FrontierPoint:
    """One dominant configuration: the knob values, the concrete plan they
    expand to, and the cost model's QoS estimate for it.

    ``counts_per_rung`` are the GLOBAL expert counts aligned with the
    plan's ladder (descending, 16-bit rung first); ``num_q_experts`` is
    their sub-16-bit sum — the paper's Num_E4 for a binary ladder."""
    num_q_experts: int        # global quantized count (multiple of L)
    resident_experts: int     # global ACCELERATOR-resident expert count
    #                           (local + peer under EP; == local at ep=1)
    plan: PrecisionPlan
    qos: QoSEstimate
    counts_per_rung: Tuple[int, ...] = ()
    #: of ``resident_experts``, how many live on PEER devices (EP
    #: placement tier, DESIGN.md §16); always 0 at ep=1.
    peer_experts: int = 0

    def quantized_counts(self) -> Dict[int, int]:
        """{rung: global count} over the plan's quantized rungs — the
        planner's ``counts`` argument (engine apply path)."""
        return {b: c for b, c in zip(self.plan.ladder, self.counts_per_rung)
                if b < 16}

    def meets(self, target: QoSTarget) -> bool:
        """Hard constraints AND the throughput objective (analytically)."""
        return (self.feasible_under(target)
                and (target.min_tokens_per_s is None
                     or self.qos.tokens_per_s >= target.min_tokens_per_s))

    def feasible_under(self, target: QoSTarget) -> bool:
        """Hard constraints only (budget + quality ceiling)."""
        if target.mem_budget_bytes is not None \
                and self.qos.device_bytes > target.mem_budget_bytes:
            return False
        if target.max_quality_loss is not None \
                and self.qos.quality_proxy > 1.0 + target.max_quality_loss \
                + 1e-12:
            return False
        return True

    def summary(self) -> str:
        q = self.qos
        rungs = [b for b in self.plan.ladder if b < 16]
        if len(rungs) <= 1:
            knobs = f"E{rungs[0] if rungs else 4}={self.num_q_experts}"
        else:
            counts = self.quantized_counts()
            knobs = "E[" + ",".join(f"{b}b={counts[b]}"
                                    for b in self.plan.ladder
                                    if b < 16) + "]"
        return (f"{knobs} res={self.resident_experts} "
                f"dev={_fmt_bytes(q.device_bytes)} "
                f"tok/s={q.tokens_per_s:.2f} ppl=x{q.quality_proxy:.3f}")


def _dominates(a: FrontierPoint, b: FrontierPoint) -> bool:
    """a dominates b in (tokens/s ↑, quality ↓, device bytes ↓)."""
    ge = (a.qos.tokens_per_s >= b.qos.tokens_per_s
          and a.qos.quality_proxy <= b.qos.quality_proxy
          and a.qos.device_bytes <= b.qos.device_bytes)
    gt = (a.qos.tokens_per_s > b.qos.tokens_per_s
          or a.qos.quality_proxy < b.qos.quality_proxy
          or a.qos.device_bytes < b.qos.device_bytes)
    return ge and gt


class ParetoFrontier:
    """The dominant set of the (counts-per-rung × residency) space.

    Built once per (model config, hardware model, batch size, seed) — i.e.
    once per hardware/budget regime change, NOT per request. Budgets are
    query-time filters (``QoSTarget.mem_budget_bytes``) so one frontier
    serves every tenant budget.

    The precision ladder comes from ``cfg.mop.precision_ladder``. A
    binary ladder enumerates each per-layer quantized count 0..E (the
    legacy ``(E+1)²`` space, bit-identical plans). A K-rung ladder
    enumerates one count axis per quantized rung; the grid is pruned to
    per-rung stride lattices (§11 rule: the per-rung level count is the
    largest uniform choice keeping the whole enumeration under
    ``max_enum_points``; 0 and E always enumerate, so pure-rung corners
    and the legacy axis endpoints are never pruned away).

    ``residency_step`` controls enumeration granularity for the residency
    axis; the default (``num_layers``) matches the balanced per-layer
    placement the N-bank MoE needs.
    """

    def __init__(self, cfg: ModelConfig,
                 hw: HardwareModel = HardwareModel(), *,
                 batch_size: int = 1, seed: int = 0,
                 residency_step: Optional[int] = None,
                 max_enum_points: int = 8192,
                 profile=None, ep: int = 1):
        if cfg.moe is None:
            raise ValueError(f"{cfg.arch_id}: the MoP frontier needs routed "
                             "experts (DESIGN.md §5)")
        ep = int(ep)
        if ep < 1:
            raise ValueError(f"ep must be >= 1, got {ep}")
        if ep > 1 and cfg.moe.num_experts % ep:
            raise ValueError(
                f"{cfg.arch_id}: {cfg.moe.num_experts} experts do not "
                f"split over ep={ep} devices (num_experts %% ep must be "
                "0 — pick an ep dividing the expert count)")
        self.cfg = cfg
        self.hw = hw
        self.batch_size = batch_size
        self.seed = seed
        self.residency_step = residency_step
        self.max_enum_points = max_enum_points
        #: EP shard count (DESIGN.md §16). ep=1 reproduces the
        #: single-device enumeration bit-for-bit (golden-fixture
        #: pinned); ep>1 rounds per-rung count levels to multiples of
        #: ep (bank shards must split evenly) and splits each residency
        #: level into a local slice (this device's HBM, budget-checked)
        #: and a PEER remainder priced at interconnect bandwidth.
        self.ep = ep
        #: optional SensitivityProfile (DESIGN.md §15): re-prices every
        #: enumerated plan's quality_proxy with the traffic-weighted
        #: per-expert objective, re-ranking the dominant set. None (or a
        #: uniform profile) keeps the legacy flat pricing bit-for-bit.
        self.profile = profile
        self.ladder = validate_ladder(cfg.mop.precision_ladder)
        layers = cfg.num_layers
        e = cfg.moe.num_experts
        total = layers * e
        step = residency_step or layers
        res_levels = sorted({*range(0, total, step), total})
        count_grids = self._count_grids(e, len(res_levels), max_enum_points)
        #: per-rung per-layer count levels actually enumerated (ascending
        #: rung order) — exposes the §11 pruning decision for inspection.
        self.count_levels: Dict[int, List[int]] = count_grids
        pts: List[FrontierPoint] = []
        for combo in self._count_combos(e, count_grids):
            counts = {b: c * layers
                      for b, c in zip(sorted(count_grids), combo)}
            nq = sum(counts.values())
            for r in res_levels:
                # EP residency split (DESIGN.md §16): a level of r
                # accelerator-resident experts shards ~evenly over ep
                # devices; this device holds ceil(r/ep) locally (the
                # max across ranks — conservative for the budget
                # check), the rest are PEER. ep=1: local=r, peer=0 —
                # the historical plan bit-for-bit.
                local = -(-r // ep) if r else 0
                peer = r - local
                plan = balanced_ladder_plan(
                    layers, e, counts, ladder=self.ladder,
                    group_size=cfg.mop.group_size,
                    seed=seed, resident_experts=local,
                    peer_experts=peer)
                qos = cost_model.estimate_qos(cfg, plan, hw, batch_size,
                                              profile)
                per_rung = tuple(total - nq if b >= 16 else counts[b]
                                 for b in self.ladder)
                pts.append(FrontierPoint(num_q_experts=nq,
                                         resident_experts=r,
                                         plan=plan, qos=qos,
                                         counts_per_rung=per_rung,
                                         peer_experts=peer))
        #: the full enumeration (kept for sweeps/plots); dominated points
        #: included.
        self.all_points: List[FrontierPoint] = pts
        #: the dominant set, ascending in predicted tokens/s — "adjacent"
        #: for the QoSController means neighbouring indices in this list.
        self.points: List[FrontierPoint] = sorted(
            self._prune(pts),
            key=lambda p: (p.qos.tokens_per_s, p.qos.quality_proxy,
                           p.qos.device_bytes, p.num_q_experts,
                           p.resident_experts))

    def _count_grids(self, e: int, n_res: int, max_enum_points: int
                     ) -> Dict[int, List[int]]:
        """Per-layer count levels per quantized rung (§11 pruning rule).

        One rung (binary ladder): the full 0..E axis — the legacy
        enumeration, never pruned. K >= 2 rungs: a uniform stride grid
        per rung, levels chosen as the largest count whose K-fold product
        times the residency levels stays under ``max_enum_points`` (the
        count-combo constraint ``sum <= E`` only shrinks it further);
        0 and E are always included.

        Under EP (DESIGN.md §16) every level must be a multiple of
        ``self.ep`` — mixed_moe shards each rung bank contiguously over
        the EP axis, so per-layer bank sizes that do not split evenly
        cannot dispatch. ep=1 keeps every grid unchanged."""
        qr = quantized_rungs(self.ladder)
        ep = self.ep
        if len(qr) == 1:
            return {qr[0]: list(range(0, e + 1, ep))}
        budget = max(max_enum_points // max(n_res, 1), 1)
        per_rung = max(2, int(budget ** (1.0 / len(qr))))
        if per_rung >= e + 1:
            levels = list(range(e + 1))
        else:
            stride = -(-e // (per_rung - 1))        # ceil
            levels = sorted({*range(0, e + 1, stride), e})
        if ep > 1:
            levels = sorted({lv - lv % ep for lv in levels} | {e})
        return {b: list(levels) for b in qr}

    @staticmethod
    def _count_combos(e: int, grids: Dict[int, List[int]]):
        """Jointly-feasible per-layer count vectors (sum <= E), iterated
        lexicographically in ascending-rung order — the binary ladder
        yields the legacy ascending-Num_E4 sequence."""
        rungs = sorted(grids)
        for combo in itertools.product(*(grids[b] for b in rungs)):
            if sum(combo) <= e:
                yield combo

    @staticmethod
    def _prune(pts: Sequence[FrontierPoint]) -> List[FrontierPoint]:
        out: List[FrontierPoint] = []
        for p in pts:
            if any(_dominates(q, p) for q in pts):
                continue
            # drop exact QoS duplicates (balanced rounding maps nearby
            # knob values to one plan) deterministically: keep the first
            # in (nq, resident) order.
            key = (p.qos.tokens_per_s, p.qos.quality_proxy,
                   p.qos.device_bytes)
            if any((q.qos.tokens_per_s, q.qos.quality_proxy,
                    q.qos.device_bytes) == key for q in out):
                continue
            out.append(p)
        return out

    def overlap_variant(self, efficiency: float) -> "ParetoFrontier":
        """Re-enumerate and re-rank THIS frontier's configuration space
        under the overlap-aware token time (DESIGN.md §12): identical
        axes/plans, the hardware model's ``overlap_efficiency`` replaced.
        Transfer-dominated points whose transfers hide under compute gain
        tokens/s, so membership of the dominant set can flip — points
        dominated under the additive model may become dominant (tested).
        ``efficiency=0.0`` returns a frontier bit-identical to the
        additive ranking."""
        hw = dataclasses.replace(self.hw,
                                 overlap_efficiency=float(efficiency))
        return ParetoFrontier(self.cfg, hw, batch_size=self.batch_size,
                              seed=self.seed,
                              residency_step=self.residency_step,
                              max_enum_points=self.max_enum_points,
                              profile=self.profile, ep=self.ep)

    def spec_variant(self, k: int, acceptance: float) -> "ParetoFrontier":
        """Re-enumerate and re-rank under the speculative token time
        (DESIGN.md §17): identical axes/plans, the hardware model's
        ``spec_k`` / ``spec_acceptance`` replaced. Every point's cycle
        becomes ``k * t_draft + t_token`` emitting ``(1 - a^(k+1)) /
        (1 - a)`` expected tokens, with ``t_draft`` the compute-only
        all-lowest-rung time — so plans whose serving rungs are far
        above the draft rung gain the most and the ranking can flip.
        ``acceptance`` should be a MEASURED rate (the engine's
        ``acceptance_rate`` metric feeding back through the
        QoSController). ``k=0`` returns a frontier bit-identical to the
        plain-decode ranking."""
        hw = dataclasses.replace(self.hw, spec_k=int(k),
                                 spec_acceptance=float(acceptance))
        return ParetoFrontier(self.cfg, hw, batch_size=self.batch_size,
                              seed=self.seed,
                              residency_step=self.residency_step,
                              max_enum_points=self.max_enum_points,
                              profile=self.profile, ep=self.ep)

    def profile_variant(self, profile) -> "ParetoFrontier":
        """Re-enumerate and re-rank under a (new) sensitivity profile
        (DESIGN.md §15): identical axes/plans, only the quality pricing
        changes. ``profile=None`` (or a uniform profile) returns a
        frontier bit-identical to the legacy flat-cost ranking."""
        return ParetoFrontier(self.cfg, self.hw,
                              batch_size=self.batch_size, seed=self.seed,
                              residency_step=self.residency_step,
                              max_enum_points=self.max_enum_points,
                              profile=profile, ep=self.ep)

    # -- queries -----------------------------------------------------------
    def feasible(self, target: QoSTarget) -> List[FrontierPoint]:
        """Frontier points satisfying the target's hard constraints,
        ascending in predicted tokens/s."""
        return [p for p in self.points if p.feasible_under(target)]

    def select(self, target: QoSTarget) -> FrontierPoint:
        """Resolve a declarative target to one frontier point.

        Among feasible points meeting ``min_tokens_per_s``: prefer quality
        (lowest quality_proxy), then the lowest device footprint — the
        deterministic tie-break of DESIGN.md §9. When no feasible point
        meets the throughput objective, fall back to the fastest feasible
        point (best effort — the controller keeps chasing from there).
        Raises :class:`InfeasibleTarget` when the hard constraints admit
        no point at all (e.g. budget below the non-expert floor).
        """
        cand = self.feasible(target)
        if not cand:
            floor = min(p.qos.device_bytes for p in self.points)
            raise InfeasibleTarget(
                f"no MoP configuration satisfies [{target.describe()}]: "
                f"smallest feasible footprint is {_fmt_bytes(floor)}")
        meeting = [p for p in cand
                   if target.min_tokens_per_s is None
                   or p.qos.tokens_per_s >= target.min_tokens_per_s]
        if meeting:
            return min(meeting, key=lambda p: (
                p.qos.quality_proxy, p.qos.device_bytes,
                -p.qos.tokens_per_s, p.num_q_experts, p.resident_experts))
        return min(cand, key=lambda p: (
            -p.qos.tokens_per_s, p.qos.quality_proxy, p.qos.device_bytes,
            p.num_q_experts, p.resident_experts))

    def neighbors(self, point: FrontierPoint, target: QoSTarget
                  ) -> tuple:
        """(slower, faster) adjacent feasible points (None at the ends) —
        the QoSController's walk steps."""
        feas = self.feasible(target)
        try:
            i = feas.index(point)
        except ValueError:
            return None, None
        slower = feas[i - 1] if i > 0 else None
        faster = feas[i + 1] if i + 1 < len(feas) else None
        return slower, faster

    def records(self) -> List[Dict]:
        """Bit-exact serialization of the dominant set, in frontier
        order — the golden-regression fixture format
        (tests/fixtures/, DESIGN.md §10.4). Floats are serialized as
        ``float.hex()`` so equality is BITWISE (a silent cost-model
        drift of one ulp fails the fixture), and each point carries a
        digest of its concrete plan arrays (quant + location + format),
        so precision/placement changes are caught even when the QoS
        estimate happens to coincide."""
        binary = len(quantized_rungs(self.ladder)) == 1
        out = []
        for p in self.points:
            h = hashlib.sha256()
            h.update(p.plan.quant.tobytes())
            h.update(p.plan.location.tobytes())
            if binary:
                # historical digest: the boolean mask + the scalar rung —
                # byte-identical to the pre-ladder fixture format.
                h.update(f"{p.plan.q_bits}:{p.plan.group_size}"
                         f":{p.plan.seed}".encode())
            else:
                h.update(p.plan.bits.tobytes())
                h.update(f"{p.plan.ladder}:{p.plan.group_size}"
                         f":{p.plan.seed}".encode())
            rec = {
                "num_q_experts": int(p.num_q_experts),
                "resident_experts": int(p.resident_experts),
                "tokens_per_s": float(p.qos.tokens_per_s).hex(),
                "quality_proxy": float(p.qos.quality_proxy).hex(),
                "device_bytes": int(p.qos.device_bytes),
                "plan_sha256": h.hexdigest(),
            }
            if not binary:
                rec["counts_per_rung"] = [int(c) for c in p.counts_per_rung]
                rec["ladder"] = list(self.ladder)
            if self.ep > 1:
                # EP-only keys (DESIGN.md §16): ep=1 records stay
                # byte-identical to the checked-in golden fixture.
                rec["ep"] = self.ep
                rec["peer_experts"] = int(p.peer_experts)
            out.append(rec)
        return out

    def best_per_quality_level(self, mem_budget_bytes: float
                               ) -> List[FrontierPoint]:
        """For each Num_E4 level, the max-residency point fitting the
        budget — the paper's Fig. 2/3 sweep axis (used by
        ``AdaptivePlanner.sweep`` and ``examples/pareto_explorer.py``)."""
        best = {}
        for p in self.all_points:
            if p.qos.device_bytes > mem_budget_bytes:
                continue
            cur = best.get(p.num_q_experts)
            if cur is None or p.resident_experts > cur.resident_experts:
                best[p.num_q_experts] = p
        return [best[k] for k in sorted(best)]
