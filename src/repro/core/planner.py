"""Adaptive Inference Partitioner & Planner (paper §3, Fig. 1).

Given a memory budget and a task preference ("throughput" | "quality"),
produce a :class:`PrecisionPlan`:

* throughput preference — bring as many experts on-device as possible.
  If the budget exceeds non-expert + all-quantized experts (at the
  ladder's LOWEST rung), eq. (1) converts the surplus into 16-bit
  experts:

      Num_E16 = floor((Mem - Size_NE - Num_E*Size_E4) / (3*Size_E4))

  (3*Size_E4 = Size_E16 - Size_E4 when Size_E16 = 4*Size_E4). Otherwise all
  experts are quantized and only a budget-sized subset is resident.

* quality preference — the caller picks the quantized counts directly:
  either the legacy ``num_q_experts`` scalar (all at the lowest rung)
  or ``counts`` — a {rung: global count} mapping over the ladder's
  quantized rungs (DESIGN.md §11); the planner derives residency from
  the leftover budget, cheapest rung first.

Reconfiguration between plans is incremental (precision_plan.reconfig_delta).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Literal, Mapping, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cost_model
from repro.core.precision_plan import (PrecisionPlan, balanced_ladder_plan,
                                       quantized_rungs, validate_ladder)

Preference = Literal["throughput", "quality"]

if False:  # typing-only, avoids a runtime cycle (pareto imports planner)
    from repro.core.pareto import ParetoFrontier  # noqa: F401


def num_e16_eq1(mem_bytes: float, size_ne: int, num_e: int,
                size_e4: int, size_e16: Optional[int] = None) -> int:
    """Paper equation (1), generalized to measured expert sizes (our int4
    expert carries group scales, so Size_E16 != exactly 4*Size_E4)."""
    if size_e16 is None:
        size_e16 = 4 * size_e4
    surplus = mem_bytes - size_ne - num_e * size_e4
    if surplus <= 0:
        return 0
    return min(num_e, int(surplus // (size_e16 - size_e4)))


@dataclasses.dataclass(frozen=True)
class PlanResult:
    plan: PrecisionPlan
    qos: cost_model.QoSEstimate
    preference: str
    mem_budget_bytes: float

    def summary(self) -> str:
        p, q = self.plan, self.qos
        return (f"[{self.preference}] E4={p.num_q_experts}/{p.bits.size} "
                f"resident={p.resident_fraction():.0%} "
                f"dev={q.device_bytes/2**30:.2f}GiB "
                f"tok/s={q.tokens_per_s:.2f} "
                f"ppl_proxy=x{q.quality_proxy:.3f}")


class AdaptivePlanner:
    """Stateful planner: re-plan on constraint change, emit reconfig deltas."""

    def __init__(self, cfg: ModelConfig,
                 hw: cost_model.HardwareModel = cost_model.HardwareModel(),
                 seed: int = 0, profile=None, ep: int = 1):
        if cfg.moe is None:
            raise ValueError(
                f"{cfg.arch_id}: MoP planning needs routed experts "
                "(DESIGN.md §5 Arch-applicability)")
        ep = int(ep)
        if ep < 1:
            raise ValueError(f"ep must be >= 1, got {ep}")
        if ep > 1 and cfg.moe.num_experts % ep:
            raise ValueError(
                f"{cfg.arch_id}: {cfg.moe.num_experts} experts do not "
                f"split over ep={ep} devices (num_experts %% ep must "
                "be 0)")
        self.cfg = cfg
        self.hw = hw
        self.seed = seed
        #: EP shard count (DESIGN.md §16): counts round to multiples of
        #: ep (bank shards must split evenly over the mesh) and the
        #: residency budget buys LOCAL experts — the other ep-1 shards
        #: mirror the purchase, so up to ep x the local capacity is
        #: accelerator-resident (the surplus rides the PEER tier). ep=1
        #: is the historical single-device planner bit-for-bit.
        self.ep = ep
        #: optional SensitivityProfile (DESIGN.md §15): data-driven
        #: quality pricing for plan()/frontier(). None = legacy flat cost.
        self.profile = profile
        self.ladder = validate_ladder(cfg.mop.precision_ladder)
        self.current: Optional[PlanResult] = None
        self._frontiers: dict = {}   # batch_size -> ParetoFrontier

    # -- sizes ------------------------------------------------------------
    def expert_bytes(self, rung: int) -> int:
        """One expert's byte size at ``rung`` (paper Size_E*)."""
        return self.cfg.expert_param_bytes(rung)

    @property
    def size_e4(self) -> int:
        """Size of the ladder's CHEAPEST rung (legacy name: with the
        default ladder the lowest rung is 4-bit)."""
        return self.cfg.expert_param_bytes(quantized_rungs(self.ladder)[0])

    @property
    def size_e16(self) -> int:
        return self.cfg.expert_param_bytes(16)

    @property
    def size_ne(self) -> int:
        return self.cfg.non_expert_bytes()

    @property
    def num_experts_total(self) -> int:
        return self.cfg.num_layers * self.cfg.moe.num_experts

    # -- planning ---------------------------------------------------------
    def plan(self, mem_budget_bytes: float, preference: Preference,
             num_q_experts: Optional[int] = None,
             batch_size: int = 1,
             counts: Optional[Mapping[int, int]] = None,
             resident_experts: Optional[int] = None,
             peer_experts: Optional[int] = None) -> PlanResult:
        """``resident_experts``/``peer_experts`` (EP apply path,
        DESIGN.md §16) pin the placement split directly — the engine
        passes a frontier point's exact (total resident, peer) pair so
        the applied plan is the point's plan bit-for-bit; ``None``
        (every single-device caller) derives residency from the budget
        as always."""
        if mem_budget_bytes < self.size_ne:
            # paper §3: non-expert layers always live on the accelerator in
            # 16-bit — below that floor no plan exists.
            raise ValueError(
                f"infeasible budget {mem_budget_bytes/2**20:.1f} MiB < "
                f"non-expert floor {self.size_ne/2**20:.1f} MiB")
        total = self.num_experts_total
        layers = self.cfg.num_layers
        low = quantized_rungs(self.ladder)[0]
        if preference == "throughput":
            if counts is not None:
                raise ValueError("throughput preference derives its own "
                                 "counts (eq. 1); pass counts with the "
                                 "quality preference")
            n16 = num_e16_eq1(mem_budget_bytes, self.size_ne, total,
                              self.size_e4, self.size_e16)
            # balanced split: floor per layer keeps the footprint <= budget
            # (each skipped promotion only frees memory)
            n16 = (n16 // layers) * layers
            counts = {low: total - n16}
        elif preference == "quality":
            if counts is None:
                if num_q_experts is None:
                    raise ValueError(
                        "quality preference needs num_q_experts or a "
                        "per-rung counts mapping (paper: user-provided "
                        "range; DESIGN.md §11)")
                counts = {low: int(num_q_experts)}
        else:
            raise ValueError(preference)
        # residency from the ACTUAL balanced counts
        counts = self._balance_counts(counts)
        if resident_experts is not None:
            # pinned placement (frontier apply path): total resident =
            # local + peer; balanced_ladder_plan takes the LOCAL count
            total_res = int(np.clip(resident_experts, 0, total))
            peer = int(np.clip(peer_experts or 0, 0, total_res))
            resident, peer = total_res - peer, peer
        elif self.ep > 1:
            # budget buys LOCAL residency; the other ep-1 shards hold
            # the same per-device share, reached via the PEER tier
            n_local = self._resident_budget(mem_budget_bytes, counts)
            total_res = min(total, n_local * self.ep)
            resident = -(-total_res // self.ep) if total_res else 0
            peer = total_res - resident
        else:
            resident = self._resident_budget(mem_budget_bytes, counts)
            peer = 0

        plan = balanced_ladder_plan(
            self.cfg.num_layers, self.cfg.moe.num_experts, counts,
            ladder=self.ladder, group_size=self.cfg.mop.group_size,
            seed=self.seed, resident_experts=resident,
            peer_experts=peer)
        qos = cost_model.estimate_qos(self.cfg, plan, self.hw, batch_size,
                                      self.profile)
        if qos.device_bytes > mem_budget_bytes * 1.001:
            raise RuntimeError(
                f"planner bug: footprint {qos.device_bytes} > budget")
        result = PlanResult(plan=plan, qos=qos, preference=preference,
                            mem_budget_bytes=mem_budget_bytes)
        return result

    def _balance_counts(self, counts: Mapping[int, int]) -> Dict[int, int]:
        """Round each rung's global count to a balanced per-layer multiple
        and clip the joint total to the expert grid (cheapest rung keeps
        priority on clipping, matching the assignment order). Under EP
        per-layer counts additionally round DOWN to multiples of
        ``self.ep`` so every rung bank splits evenly over the mesh
        (mixed_moe's dispatch invariant — DESIGN.md §16)."""
        layers = self.cfg.num_layers
        e = self.cfg.moe.num_experts
        out: Dict[int, int] = {}
        room = e
        for b in quantized_rungs(self.ladder):
            per_layer = int(round(int(counts.get(b, 0)) / layers))
            per_layer = min(max(per_layer, 0), room)
            per_layer -= per_layer % self.ep
            out[b] = per_layer * layers
            room -= per_layer
        return out

    def _resident_budget(self, mem_bytes: float,
                         counts: Mapping[int, int]) -> int:
        """How many experts fit on-device: cheapest rung first (the
        paper's priority rule generalized over the ladder)."""
        total = self.num_experts_total
        left = mem_bytes - self.size_ne
        if left <= 0:
            return 0
        resident = 0
        remaining = total
        for b in quantized_rungs(self.ladder):
            have = int(counts.get(b, 0))
            n = min(have, int(left // self.expert_bytes(b)))
            n = max(n, 0)
            resident += n
            left -= n * self.expert_bytes(b)
            remaining -= have
        n16 = min(remaining, max(0, int(left // self.size_e16)))
        return resident + n16

    def replan(self, mem_budget_bytes: float, preference: Preference,
               num_q_experts: Optional[int] = None, batch_size: int = 1,
               counts: Optional[Mapping[int, int]] = None,
               resident_experts: Optional[int] = None,
               peer_experts: Optional[int] = None):
        """Returns (PlanResult, delta|None). Keeps planner state."""
        from repro.core.precision_plan import (delta_cost_bytes,
                                               migrated_expert_keys,
                                               reconfig_delta)
        new = self.plan(mem_budget_bytes, preference, num_q_experts,
                        batch_size, counts=counts,
                        resident_experts=resident_experts,
                        peer_experts=peer_experts)
        delta = None
        if self.current is not None:
            delta = reconfig_delta(self.current.plan, new.plan)
            # the partial-reconfiguration working set: experts that
            # actually stream (each once), and the traffic they cost
            delta["migrated"] = migrated_expert_keys(delta, new.plan)
            delta["traffic_bytes"] = delta_cost_bytes(
                delta, self.cfg.expert_param_bytes, new.plan)
        self.current = new
        return new, delta

    def recalibrate(self, hw: cost_model.HardwareModel) -> None:
        """Swap the hardware model — e.g. after the serving engine
        measures its actual overlap efficiency (DESIGN.md §12) — and
        drop every cached frontier so future ``plan()``/``frontier()``
        calls rank under the new constants. The active plan is kept:
        recalibration changes predictions, not placements."""
        self.hw = hw
        self._frontiers.clear()

    def set_profile(self, profile) -> None:
        """Swap the sensitivity profile (DESIGN.md §15) — e.g. after an
        offline calibration pass or when the dynamic controller folds in
        fresh traffic stats — and drop cached frontiers so future
        rankings price quality per expert. The active plan is kept."""
        self.profile = profile
        self._frontiers.clear()

    def frontier(self, batch_size: int = 1) -> "ParetoFrontier":
        """The ParetoFrontier for this planner's (cfg, hw, seed) — built
        once per batch size and cached (DESIGN.md §9). Frontier plans are
        bit-identical to ``plan()`` output for the same knob values."""
        if batch_size not in self._frontiers:
            from repro.core.pareto import ParetoFrontier
            self._frontiers[batch_size] = ParetoFrontier(
                self.cfg, self.hw, batch_size=batch_size, seed=self.seed,
                profile=self.profile, ep=self.ep)
        return self._frontiers[batch_size]

    def sweep(self, mem_budget_bytes: float, batch_size: int = 1,
              points: Optional[int] = None):
        """Quality-mode sweep over the quantized-count levels — the
        paper's config space (Fig. 2/3 x-axes); returns list of
        PlanResult + Pareto indices.

        Rebased on :meth:`frontier`: one point per balanced quantized
        level, each at the max residency fitting the budget. ``points``
        is kept for backward compatibility and ignored (the balanced
        levels ARE the distinct plans the old dense sampling collapsed
        to)."""
        del points
        results = [
            PlanResult(plan=p.plan, qos=p.qos, preference="quality",
                       mem_budget_bytes=mem_budget_bytes)
            for p in self.frontier(batch_size)
            .best_per_quality_level(mem_budget_bytes)
        ]
        pts = [(r.qos.tokens_per_s, r.qos.quality_proxy) for r in results]
        return results, cost_model.pareto_frontier(pts)
