"""Adaptive Inference Partitioner & Planner (paper §3, Fig. 1).

Given a memory budget and a task preference ("throughput" | "quality"),
produce a :class:`PrecisionPlan`:

* throughput preference — bring as many experts on-device as possible.
  If the budget exceeds non-expert + all-4-bit experts, eq. (1) converts the
  surplus into 16-bit experts:

      Num_E16 = floor((Mem - Size_NE - Num_E*Size_E4) / (3*Size_E4))

  (3*Size_E4 = Size_E16 - Size_E4 when Size_E16 = 4*Size_E4). Otherwise all
  experts are 4-bit and only a budget-sized subset is resident.

* quality preference — the caller picks Num_E4 (0..Num_E) directly; the
  planner derives residency from the leftover budget, 4-bit experts first.

Reconfiguration between plans is incremental (precision_plan.reconfig_delta).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

from repro.configs.base import ModelConfig
from repro.core import cost_model
from repro.core.precision_plan import PrecisionPlan, balanced_random_plan

Preference = Literal["throughput", "quality"]

if False:  # typing-only, avoids a runtime cycle (pareto imports planner)
    from repro.core.pareto import ParetoFrontier  # noqa: F401


def num_e16_eq1(mem_bytes: float, size_ne: int, num_e: int,
                size_e4: int, size_e16: Optional[int] = None) -> int:
    """Paper equation (1), generalized to measured expert sizes (our int4
    expert carries group scales, so Size_E16 != exactly 4*Size_E4)."""
    if size_e16 is None:
        size_e16 = 4 * size_e4
    surplus = mem_bytes - size_ne - num_e * size_e4
    if surplus <= 0:
        return 0
    return min(num_e, int(surplus // (size_e16 - size_e4)))


@dataclasses.dataclass(frozen=True)
class PlanResult:
    plan: PrecisionPlan
    qos: cost_model.QoSEstimate
    preference: str
    mem_budget_bytes: float

    def summary(self) -> str:
        p, q = self.plan, self.qos
        return (f"[{self.preference}] E4={p.num_q_experts}/{p.quant.size} "
                f"resident={p.resident_fraction():.0%} "
                f"dev={q.device_bytes/2**30:.2f}GiB "
                f"tok/s={q.tokens_per_s:.2f} "
                f"ppl_proxy=x{q.quality_proxy:.3f}")


class AdaptivePlanner:
    """Stateful planner: re-plan on constraint change, emit reconfig deltas."""

    def __init__(self, cfg: ModelConfig,
                 hw: cost_model.HardwareModel = cost_model.HardwareModel(),
                 seed: int = 0):
        if cfg.moe is None:
            raise ValueError(
                f"{cfg.arch_id}: MoP planning needs routed experts "
                "(DESIGN.md §5 Arch-applicability)")
        self.cfg = cfg
        self.hw = hw
        self.seed = seed
        self.current: Optional[PlanResult] = None
        self._frontiers: dict = {}   # batch_size -> ParetoFrontier

    # -- sizes ------------------------------------------------------------
    @property
    def size_e4(self) -> int:
        return self.cfg.expert_param_bytes(self.cfg.mop.bits)

    @property
    def size_e16(self) -> int:
        return self.cfg.expert_param_bytes(16)

    @property
    def size_ne(self) -> int:
        return self.cfg.non_expert_bytes()

    @property
    def num_experts_total(self) -> int:
        return self.cfg.num_layers * self.cfg.moe.num_experts

    # -- planning ---------------------------------------------------------
    def plan(self, mem_budget_bytes: float, preference: Preference,
             num_q_experts: Optional[int] = None,
             batch_size: int = 1) -> PlanResult:
        if mem_budget_bytes < self.size_ne:
            # paper §3: non-expert layers always live on the accelerator in
            # 16-bit — below that floor no plan exists.
            raise ValueError(
                f"infeasible budget {mem_budget_bytes/2**20:.1f} MiB < "
                f"non-expert floor {self.size_ne/2**20:.1f} MiB")
        total = self.num_experts_total
        layers = self.cfg.num_layers
        if preference == "throughput":
            n16 = num_e16_eq1(mem_budget_bytes, self.size_ne, total,
                              self.size_e4, self.size_e16)
            # balanced split: floor per layer keeps the footprint <= budget
            # (each skipped promotion only frees memory)
            n16 = (n16 // layers) * layers
            nq = total - n16
        elif preference == "quality":
            if num_q_experts is None:
                raise ValueError("quality preference needs num_q_experts "
                                 "(paper: user-provided range)")
            nq = int(round(num_q_experts / layers)) * layers
            nq = min(max(nq, 0), total)
        else:
            raise ValueError(preference)
        # residency from the ACTUAL balanced count
        resident = self._resident_budget(mem_budget_bytes, nq)

        plan = balanced_random_plan(
            self.cfg.num_layers, self.cfg.moe.num_experts, nq,
            bits=self.cfg.mop.bits, group_size=self.cfg.mop.group_size,
            seed=self.seed, resident_experts=resident)
        qos = cost_model.estimate_qos(self.cfg, plan, self.hw, batch_size)
        if qos.device_bytes > mem_budget_bytes * 1.001:
            raise RuntimeError(
                f"planner bug: footprint {qos.device_bytes} > budget")
        result = PlanResult(plan=plan, qos=qos, preference=preference,
                            mem_budget_bytes=mem_budget_bytes)
        return result

    def _resident_budget(self, mem_bytes: float, num_q: int) -> int:
        """How many experts fit on-device: 4-bit first (paper priority)."""
        total = self.num_experts_total
        left = mem_bytes - self.size_ne
        if left <= 0:
            return 0
        n4 = min(num_q, int(left // self.size_e4))
        left -= n4 * self.size_e4
        n16 = min(total - num_q, max(0, int(left // self.size_e16)))
        return n4 + n16

    def replan(self, mem_budget_bytes: float, preference: Preference,
               num_q_experts: Optional[int] = None, batch_size: int = 1):
        """Returns (PlanResult, delta|None). Keeps planner state."""
        from repro.core.precision_plan import (delta_cost_bytes,
                                               migrated_expert_keys,
                                               reconfig_delta)
        new = self.plan(mem_budget_bytes, preference, num_q_experts,
                        batch_size)
        delta = None
        if self.current is not None:
            delta = reconfig_delta(self.current.plan, new.plan)
            # the partial-reconfiguration working set: experts that
            # actually stream (each once), and the traffic they cost
            delta["migrated"] = migrated_expert_keys(delta, new.plan)
            delta["traffic_bytes"] = delta_cost_bytes(
                delta, self.size_e4, self.size_e16, new.plan)
        self.current = new
        return new, delta

    def frontier(self, batch_size: int = 1) -> "ParetoFrontier":
        """The ParetoFrontier for this planner's (cfg, hw, seed) — built
        once per batch size and cached (DESIGN.md §9). Frontier plans are
        bit-identical to ``plan()`` output for the same knob values."""
        if batch_size not in self._frontiers:
            from repro.core.pareto import ParetoFrontier
            self._frontiers[batch_size] = ParetoFrontier(
                self.cfg, self.hw, batch_size=batch_size, seed=self.seed)
        return self._frontiers[batch_size]

    def sweep(self, mem_budget_bytes: float, batch_size: int = 1,
              points: Optional[int] = None):
        """Quality-mode sweep over Num_E4 — the paper's config space
        (Fig. 2/3 x-axes); returns list of PlanResult + Pareto indices.

        Rebased on :meth:`frontier`: one point per balanced Num_E4 level,
        each at the max residency fitting the budget. ``points`` is kept
        for backward compatibility and ignored (the balanced levels ARE
        the distinct plans the old dense sampling collapsed to)."""
        del points
        results = [
            PlanResult(plan=p.plan, qos=p.qos, preference="quality",
                       mem_budget_bytes=mem_budget_bytes)
            for p in self.frontier(batch_size)
            .best_per_quality_level(mem_budget_bytes)
        ]
        pts = [(r.qos.tokens_per_s, r.qos.quality_proxy) for r in results]
        return results, cost_model.pareto_frontier(pts)
