"""Group-wise weight quantization (the paper's precision substrate).

The paper uses bitsandbytes NF4 on GPU. On TPU we use *symmetric group-wise
int4/int8* (DESIGN.md §2): along the reduction dim K, groups of ``group_size``
share one bf16 absmax scale. int4 values live in [-8, 7] and are packed two
nibbles per byte along K (even K index = low nibble). Dequantization is a
vector multiply that fuses into the Pallas matmul kernel
(``repro.kernels.q4_matmul``).

An NF4 codebook path is kept for quality comparison in the reference/bench
code — it is gather-based and deliberately not used in the compute path.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# NF4 quantile codebook (bitsandbytes), for the quality-comparison path only.
NF4_CODE = np.array(
    [-1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
     -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
     0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
     0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
     0.7229568362236023, 1.0], dtype=np.float32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    """Quantized weight: packed codes + per-group scales.

    For ``bits=4``: ``q`` has shape ``(..., K//2, N)`` uint8 (two nibbles
    along K). For ``bits=8``: ``q`` has shape ``(..., K, N)`` int8.
    ``scales`` has shape ``(..., K//group_size, N)``.
    """
    q: jax.Array
    scales: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True), default=4)
    group_size: int = dataclasses.field(metadata=dict(static=True), default=64)

    @property
    def shape(self) -> Tuple[int, ...]:
        *b, kp, n = self.q.shape
        k = kp * 2 if self.bits == 4 else kp
        return (*b, k, n)

    @property
    def dtype(self):
        return jnp.bfloat16

    def nbytes(self) -> int:
        return self.q.size * self.q.dtype.itemsize + \
            self.scales.size * self.scales.dtype.itemsize


def pack_int4(q: jax.Array) -> jax.Array:
    """(..., K, N) int8 in [-8,7] -> (..., K//2, N) uint8."""
    if q.shape[-2] % 2:
        raise ValueError(f"K must be even, got {q.shape}")
    u = (q + 8).astype(jnp.uint8)
    lo, hi = u[..., 0::2, :], u[..., 1::2, :]
    return (hi << 4) | lo


def unpack_int4(packed: jax.Array) -> jax.Array:
    """(..., K//2, N) uint8 -> (..., K, N) int8 in [-8,7]."""
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    *b, kp, n = packed.shape
    # interleave along K: (..., K//2, 2, N) -> (..., K, N)
    return jnp.stack([lo, hi], axis=-2).reshape(*b, 2 * kp, n)


def quantize(w: jax.Array, bits: int = 4, group_size: int = 64) -> QTensor:
    """Symmetric absmax group-wise quantization along dim -2 (reduction K)."""
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    *b, k, n = w.shape
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    wf = w.astype(jnp.float32).reshape(*b, k // group_size, group_size, n)
    qmax = 7.0 if bits == 4 else 127.0
    absmax = jnp.max(jnp.abs(wf), axis=-2)                     # (..., K/G, N)
    scales = (absmax / qmax).astype(jnp.float32)
    inv = jnp.where(scales > 0, 1.0 / scales, 0.0)
    q = jnp.clip(jnp.round(wf * inv[..., None, :]), -qmax - 1, qmax)
    q = q.astype(jnp.int8).reshape(*b, k, n)
    if bits == 4:
        q = pack_int4(q)
    return QTensor(q=q, scales=scales.astype(jnp.bfloat16),
                   bits=bits, group_size=group_size)


def dequantize(qt: QTensor) -> jax.Array:
    """QTensor -> bf16 weight (..., K, N). Pure-jnp oracle for the kernel."""
    q = unpack_int4(qt.q) if qt.bits == 4 else qt.q
    *b, k, n = q.shape
    g = qt.group_size
    wf = q.astype(jnp.float32).reshape(*b, k // g, g, n)
    wf = wf * qt.scales.astype(jnp.float32)[..., None, :]
    return wf.reshape(*b, k, n).astype(jnp.bfloat16)


def quantize_nf4(w: jax.Array, group_size: int = 64) -> Tuple[jax.Array, jax.Array]:
    """NF4 codebook quantization (quality-comparison path, not compute path).

    Returns (codes uint8 (..., K, N), absmax (..., K/G, N))."""
    *b, k, n = w.shape
    wf = w.astype(jnp.float32).reshape(*b, k // group_size, group_size, n)
    absmax = jnp.max(jnp.abs(wf), axis=-2) + 1e-12
    norm = wf / absmax[..., None, :]
    code = jnp.asarray(NF4_CODE)
    idx = jnp.argmin(jnp.abs(norm[..., None] - code), axis=-1)
    return idx.reshape(*b, k, n).astype(jnp.uint8), absmax


def dequantize_nf4(codes: jax.Array, absmax: jax.Array,
                   group_size: int = 64) -> jax.Array:
    *b, k, n = codes.shape
    code = jnp.asarray(NF4_CODE)
    wf = code[codes.astype(jnp.int32)].reshape(*b, k // group_size, group_size, n)
    return (wf * absmax[..., None, :]).reshape(*b, k, n).astype(jnp.bfloat16)


def quantization_rmse(w: jax.Array, bits: int = 4, group_size: int = 64,
                      nf4: bool = False) -> float:
    """Relative RMSE of one quantize/dequantize round trip."""
    if nf4:
        deq = dequantize_nf4(*quantize_nf4(w, group_size), group_size)
    else:
        deq = dequantize(quantize(w, bits, group_size))
    err = jnp.sqrt(jnp.mean((w.astype(jnp.float32)
                             - deq.astype(jnp.float32)) ** 2))
    return float(err / (jnp.sqrt(jnp.mean(w.astype(jnp.float32) ** 2)) + 1e-12))


# ----- whole-model homogeneous quantization (paper's Table-1 baselines) -----

def quantize_tree(params, bits: int, group_size: int = 64,
                  min_dims: int = 2, min_k: int = 128):
    """Quantize every eligible weight matrix in a pytree (homogeneous
    baseline: '4-bit everything' / '8-bit everything' rows of Table 1).

    Arrays with fewer than ``min_dims`` dims, a reduction dim smaller than
    ``min_k``, or K not divisible by the group are left untouched (norm
    scales, biases, small heads)."""
    def _q(x):
        if (not isinstance(x, jax.Array) and not isinstance(x, np.ndarray)):
            return x
        if x.ndim < min_dims or x.shape[-2] < min_k or \
                x.shape[-2] % group_size:
            return x
        return quantize(jnp.asarray(x), bits, group_size)
    return jax.tree_util.tree_map(_q, params)


def dequantize_tree(params):
    def _dq(x):
        return dequantize(x) if isinstance(x, QTensor) else x
    return jax.tree_util.tree_map(
        _dq, params, is_leaf=lambda x: isinstance(x, QTensor))


def tree_nbytes(params) -> int:
    """Model size in bytes, QTensor-aware (paper's Model Size column)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes()
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
