"""The paper's contribution: Mixture of Experts with Mixture of Precisions.

Layers:
  quantization   — int4/int8 group-wise QTensor + pack/unpack + tree quant
  precision_plan — per-expert {bits, placement} ladder table (balanced-random)
  planner        — eq.(1) partitioner, budget->plan, incremental reconfig
  cost_model     — analytic tokens/s + quality proxy (Fig. 3 model)
  pareto         — declarative QoS targets over the config-space frontier
  expert_cache   — LRU device cache + swap space (+ speculative prefetch)
  mixed_moe      — N-bank (int4|int8|bf16) MoE layer, EP/TP dispatch
"""
from repro.core.quantization import (  # noqa: F401
    QTensor, dequantize, dequantize_tree, pack_int4, quantize, quantize_tree,
    tree_nbytes, unpack_int4,
)
from repro.core.precision_plan import (  # noqa: F401
    DEFAULT_LADDER, DEVICE, HOST, PrecisionPlan, balanced_ladder_plan,
    balanced_random_plan, quantized_rungs, reconfig_delta, validate_ladder,
)
from repro.core.planner import AdaptivePlanner, PlanResult, num_e16_eq1  # noqa: F401
from repro.core.cost_model import (  # noqa: F401
    HardwareModel, QoSEstimate, estimate_qos, pareto_frontier,
)
from repro.core.pareto import (  # noqa: F401
    FrontierPoint, InfeasibleTarget, ParetoFrontier, QoSTarget,
)
from repro.core.expert_cache import ExpertCache, PrefetchingExpertCache  # noqa: F401
