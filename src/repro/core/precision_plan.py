"""Per-expert precision & placement table (the paper's Fig. 1 state),
generalized to a PRECISION LADDER (DESIGN.md §11).

The paper keeps, for every expert, two attributes:
  * precision — originally boolean (4-bit vs 16-bit); here an explicit
    per-expert bit-width drawn from a declared *ladder* (descending tuple
    of rungs, default ``(16, 4)``; extended deployments use ``(16, 8, 4)``
    — MxMoE-style per-expert mixed precision as a serving knob);
  * location — on accelerator vs host.

Assignment of the precision attribute is random — the paper argues MoE
experts have uniform access frequency, so the choice of *which* experts
land on a rung does not matter. We use **balanced-random** (same per-rung
count per layer, random within a layer) so a scanned layer stack keeps
static bank shapes.

Backward compatibility is part of the API contract: with the binary
ladder ``(16, 4)`` every plan is bit-identical to the historical boolean
encoding — ``quant``/``num_q_experts``/``bank_sizes()`` survive as
derived views over ``bits == 4`` and the rng consumption of
:func:`balanced_ladder_plan` exactly reproduces the legacy
:func:`balanced_random_plan` stream (tests/test_ladder.py pins this
against the checked-in frontier golden fixture).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

#: placement tiers (DESIGN.md §16): LOCAL accelerator HBM, host DRAM
#: behind the PCIe link, or a PEER device's HBM reached via the EP
#: all2all at inter-device bandwidth. Single-device plans never contain
#: PEER, so the historical two-tier encoding is preserved byte-for-byte.
DEVICE, HOST, PEER = 0, 1, 2

#: rungs the quantization substrate implements (DESIGN.md §2): packed
#: int4 / int8 group-wise symmetric, plus the bf16 identity rung.
SUPPORTED_RUNGS = (4, 8, 16)
DEFAULT_LADDER = (16, 4)


def validate_ladder(ladder: Sequence[int]) -> Tuple[int, ...]:
    """A ladder is a strictly DESCENDING tuple of supported rungs that
    contains the 16-bit rung (non-expert weights and the f16 bank are
    bf16; an all-quantized plan is expressed through the counts, not by
    removing the rung)."""
    lad = tuple(int(b) for b in ladder)
    if len(lad) < 2:
        raise ValueError(f"ladder needs >= 2 rungs, got {lad}")
    if any(b not in SUPPORTED_RUNGS for b in lad):
        raise ValueError(f"ladder {lad}: rungs must be in {SUPPORTED_RUNGS}")
    if list(lad) != sorted(set(lad), reverse=True):
        raise ValueError(f"ladder {lad} must be strictly descending")
    if lad[0] != 16:
        raise ValueError(f"ladder {lad} must contain the 16-bit rung")
    return lad


def quantized_rungs(ladder: Sequence[int]) -> Tuple[int, ...]:
    """The ladder's sub-16-bit rungs, ascending (cheapest first — the
    bank order and the residency-priority order)."""
    return tuple(sorted(b for b in ladder if b < 16))


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """``bits[L, E]``: per-expert bit-width (a ladder rung).
    ``location[L, E]``: DEVICE or HOST."""
    bits: np.ndarray
    location: np.ndarray
    ladder: Tuple[int, ...] = DEFAULT_LADDER
    group_size: int = 64
    seed: int = 0

    @property
    def num_layers(self) -> int:
        return self.bits.shape[0]

    @property
    def num_experts(self) -> int:
        return self.bits.shape[1]

    # -- legacy boolean views (binary-ladder compatible) -------------------
    @property
    def quant(self) -> np.ndarray:
        """[L, E] bool: True = quantized (any sub-16-bit rung). With the
        binary ladder this IS the historical ``quant`` array bit-for-bit."""
        return self.bits < 16

    @property
    def num_q_experts(self) -> int:
        """Global count of quantized experts (the paper's Num_E4 for the
        binary ladder)."""
        return int((self.bits < 16).sum())

    @property
    def num_q_per_layer(self) -> int:
        return int((self.bits[0] < 16).sum())

    @property
    def q_bits(self) -> int:
        """The single quantized rung of a binary ladder (legacy scalar
        ``plan.bits``); raises on multi-rung ladders — callers that can
        see those must consult ``bits[l, e]`` per expert."""
        rungs = quantized_rungs(self.ladder)
        if len(rungs) != 1:
            raise ValueError(
                f"plan has a multi-rung ladder {self.ladder}; per-expert "
                "bit-widths live in plan.bits[l, e]")
        return rungs[0]

    # -- rung-indexed views -------------------------------------------------
    def rung_counts(self) -> Dict[int, int]:
        """{rung: global expert count} over the full ladder."""
        return {b: int((self.bits == b).sum()) for b in self.ladder}

    def rung_counts_per_layer(self) -> Dict[int, int]:
        """{rung: per-layer count} (equal across layers by construction)."""
        return {b: int((self.bits[0] == b).sum()) for b in self.ladder}

    def resident_fraction(self) -> float:
        return float((self.location == DEVICE).mean())

    def peer_fraction(self) -> float:
        """Fraction of experts resident on PEER devices (EP shards
        reached via all2all — DESIGN.md §16). 0.0 for single-device
        plans."""
        return float((self.location == PEER).mean())

    def placement_counts(self) -> Dict[str, int]:
        """{tier name: expert count} over the three placement tiers."""
        return {"device": int((self.location == DEVICE).sum()),
                "peer": int((self.location == PEER).sum()),
                "host": int((self.location == HOST).sum())}

    def device_assignment(self, ep: int) -> np.ndarray:
        """[L, E] owning EP rank of every expert under ``ep``-way expert
        parallelism — derived, not stored: mixed_moe shards each rung
        bank contiguously over the EP axis (``_local_slot``: within bank
        b of per-layer total tot_b, rank r owns bank slots
        [r*tot_b/ep, (r+1)*tot_b/ep)), so the assignment is a pure
        function of (bits, ep). Raises when a bank does not split
        evenly — the same constraint ``moe_apply`` enforces at dispatch
        time (the planner rounds per-layer counts to multiples of ep)."""
        ep = int(ep)
        if ep < 1:
            raise ValueError(f"ep must be >= 1, got {ep}")
        sizes = self.bank_sizes()
        if any(tot % ep for tot in sizes):
            raise ValueError(
                f"EP banks must split evenly: per-layer bank sizes "
                f"{sizes} over {ep} shards (planner rounds per-layer "
                "counts)")
        ranks = np.empty(self.bits.shape, dtype=np.int32)
        order = self.expert_order()
        for l in range(self.num_layers):
            slot_rank = np.concatenate([
                np.repeat(np.arange(ep, dtype=np.int32), tot // ep)
                for tot in sizes if tot])
            ranks[l, order[l]] = slot_rank
        return ranks

    def bank_sizes(self) -> Tuple[int, ...]:
        """Per-layer bank sizes in ASCENDING-bits bank order — static
        shapes for the N-bank MoE. Binary ladder: ``(E4, E16)``."""
        row = self.bits[0]
        return tuple(int((row == b).sum()) for b in sorted(self.ladder))

    def expert_order(self) -> np.ndarray:
        """[L, E] permutation: lowest-precision experts first, ascending
        through the ladder (binary: 4-bit first, then 16-bit — unchanged).

        The N-bank MoE stores experts in this order; the router output is
        permuted accordingly so routing semantics are unchanged."""
        order = np.empty(self.bits.shape, dtype=np.int32)
        rungs = sorted(self.ladder)
        for l in range(self.num_layers):
            order[l] = np.concatenate(
                [np.where(self.bits[l] == b)[0] for b in rungs])
        return order


def _normalize_counts(counts: Mapping[int, int],
                      ladder: Tuple[int, ...]) -> Dict[int, int]:
    """Counts for the QUANTIZED rungs only; unknown rungs rejected."""
    out = {}
    qr = quantized_rungs(ladder)
    for b, c in counts.items():
        b = int(b)
        if b >= 16:
            continue                     # 16 is the remainder, never counted
        if b not in qr:
            raise ValueError(f"count for rung {b} not in ladder {ladder}")
        out[b] = int(c)
    return {b: out.get(b, 0) for b in qr}


def balanced_ladder_plan(num_layers: int, num_experts: int,
                         counts: Mapping[int, int], *,
                         ladder: Sequence[int] = DEFAULT_LADDER,
                         group_size: int = 64, seed: int = 0,
                         resident_experts: Optional[int] = None,
                         peer_experts: int = 0
                         ) -> PrecisionPlan:
    """Paper §3 assignment generalized to the ladder, balanced per layer.

    ``counts`` maps each quantized rung to its GLOBAL expert count (each
    in [0, L*E], jointly at most L*E); every layer gets
    ``round(count / L)`` experts of that rung (clipped so a balanced
    split exists), assigned from ONE random permutation per layer —
    lowest rung takes the first slice, and so on ascending; the
    remainder stays 16-bit. With the binary ladder this consumes the rng
    exactly like the legacy boolean assignment (bit-identical plans).

    ``resident_experts`` (global count) fills the location attribute with
    the paper's priority rule generalized to the ladder: cheapest rung
    first (lower bits = cheaper to keep resident -> higher hit rate),
    round-robin over layers so every layer keeps a similar hit rate.

    ``peer_experts`` (global count, EP deployments — DESIGN.md §16)
    extends the same priority order past the local-resident slice: the
    next ``peer_experts`` entries land on PEER devices (accelerator HBM
    reached via all2all) before the remainder falls to HOST. The rng
    stream is untouched (the priority order is built either way), so
    ``peer_experts=0`` plans are bit-identical to the historical
    two-tier encoding.
    """
    lad = validate_ladder(ladder)
    qr = quantized_rungs(lad)
    counts = _normalize_counts(counts, lad)
    total = num_layers * num_experts
    gsum = sum(counts.values())
    if any(c < 0 for c in counts.values()) or gsum > total:
        raise ValueError(f"counts {counts} not in [0,{total}] jointly")
    rng = np.random.default_rng(seed)
    per_layer: Dict[int, int] = {}
    room = num_experts
    for b in qr:
        c = int(round(counts[b] / num_layers))
        c = min(c, room)
        per_layer[b] = c
        room -= c
    bits = np.full((num_layers, num_experts), 16, dtype=np.int16)
    for l in range(num_layers):
        perm = rng.permutation(num_experts)
        off = 0
        for b in qr:
            bits[l, perm[off:off + per_layer[b]]] = b
            off += per_layer[b]

    location = np.full((num_layers, num_experts), DEVICE, dtype=np.int8)
    if peer_experts and resident_experts is None:
        raise ValueError("peer_experts needs an explicit resident_experts "
                         "count (the priority order assigns LOCAL first)")
    if resident_experts is not None:
        resident_experts = int(np.clip(resident_experts, 0, total))
        peer_experts = int(np.clip(peer_experts, 0,
                                   total - resident_experts))
        location[:] = HOST
        # priority: cheapest rung first (paper §3 generalized), round-robin
        # over layers so every layer keeps a similar hit rate.
        order: List[Tuple[int, int]] = []
        for phase in (*qr, 16):
            cols: List[List[Tuple[int, int]]] = []
            for l in range(num_layers):
                es = [(l, e) for e in np.where(bits[l] == phase)[0]]
                rng.shuffle(es)
                cols.append(es)
            for i in range(max((len(c) for c in cols), default=0)):
                for c in cols:
                    if i < len(c):
                        order.append(c[i])
        for (l, e) in order[:resident_experts]:
            location[l, e] = DEVICE
        for (l, e) in order[resident_experts:resident_experts
                            + peer_experts]:
            location[l, e] = PEER
    return PrecisionPlan(bits=bits, location=location, ladder=lad,
                         group_size=group_size, seed=seed)


def balanced_random_plan(num_layers: int, num_experts: int,
                         num_q_experts: int, *, bits: int = 4,
                         group_size: int = 64, seed: int = 0,
                         resident_experts: Optional[int] = None
                         ) -> PrecisionPlan:
    """Legacy binary spelling: ``num_q_experts`` experts at the single
    quantized rung ``bits``, the rest 16-bit (paper §3). Thin wrapper
    over :func:`balanced_ladder_plan` with the ladder ``(16, bits)`` —
    plans are bit-identical to the pre-ladder encoding."""
    total = num_layers * num_experts
    if not 0 <= num_q_experts <= total:
        raise ValueError(f"num_q_experts {num_q_experts} not in [0,{total}]")
    return balanced_ladder_plan(
        num_layers, num_experts, {bits: num_q_experts},
        ladder=(16, int(bits)), group_size=group_size, seed=seed,
        resident_experts=resident_experts)


def reconfig_delta(old: PrecisionPlan, new: PrecisionPlan):
    """Minimal reconfiguration ops between two plans (paper §3: partial
    reconfiguration instead of a full reload).

    Returns dict with index arrays of experts to (re)quantize (bit-width
    DROPS, incl. 8->4 demotions), dequantize/promote (bit-width RISES,
    incl. 4->8 promotions), upload (host->accelerator: DEVICE or PEER),
    evict (accelerator->host) and rebalance (DEVICE<->PEER moves — the
    expert stays in accelerator HBM and travels over the interconnect,
    never the host link; single-device plans never produce any)."""
    if old.bits.shape != new.bits.shape:
        raise ValueError("plans must describe the same model")
    old_acc = old.location != HOST
    new_acc = new.location != HOST
    return {
        "to_quantize": np.argwhere(old.bits > new.bits),
        "to_dequantize": np.argwhere(old.bits < new.bits),
        "to_upload": np.argwhere(~old_acc & new_acc),
        "to_evict": np.argwhere(old_acc & ~new_acc),
        "to_rebalance": np.argwhere(old_acc & new_acc
                                    & (old.location != new.location)),
    }


def migrated_expert_keys(delta, new: PrecisionPlan) -> List[Tuple[int, int]]:
    """The (layer, expert) set a PARTIAL reconfiguration actually touches
    with host<->device traffic: uploads plus format flips (any rung
    change) of device-resident experts — each expert counted ONCE even
    when it both moves and flips format. Everything else stays in place
    (the paper's partial-reconfiguration claim; the multi-tenant
    migration report asserts against exactly this set, DESIGN.md §10.3)."""
    keys = {(int(l), int(e)) for (l, e) in delta["to_upload"]}
    for field in ("to_quantize", "to_dequantize"):
        for (l, e) in delta[field]:
            if new.location[l, e] != HOST:
                keys.add((int(l), int(e)))
    return sorted(keys)


def delta_cost_bytes(delta, expert_bytes, new: PrecisionPlan):
    """Host->device traffic a reconfig needs (downtime estimator): each
    migrated expert streams once, in its NEW format.

    ``expert_bytes`` maps a rung (bit-width) to one expert's byte size —
    usually ``cfg.expert_param_bytes``."""
    up = 0
    for (l, e) in migrated_expert_keys(delta, new):
        up += expert_bytes(int(new.bits[l, e]))
    return int(up)
