"""Per-expert precision & placement table (the paper's Fig. 1 state).

The paper keeps, for every expert, two boolean attributes:
  * quantized?  (4-bit vs 16-bit)
  * location    (on accelerator vs host)

Assignment of the quantization attribute is random — the paper argues MoE
experts have uniform access frequency, so the choice of *which* experts to
quantize does not matter. We use **balanced-random** (same #4-bit experts per
layer, random within a layer) so a scanned layer stack keeps static bank
shapes; tests/test_precision_plan.py checks the statistical equivalence.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

DEVICE, HOST = 0, 1


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """quant[L, E]: True = 4-bit. location[L, E]: DEVICE or HOST."""
    quant: np.ndarray
    location: np.ndarray
    bits: int = 4
    group_size: int = 64
    seed: int = 0

    @property
    def num_layers(self) -> int:
        return self.quant.shape[0]

    @property
    def num_experts(self) -> int:
        return self.quant.shape[1]

    @property
    def num_q_experts(self) -> int:
        return int(self.quant.sum())

    @property
    def num_q_per_layer(self) -> int:
        return int(self.quant[0].sum())

    def resident_fraction(self) -> float:
        return float((self.location == DEVICE).mean())

    def bank_sizes(self) -> Tuple[int, int]:
        """(E4, E16) per layer — static shapes for the dual-bank MoE."""
        e4 = self.num_q_per_layer
        return e4, self.num_experts - e4

    def expert_order(self) -> np.ndarray:
        """[L, E] permutation: 4-bit experts first, then 16-bit.

        The dual-bank MoE stores experts in this order; the router output is
        permuted accordingly so routing semantics are unchanged."""
        order = np.empty_like(self.quant, dtype=np.int32)
        for l in range(self.num_layers):
            q = np.where(self.quant[l])[0]
            f = np.where(~self.quant[l])[0]
            order[l] = np.concatenate([q, f])
        return order


def balanced_random_plan(num_layers: int, num_experts: int,
                         num_q_experts: int, *, bits: int = 4,
                         group_size: int = 64, seed: int = 0,
                         resident_experts: Optional[int] = None
                         ) -> PrecisionPlan:
    """Paper §3 assignment, balanced per layer.

    ``num_q_experts`` is the global Num_E4 in [0, L*E]; each layer gets
    ``round(num_q_experts / L)`` 4-bit experts (clipped so the global count
    is met as closely as a balanced split allows).

    ``resident_experts`` (global count) fills the location attribute with the
    paper's priority rule: 4-bit experts are placed on-device first (cheaper
    to keep resident -> higher hit rate), then 16-bit ones.
    """
    total = num_layers * num_experts
    if not 0 <= num_q_experts <= total:
        raise ValueError(f"num_q_experts {num_q_experts} not in [0,{total}]")
    rng = np.random.default_rng(seed)
    per_layer = int(round(num_q_experts / num_layers))
    per_layer = min(per_layer, num_experts)
    quant = np.zeros((num_layers, num_experts), dtype=bool)
    for l in range(num_layers):
        idx = rng.permutation(num_experts)[:per_layer]
        quant[l, idx] = True

    location = np.full((num_layers, num_experts), DEVICE, dtype=np.int8)
    if resident_experts is not None:
        resident_experts = int(np.clip(resident_experts, 0, total))
        location[:] = HOST
        # priority: quantized first (paper §3), round-robin over layers so
        # every layer keeps a similar hit rate.
        order: List[Tuple[int, int]] = []
        for phase in (True, False):
            cols: List[List[Tuple[int, int]]] = []
            for l in range(num_layers):
                es = [(l, e) for e in np.where(quant[l] == phase)[0]]
                rng.shuffle(es)
                cols.append(es)
            for i in range(max((len(c) for c in cols), default=0)):
                for c in cols:
                    if i < len(c):
                        order.append(c[i])
        for (l, e) in order[:resident_experts]:
            location[l, e] = DEVICE
    return PrecisionPlan(quant=quant, location=location, bits=bits,
                         group_size=group_size, seed=seed)


def reconfig_delta(old: PrecisionPlan, new: PrecisionPlan):
    """Minimal reconfiguration ops between two plans (paper §3: partial
    reconfiguration instead of a full reload).

    Returns dict with index arrays of experts to (re)quantize, dequantize,
    upload (host->device) and evict (device->host)."""
    if old.quant.shape != new.quant.shape:
        raise ValueError("plans must describe the same model")
    return {
        "to_quantize": np.argwhere(~old.quant & new.quant),
        "to_dequantize": np.argwhere(old.quant & ~new.quant),
        "to_upload": np.argwhere((old.location == HOST)
                                 & (new.location == DEVICE)),
        "to_evict": np.argwhere((old.location == DEVICE)
                                & (new.location == HOST)),
    }


def migrated_expert_keys(delta, new: PrecisionPlan) -> List[Tuple[int, int]]:
    """The (layer, expert) set a PARTIAL reconfiguration actually touches
    with host<->device traffic: uploads plus format flips of
    device-resident experts — each expert counted ONCE even when it both
    moves and flips format. Everything else stays in place (the paper's
    partial-reconfiguration claim; the multi-tenant migration report
    asserts against exactly this set, DESIGN.md §10.3)."""
    keys = {(int(l), int(e)) for (l, e) in delta["to_upload"]}
    for field in ("to_quantize", "to_dequantize"):
        for (l, e) in delta[field]:
            if new.location[l, e] == DEVICE:
                keys.add((int(l), int(e)))
    return sorted(keys)


def delta_cost_bytes(delta, size_e4: int, size_e16: int, new: PrecisionPlan):
    """Host->device traffic a reconfig needs (downtime estimator): each
    migrated expert streams once, in its NEW format."""
    up = 0
    for (l, e) in migrated_expert_keys(delta, new):
        up += size_e4 if new.quant[l, e] else size_e16
    return int(up)
