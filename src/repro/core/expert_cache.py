"""Device-resident expert cache with LRU replacement + swap space (paper §3).

The serving engine keeps the master copy of every expert on the host (numpy)
and a bounded device cache keyed by (layer, expert). On an expert miss the
weight is staged through a reusable swap buffer (``jax.device_put``) — the
TPU analogue of the paper's pinned CPU<->GPU swap space. Hits/misses and
transferred bytes feed the serving metrics and validate the cost model.

Multi-tenant serving (DESIGN.md §10) shares ONE swap space between N
engines. Raw ``(layer, expert)`` keys would collide across tenants (tenant
A's ``(0, 3)`` is a different weight blob than tenant B's), so the shared
cache is accessed through :meth:`ExpertCache.scoped` views: a
:class:`ScopedExpertCache` namespaces every key with an explicit owner
field, keeps per-owner hit/miss/eviction accounting (the parent's LRU and
byte budget stay GLOBAL — one tenant's misses may evict another tenant's
swap entries, and the eviction is credited to the owner who lost the
entry), and routes misses to the owner's own host loader.

Asynchronous staging (DESIGN.md §12) moves transfers OFF the decode
critical path: :class:`AsyncExpertCache` runs a small transfer worker
pool behind the same interface — ``prefetch``/``hint`` is a non-blocking
enqueue, ``wait(keys)`` blocks only until the named keys are resident,
and the engine's per-layer lookahead pipeline hides most transfer time
under layer compute. Demand traffic (``bytes_in``/``transfer_s``) and
speculative traffic (``prefetch_bytes``/``prefetch_s``) are accounted
separately so the engine's transfer metrics never conflate the two.

This is the *runtime* placement path; the in-graph dual-bank path
(``mixed_moe``) covers the resident portion.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Hashable, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    #: DEMAND traffic only — transfers a decode step actually asked for.
    bytes_in: int = 0
    transfer_s: float = 0.0
    #: SPECULATIVE traffic (hint/prefetch staging) — kept apart so
    #: miss-rate and transfer metrics never conflate demand with
    #: speculation (DESIGN.md §12).
    prefetch_bytes: int = 0
    prefetch_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 1.0

    def reset(self):
        self.__init__()


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


class ExpertCache:
    """LRU cache of expert weight pytrees under a byte budget.

    Used directly (one owner, ``fetch`` bound at construction) or as the
    shared store behind :meth:`scoped` views (``fetch`` may then be None —
    each view brings its own loader)."""

    #: staging discipline flag: False = every transfer blocks the caller
    #: (the paper's serial swap); AsyncExpertCache overrides (DESIGN.md §12).
    is_async = False

    def __init__(self, fetch: Optional[Callable[[Hashable], object]] = None,
                 capacity_bytes: int = 0,
                 device: Optional[jax.Device] = None):
        if int(capacity_bytes) <= 0:
            raise ValueError("ExpertCache needs a positive capacity_bytes "
                             "(a 0-byte cache would thrash every access)")
        self._fetch = fetch                     # host loader: key -> pytree
        self.capacity = int(capacity_bytes)
        self.device = device or jax.devices()[0]
        self._cache: "collections.OrderedDict[Hashable, Tuple[object,int]]" \
            = collections.OrderedDict()
        self._used = 0
        self.stats = CacheStats()
        #: owner -> view registry, so evictions of namespaced entries are
        #: credited to the view that loses them (cross-tenant accounting).
        self._views: Dict[str, "ScopedExpertCache"] = {}

    # -- core -------------------------------------------------------------
    def get(self, key: Hashable):
        if key in self._cache:
            self._cache.move_to_end(key)
            self.stats.hits += 1
            return self._cache[key][0]
        if self._fetch is None:
            raise RuntimeError(
                "shared ExpertCache has no fetch of its own — access it "
                "through a scoped() view (DESIGN.md §10)")
        self.stats.misses += 1
        host = self._fetch(key)
        self._admit(key, host)
        return self._cache[key][0]

    def _peek(self, key: Hashable):
        """Hit path without stats (views keep their own counters);
        returns the device pytree or None."""
        if key not in self._cache:
            return None
        self._cache.move_to_end(key)
        return self._cache[key][0]

    def _admit(self, key: Hashable, host,
               speculative: bool = False) -> Tuple[int, float]:
        """Stage a host pytree into the cache; returns (bytes, seconds)
        of the device transfer. Updates the parent's aggregate stats
        (bytes_in/transfer_s for demand, prefetch_bytes/prefetch_s for
        speculative staging — hit/miss bookkeeping is the caller's)."""
        nb = _nbytes(host)
        self._evict_until(nb)
        t0 = time.perf_counter()
        dev = jax.device_put(host, self.device)
        jax.block_until_ready(dev)
        dt = time.perf_counter() - t0
        if speculative:
            self.stats.prefetch_s += dt
            self.stats.prefetch_bytes += nb
        else:
            self.stats.transfer_s += dt
            self.stats.bytes_in += nb
        self._cache[key] = (dev, nb)
        self._used += nb
        return nb, dt

    def _credit_eviction(self, key: Hashable):
        """Per-owner eviction accounting for namespaced entries."""
        self.stats.evictions += 1
        if isinstance(key, tuple) and len(key) == 2 \
                and isinstance(key[0], str) and key[0] in self._views:
            self._views[key[0]].stats.evictions += 1

    def _evict_until(self, need: int):
        while self._cache and self._used + need > self.capacity:
            key, (old, nb) = self._cache.popitem(last=False)
            del old
            self._used -= nb
            self._credit_eviction(key)

    def update(self, key: Hashable, host) -> int:
        """Replace ``key``'s entry IN PLACE with a new host pytree — the
        precision-ladder promote/demote path (DESIGN.md §11): an expert
        that flips rung (e.g. 4 -> 8 bit) but stays swap-resident
        re-streams in its new format and the cache's byte accounting
        charges exactly the size delta. Admits the key when absent
        (delta = full new size). Returns the byte delta (new - old)."""
        old_nb = 0
        if key in self._cache:
            _, old_nb = self._cache.pop(key)
            self._used -= old_nb
        nb, _ = self._admit(key, host)
        return nb - old_nb

    # -- namespacing (multi-tenant shared swap, DESIGN.md §10) --------------
    def scoped(self, owner: str,
               fetch: Optional[Callable[[Hashable], object]] = None
               ) -> "ScopedExpertCache":
        """A namespaced view for ``owner``: same LRU, same byte budget,
        keys prefixed with the owner so identical (layer, expert) ids of
        different tenants never collide. One view per owner."""
        if owner in self._views:
            raise ValueError(f"owner {owner!r} already has a scoped view")
        view = ScopedExpertCache(self, owner, fetch)
        self._views[owner] = view
        return view

    # -- management (planner reconfig hooks) -------------------------------
    def pin(self, keys):
        """Pre-load keys (planner's resident set), most-priority last."""
        for k in keys:
            self.get(k)

    def invalidate(self, keys=None):
        if keys is None:
            for k in list(self._cache):
                self._credit_eviction(k)
            self._cache.clear()
            self._used = 0
            return
        for k in list(keys):
            if k in self._cache:
                self._used -= self._cache.pop(k)[1]
                self._credit_eviction(k)

    def resize(self, capacity_bytes: int):
        """Change the byte budget. A shrink below ``used_bytes`` evicts
        down IMMEDIATELY (LRU order) — the cache is never left over
        budget until the next admission (tested)."""
        self.capacity = int(capacity_bytes)
        self._evict_until(0)

    def drain(self):
        """Synchronous staging has nothing in flight — no-op (the async
        subclass blocks until every enqueued transfer lands)."""

    def close(self):
        """No transfer workers to join — no-op (see AsyncExpertCache)."""

    @property
    def used_bytes(self) -> int:
        return self._used

    def resident_keys(self):
        return list(self._cache.keys())

    def owner_used_bytes(self, owner: str) -> int:
        return sum(nb for k, (_, nb) in self._cache.items()
                   if isinstance(k, tuple) and len(k) == 2 and k[0] == owner)


class ScopedExpertCache:
    """One owner's view of a shared :class:`ExpertCache` (DESIGN.md §10).

    Presents the single-owner cache interface (``get``/``invalidate``/
    ``resident_keys``/``stats``) over namespaced keys ``(owner, key)``.
    Capacity and LRU order are the PARENT's — the byte budget is jointly
    shared, so this view's misses may evict another owner's entries (and
    vice versa; each eviction is credited to the owner losing the entry)."""

    def __init__(self, parent: ExpertCache, owner: str,
                 fetch: Optional[Callable[[Hashable], object]] = None):
        self.parent = parent
        self.owner = owner
        self._fetch = fetch
        self.stats = CacheStats()

    def bind_fetch(self, fetch: Callable[[Hashable], object]):
        """Late-bind the host loader (the serving engine constructs its
        loader after the view exists)."""
        self._fetch = fetch

    def _full(self, key: Hashable) -> Tuple[str, Hashable]:
        return (self.owner, key)

    # -- single-owner cache interface ---------------------------------------
    def get(self, key: Hashable):
        if self.is_async:
            return self._get_async(key)
        full = self._full(key)
        hit = self.parent._peek(full)
        if hit is not None:
            self.stats.hits += 1
            self.parent.stats.hits += 1
            return hit
        if self._fetch is None:
            raise RuntimeError(f"scoped cache {self.owner!r}: no fetch "
                               "bound (bind_fetch first)")
        self.stats.misses += 1
        self.parent.stats.misses += 1
        host = self._fetch(key)
        nb, dt = self.parent._admit(full, host)
        self.stats.bytes_in += nb
        self.stats.transfer_s += dt
        return self.parent._cache[full][0]

    def pin(self, keys):
        for k in keys:
            self.get(k)

    # -- async transfer-engine delegation (DESIGN.md §12) -------------------
    # Per-owner DEMAND accounting is delta-based over the parent's stats:
    # safe because each tenant engine drives its cache view from the one
    # serving thread (workers only touch the speculative counters, which
    # stay parent-global).
    @property
    def is_async(self) -> bool:
        return bool(getattr(self.parent, "is_async", False))

    def _async_parent(self) -> "AsyncExpertCache":
        if not self.is_async:
            raise RuntimeError(
                f"scoped cache {self.owner!r}: the shared swap space is "
                "synchronous — build it as AsyncExpertCache for overlap "
                "serving (DESIGN.md §12)")
        return self.parent

    def _scoped_fetch(self, full_key):
        if self._fetch is None:
            raise RuntimeError(f"scoped cache {self.owner!r}: no fetch "
                               "bound (bind_fetch first)")
        return self._fetch(full_key[1])

    def _get_async(self, key: Hashable):
        p = self._async_parent()
        with p._lock:
            h0, m0 = p.stats.hits, p.stats.misses
            b0, t0 = p.stats.bytes_in, p.stats.transfer_s
        val = p.get(self._full(key), fetch=self._scoped_fetch)
        with p._lock:
            self.stats.hits += p.stats.hits - h0
            self.stats.misses += p.stats.misses - m0
            self.stats.bytes_in += p.stats.bytes_in - b0
            self.stats.transfer_s += p.stats.transfer_s - t0
        return val

    def prefetch(self, keys) -> int:
        """Non-blocking speculative enqueue through the async parent
        (speculative traffic is accounted parent-globally)."""
        return self._async_parent().prefetch(
            [self._full(k) for k in keys], fetch=self._scoped_fetch)

    def hint(self, keys):
        """Speculative staging for this namespace: non-blocking enqueue
        on an async parent, inline speculative admit on a sync one (the
        blocking staging time is mirrored into THIS view's stats so the
        engine's exposed-time accounting sees it)."""
        if self.is_async:
            self.prefetch(keys)
            return
        for k in keys:
            full = self._full(k)
            if self.parent._peek(full) is None:
                nb, dt = self.parent._admit(full, self._scoped_fetch(full),
                                            speculative=True)
                self.stats.prefetch_bytes += nb
                self.stats.prefetch_s += dt

    def wait(self, keys) -> int:
        """Demand-wait through the async parent; per-owner demand stats
        mirror the parent's deltas (snapshots under the parent's lock —
        the same discipline as ``_get_async``). Returns the demand-fetch
        count."""
        p = self._async_parent()
        keys = list(keys)
        with p._lock:
            b0, t0 = p.stats.bytes_in, p.stats.transfer_s
        n = p.wait([self._full(k) for k in keys],
                   fetch=self._scoped_fetch)
        with p._lock:
            self.stats.bytes_in += p.stats.bytes_in - b0
            self.stats.transfer_s += p.stats.transfer_s - t0
        self.stats.misses += n
        self.stats.hits += len(keys) - n
        return n

    def drain(self):
        self.parent.drain()

    def close(self):
        """Drain this view's traffic but leave the SHARED space open —
        it is closed by whoever owns it (e.g. MultiTenantEngine)."""
        self.parent.drain()

    def update(self, key: Hashable, host) -> int:
        """In-place rung promote/demote of this owner's entry
        (see :meth:`ExpertCache.update`); returns the byte delta. On an
        async parent the whole read-update-read runs under its (re-
        entrant) lock so concurrent workers can't skew the deltas."""
        lock = getattr(self.parent, "_lock", None)
        with lock if lock is not None else contextlib.nullcontext():
            bytes_before = self.parent.stats.bytes_in
            time_before = self.parent.stats.transfer_s
            delta = self.parent.update(self._full(key), host)
            self.stats.bytes_in += \
                self.parent.stats.bytes_in - bytes_before
            self.stats.transfer_s += \
                self.parent.stats.transfer_s - time_before
        return delta

    def invalidate(self, keys=None):
        """Drop this owner's entries only — other namespaces are
        untouched (tested)."""
        if keys is None:
            full = [k for k in self.parent.resident_keys()
                    if isinstance(k, tuple) and len(k) == 2
                    and k[0] == self.owner]
        else:
            full = [self._full(k) for k in keys]
        self.parent.invalidate(full)

    def resident_keys(self) -> List[Hashable]:
        return [k[1] for k in self.parent.resident_keys()
                if isinstance(k, tuple) and len(k) == 2
                and k[0] == self.owner]

    @property
    def used_bytes(self) -> int:
        return self.parent.owner_used_bytes(self.owner)

    @property
    def capacity(self) -> int:
        return self.parent.capacity


class PrefetchingExpertCache(ExpertCache):
    """Beyond-paper: gate-ahead speculative prefetch (à la [5] Eliseev &
    Mazur). The engine calls ``hint(keys)`` with the *predicted* experts of
    the next layer (reusing the current activations against the next layer's
    router); hints are fetched before they are demanded. Synchronous staging
    keeps the implementation portable; :class:`AsyncExpertCache` is the
    overlapped variant (DESIGN.md §12).

    Speculative staging is accounted in ``stats.prefetch_bytes`` /
    ``stats.prefetch_s`` — it never pollutes the demand counters
    (``misses``/``bytes_in``/``transfer_s``), so the engine's measured
    miss rate and transfer time stay demand-only."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.prefetch_hits = 0

    def hint(self, keys):
        for k in keys:
            if k not in self._cache:
                self._admit(k, self._fetch(k), speculative=True)
            else:
                self.prefetch_hits += 1


class AsyncExpertCache(ExpertCache):
    """Overlapped expert staging (DESIGN.md §12): a small transfer worker
    pool + double-buffered swap staging behind the LRU cache interface.

    * ``prefetch(keys)`` / ``hint(keys)`` — NON-BLOCKING speculative
      enqueue; at most one in-flight future per key (futures are keyed by
      the full cache key, i.e. ``(owner, layer, expert)`` through a
      scoped view).
    * ``wait(keys)`` — block until every key is device-resident; keys
      that are neither resident nor in flight are fetched as DEMAND
      (counted in ``misses``/``bytes_in``/``transfer_s``); keys whose
      speculative fetch is still in flight only block for the remainder.
    * ``drain()`` — barrier: every enqueued transfer lands (the engine
      calls it before replans so stale-plan blobs can't be admitted after
      an ``invalidate``).
    * ``close()`` — drain + join the workers; idempotent. A deadlocked
      pipeline therefore fails a wall-clock CI timeout instead of
      leaking threads.

    ``staging_buffers`` bounds CONCURRENT host→device copies (the
    double-buffered swap staging: one buffer transfers while the next is
    prepared); additional enqueues queue behind the semaphore.
    Admission and eviction stay LRU-correct while fetches are in flight:
    all cache-dict mutations happen under one lock, in-flight keys are
    not yet admitted (hence not evictable), and a speculative entry that
    was LRU-evicted before its demand is simply re-fetched on demand."""

    is_async = True

    def __init__(self, *a, workers: int = 2, staging_buffers: int = 2,
                 **kw):
        super().__init__(*a, **kw)
        self._lock = threading.RLock()
        self._inflight: Dict[Hashable, Future] = {}
        self._staging = threading.BoundedSemaphore(max(int(staging_buffers),
                                                       1))
        self._pool = ThreadPoolExecutor(
            max_workers=max(int(workers), 1),
            thread_name_prefix="expert-xfer")
        self._closed = False
        self.prefetch_hits = 0

    # -- worker side --------------------------------------------------------
    def _stage(self, key: Hashable, speculative: bool,
               fetch: Optional[Callable]) -> Tuple[int, float]:
        try:
            with self._staging:          # double-buffered swap staging
                host = (fetch or self._fetch)(key)
                nb = _nbytes(host)
                t0 = time.perf_counter()
                dev = jax.device_put(host, self.device)
                jax.block_until_ready(dev)
                dt = time.perf_counter() - t0
            with self._lock:
                if speculative:
                    self.stats.prefetch_s += dt
                    self.stats.prefetch_bytes += nb
                else:
                    self.stats.transfer_s += dt
                    self.stats.bytes_in += nb
                if key in self._cache:   # raced with an update(): replace
                    self._used -= self._cache.pop(key)[1]
                self._evict_until(nb)
                self._cache[key] = (dev, nb)
                self._used += nb
                self._inflight.pop(key, None)
            return nb, dt
        except BaseException:
            with self._lock:
                self._inflight.pop(key, None)
            raise

    def _submit(self, key: Hashable, speculative: bool,
                fetch: Optional[Callable]) -> Future:
        """Enqueue one transfer; the caller holds the lock."""
        if self._closed:
            raise RuntimeError("AsyncExpertCache is closed")
        fut = self._pool.submit(self._stage, key, speculative, fetch)
        self._inflight[key] = fut
        return fut

    # -- async interface ----------------------------------------------------
    def prefetch(self, keys, fetch: Optional[Callable] = None) -> int:
        """Non-blocking speculative enqueue; returns the number of
        transfers actually enqueued (resident / already-in-flight keys
        are skipped)."""
        n = 0
        with self._lock:
            for k in keys:
                if k in self._cache:
                    # LRU-touch: the prediction says this key is about
                    # to be demanded — it must not sit at the LRU tail
                    # where the current layer's admissions would evict
                    # it right before its wait()
                    self._cache.move_to_end(k)
                    self.prefetch_hits += 1
                    continue
                if k in self._inflight:
                    continue
                self._submit(k, True, fetch)
                n += 1
        return n

    def hint(self, keys):
        """PrefetchingExpertCache-compatible spelling of
        :meth:`prefetch` — a non-blocking enqueue (DESIGN.md §12)."""
        self.prefetch(keys)

    def wait(self, keys, fetch: Optional[Callable] = None) -> int:
        """Block until every key's transfer has LANDED (each key was
        admitted at least once). Under extreme memory pressure a just-
        landed entry may already have been LRU-evicted by a concurrent
        admission — a later access simply re-demands it (``get`` does so
        transparently); simultaneous residency of an arbitrary key set
        cannot be promised by a bounded cache (len(keys) may exceed
        capacity). Returns the number of DEMAND fetches (keys that were
        neither resident nor already in flight)."""
        fetched = 0
        futs: List[Future] = []
        with self._lock:
            for k in keys:
                if k in self._cache:
                    self._cache.move_to_end(k)
                    self.stats.hits += 1
                    continue
                fut = self._inflight.get(k)
                if fut is None:
                    self.stats.misses += 1
                    fetched += 1
                    fut = self._submit(k, False, fetch)
                else:
                    # demanded while its speculative fetch is in flight:
                    # block only for the remainder of the transfer
                    self.stats.hits += 1
                    self.prefetch_hits += 1
                futs.append(fut)
        for fut in futs:
            fut.result()
        return fetched

    def drain(self):
        while True:
            with self._lock:
                futs = list(self._inflight.values())
            if not futs:
                return
            for fut in futs:
                fut.result()

    def close(self):
        if self._closed:
            return
        try:
            self.drain()
        finally:
            self._closed = True
            self._pool.shutdown(wait=True)

    # -- thread-safe overrides of the sync surface --------------------------
    def get(self, key: Hashable, fetch: Optional[Callable] = None):
        with self._lock:
            if key in self._cache:
                self._cache.move_to_end(key)
                self.stats.hits += 1
                return self._cache[key][0]
            fut = self._inflight.get(key)
            if fut is None:
                if fetch is None and self._fetch is None:
                    raise RuntimeError(
                        "shared AsyncExpertCache has no fetch of its own "
                        "— access it through a scoped() view "
                        "(DESIGN.md §10)")
                self.stats.misses += 1
                fut = self._submit(key, False, fetch)
            else:
                self.stats.hits += 1
                self.prefetch_hits += 1
        fut.result()
        while True:
            with self._lock:
                entry = self._cache.get(key)
                if entry is not None:
                    self._cache.move_to_end(key)
                    return entry[0]
                fut = self._inflight.get(key)
                if fut is None:
                    # LRU-evicted between the future landing and this
                    # read (tiny caches): silent re-fetch, no re-count
                    fut = self._submit(key, False, fetch)
            fut.result()

    def update(self, key: Hashable, host) -> int:
        with self._lock:
            return super().update(key, host)

    def invalidate(self, keys=None):
        with self._lock:
            super().invalidate(keys)

    def resize(self, capacity_bytes: int):
        with self._lock:
            super().resize(capacity_bytes)

    def resident_keys(self):
        with self._lock:
            return super().resident_keys()

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used
