"""Device-resident expert cache with LRU replacement + swap space (paper §3).

The serving engine keeps the master copy of every expert on the host (numpy)
and a bounded device cache keyed by (layer, expert). On an expert miss the
weight is staged through a reusable swap buffer (``jax.device_put``) — the
TPU analogue of the paper's pinned CPU<->GPU swap space. Hits/misses and
transferred bytes feed the serving metrics and validate the cost model.

This is the *runtime* placement path; the in-graph dual-bank path
(``mixed_moe``) covers the resident portion.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, Hashable, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_in: int = 0
    transfer_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 1.0

    def reset(self):
        self.__init__()


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


class ExpertCache:
    """LRU cache of expert weight pytrees under a byte budget."""

    def __init__(self, fetch: Callable[[Hashable], object],
                 capacity_bytes: int,
                 device: Optional[jax.Device] = None):
        self._fetch = fetch                     # host loader: key -> pytree
        self.capacity = int(capacity_bytes)
        self.device = device or jax.devices()[0]
        self._cache: "collections.OrderedDict[Hashable, Tuple[object,int]]" \
            = collections.OrderedDict()
        self._used = 0
        self.stats = CacheStats()

    # -- core -------------------------------------------------------------
    def get(self, key: Hashable):
        if key in self._cache:
            self._cache.move_to_end(key)
            self.stats.hits += 1
            return self._cache[key][0]
        self.stats.misses += 1
        host = self._fetch(key)
        nb = _nbytes(host)
        self._evict_until(nb)
        t0 = time.perf_counter()
        dev = jax.device_put(host, self.device)
        jax.block_until_ready(dev)
        self.stats.transfer_s += time.perf_counter() - t0
        self.stats.bytes_in += nb
        self._cache[key] = (dev, nb)
        self._used += nb
        return dev

    def _evict_until(self, need: int):
        while self._cache and self._used + need > self.capacity:
            _, (old, nb) = self._cache.popitem(last=False)
            del old
            self._used -= nb
            self.stats.evictions += 1

    # -- management (planner reconfig hooks) -------------------------------
    def pin(self, keys):
        """Pre-load keys (planner's resident set), most-priority last."""
        for k in keys:
            self.get(k)

    def invalidate(self, keys=None):
        if keys is None:
            self.stats.evictions += len(self._cache)
            self._cache.clear()
            self._used = 0
            return
        for k in list(keys):
            if k in self._cache:
                self._used -= self._cache.pop(k)[1]
                self.stats.evictions += 1

    def resize(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        self._evict_until(0)

    @property
    def used_bytes(self) -> int:
        return self._used

    def resident_keys(self):
        return list(self._cache.keys())


class PrefetchingExpertCache(ExpertCache):
    """Beyond-paper: gate-ahead speculative prefetch (à la [5] Eliseev &
    Mazur). The engine calls ``hint(keys)`` with the *predicted* experts of
    the next layer (reusing the current activations against the next layer's
    router); hints are fetched before they are demanded. Synchronous staging
    keeps the implementation portable; the TPU runtime overlaps via its own
    transfer streams."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.prefetch_hits = 0

    def hint(self, keys):
        for k in keys:
            if k not in self._cache:
                self.get(k)
                self.stats.misses -= 1      # speculative, not demand
            else:
                self.prefetch_hits += 1
