"""Device-resident expert cache with LRU replacement + swap space (paper §3).

The serving engine keeps the master copy of every expert on the host (numpy)
and a bounded device cache keyed by (layer, expert). On an expert miss the
weight is staged through a reusable swap buffer (``jax.device_put``) — the
TPU analogue of the paper's pinned CPU<->GPU swap space. Hits/misses and
transferred bytes feed the serving metrics and validate the cost model.

Multi-tenant serving (DESIGN.md §10) shares ONE swap space between N
engines. Raw ``(layer, expert)`` keys would collide across tenants (tenant
A's ``(0, 3)`` is a different weight blob than tenant B's), so the shared
cache is accessed through :meth:`ExpertCache.scoped` views: a
:class:`ScopedExpertCache` namespaces every key with an explicit owner
field, keeps per-owner hit/miss/eviction accounting (the parent's LRU and
byte budget stay GLOBAL — one tenant's misses may evict another tenant's
swap entries, and the eviction is credited to the owner who lost the
entry), and routes misses to the owner's own host loader.

This is the *runtime* placement path; the in-graph dual-bank path
(``mixed_moe``) covers the resident portion.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, Hashable, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_in: int = 0
    transfer_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 1.0

    def reset(self):
        self.__init__()


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


class ExpertCache:
    """LRU cache of expert weight pytrees under a byte budget.

    Used directly (one owner, ``fetch`` bound at construction) or as the
    shared store behind :meth:`scoped` views (``fetch`` may then be None —
    each view brings its own loader)."""

    def __init__(self, fetch: Optional[Callable[[Hashable], object]] = None,
                 capacity_bytes: int = 0,
                 device: Optional[jax.Device] = None):
        if int(capacity_bytes) <= 0:
            raise ValueError("ExpertCache needs a positive capacity_bytes "
                             "(a 0-byte cache would thrash every access)")
        self._fetch = fetch                     # host loader: key -> pytree
        self.capacity = int(capacity_bytes)
        self.device = device or jax.devices()[0]
        self._cache: "collections.OrderedDict[Hashable, Tuple[object,int]]" \
            = collections.OrderedDict()
        self._used = 0
        self.stats = CacheStats()
        #: owner -> view registry, so evictions of namespaced entries are
        #: credited to the view that loses them (cross-tenant accounting).
        self._views: Dict[str, "ScopedExpertCache"] = {}

    # -- core -------------------------------------------------------------
    def get(self, key: Hashable):
        if key in self._cache:
            self._cache.move_to_end(key)
            self.stats.hits += 1
            return self._cache[key][0]
        if self._fetch is None:
            raise RuntimeError(
                "shared ExpertCache has no fetch of its own — access it "
                "through a scoped() view (DESIGN.md §10)")
        self.stats.misses += 1
        host = self._fetch(key)
        self._admit(key, host)
        return self._cache[key][0]

    def _peek(self, key: Hashable):
        """Hit path without stats (views keep their own counters);
        returns the device pytree or None."""
        if key not in self._cache:
            return None
        self._cache.move_to_end(key)
        return self._cache[key][0]

    def _admit(self, key: Hashable, host) -> Tuple[int, float]:
        """Stage a host pytree into the cache; returns (bytes, seconds)
        of the device transfer. Updates the parent's aggregate stats
        (bytes_in/transfer_s only — hit/miss bookkeeping is the caller's)."""
        nb = _nbytes(host)
        self._evict_until(nb)
        t0 = time.perf_counter()
        dev = jax.device_put(host, self.device)
        jax.block_until_ready(dev)
        dt = time.perf_counter() - t0
        self.stats.transfer_s += dt
        self.stats.bytes_in += nb
        self._cache[key] = (dev, nb)
        self._used += nb
        return nb, dt

    def _credit_eviction(self, key: Hashable):
        """Per-owner eviction accounting for namespaced entries."""
        self.stats.evictions += 1
        if isinstance(key, tuple) and len(key) == 2 \
                and isinstance(key[0], str) and key[0] in self._views:
            self._views[key[0]].stats.evictions += 1

    def _evict_until(self, need: int):
        while self._cache and self._used + need > self.capacity:
            key, (old, nb) = self._cache.popitem(last=False)
            del old
            self._used -= nb
            self._credit_eviction(key)

    def update(self, key: Hashable, host) -> int:
        """Replace ``key``'s entry IN PLACE with a new host pytree — the
        precision-ladder promote/demote path (DESIGN.md §11): an expert
        that flips rung (e.g. 4 -> 8 bit) but stays swap-resident
        re-streams in its new format and the cache's byte accounting
        charges exactly the size delta. Admits the key when absent
        (delta = full new size). Returns the byte delta (new - old)."""
        old_nb = 0
        if key in self._cache:
            _, old_nb = self._cache.pop(key)
            self._used -= old_nb
        nb, _ = self._admit(key, host)
        return nb - old_nb

    # -- namespacing (multi-tenant shared swap, DESIGN.md §10) --------------
    def scoped(self, owner: str,
               fetch: Optional[Callable[[Hashable], object]] = None
               ) -> "ScopedExpertCache":
        """A namespaced view for ``owner``: same LRU, same byte budget,
        keys prefixed with the owner so identical (layer, expert) ids of
        different tenants never collide. One view per owner."""
        if owner in self._views:
            raise ValueError(f"owner {owner!r} already has a scoped view")
        view = ScopedExpertCache(self, owner, fetch)
        self._views[owner] = view
        return view

    # -- management (planner reconfig hooks) -------------------------------
    def pin(self, keys):
        """Pre-load keys (planner's resident set), most-priority last."""
        for k in keys:
            self.get(k)

    def invalidate(self, keys=None):
        if keys is None:
            for k in list(self._cache):
                self._credit_eviction(k)
            self._cache.clear()
            self._used = 0
            return
        for k in list(keys):
            if k in self._cache:
                self._used -= self._cache.pop(k)[1]
                self._credit_eviction(k)

    def resize(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        self._evict_until(0)

    @property
    def used_bytes(self) -> int:
        return self._used

    def resident_keys(self):
        return list(self._cache.keys())

    def owner_used_bytes(self, owner: str) -> int:
        return sum(nb for k, (_, nb) in self._cache.items()
                   if isinstance(k, tuple) and len(k) == 2 and k[0] == owner)


class ScopedExpertCache:
    """One owner's view of a shared :class:`ExpertCache` (DESIGN.md §10).

    Presents the single-owner cache interface (``get``/``invalidate``/
    ``resident_keys``/``stats``) over namespaced keys ``(owner, key)``.
    Capacity and LRU order are the PARENT's — the byte budget is jointly
    shared, so this view's misses may evict another owner's entries (and
    vice versa; each eviction is credited to the owner losing the entry)."""

    def __init__(self, parent: ExpertCache, owner: str,
                 fetch: Optional[Callable[[Hashable], object]] = None):
        self.parent = parent
        self.owner = owner
        self._fetch = fetch
        self.stats = CacheStats()

    def bind_fetch(self, fetch: Callable[[Hashable], object]):
        """Late-bind the host loader (the serving engine constructs its
        loader after the view exists)."""
        self._fetch = fetch

    def _full(self, key: Hashable) -> Tuple[str, Hashable]:
        return (self.owner, key)

    # -- single-owner cache interface ---------------------------------------
    def get(self, key: Hashable):
        full = self._full(key)
        hit = self.parent._peek(full)
        if hit is not None:
            self.stats.hits += 1
            self.parent.stats.hits += 1
            return hit
        if self._fetch is None:
            raise RuntimeError(f"scoped cache {self.owner!r}: no fetch "
                               "bound (bind_fetch first)")
        self.stats.misses += 1
        self.parent.stats.misses += 1
        host = self._fetch(key)
        nb, dt = self.parent._admit(full, host)
        self.stats.bytes_in += nb
        self.stats.transfer_s += dt
        return self.parent._cache[full][0]

    def pin(self, keys):
        for k in keys:
            self.get(k)

    def update(self, key: Hashable, host) -> int:
        """In-place rung promote/demote of this owner's entry
        (see :meth:`ExpertCache.update`); returns the byte delta."""
        bytes_before = self.parent.stats.bytes_in
        time_before = self.parent.stats.transfer_s
        delta = self.parent.update(self._full(key), host)
        self.stats.bytes_in += self.parent.stats.bytes_in - bytes_before
        self.stats.transfer_s += self.parent.stats.transfer_s - time_before
        return delta

    def invalidate(self, keys=None):
        """Drop this owner's entries only — other namespaces are
        untouched (tested)."""
        if keys is None:
            full = [k for k in self.parent.resident_keys()
                    if isinstance(k, tuple) and len(k) == 2
                    and k[0] == self.owner]
        else:
            full = [self._full(k) for k in keys]
        self.parent.invalidate(full)

    def resident_keys(self) -> List[Hashable]:
        return [k[1] for k in self.parent.resident_keys()
                if isinstance(k, tuple) and len(k) == 2
                and k[0] == self.owner]

    @property
    def used_bytes(self) -> int:
        return self.parent.owner_used_bytes(self.owner)

    @property
    def capacity(self) -> int:
        return self.parent.capacity


class PrefetchingExpertCache(ExpertCache):
    """Beyond-paper: gate-ahead speculative prefetch (à la [5] Eliseev &
    Mazur). The engine calls ``hint(keys)`` with the *predicted* experts of
    the next layer (reusing the current activations against the next layer's
    router); hints are fetched before they are demanded. Synchronous staging
    keeps the implementation portable; the TPU runtime overlaps via its own
    transfer streams."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.prefetch_hits = 0

    def hint(self, keys):
        for k in keys:
            if k not in self._cache:
                self.get(k)
                self.stats.misses -= 1      # speculative, not demand
            else:
                self.prefetch_hits += 1
