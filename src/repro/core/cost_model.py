"""Analytic throughput/quality model behind the planner (paper Fig. 3).

Token-generation time for an offloading MoE server decomposes as

    t_token = t_compute + t_router + E[misses per token] * t_transfer

with ``E[misses] = L * top_k * (1 - hit_rate)`` under the paper's
uniform-expert-access assumption, where the hit rate equals the fraction of
(access-weighted) experts resident on the accelerator. In the all-resident
region the model reproduces Fig. 3's plateau (max throughput, slight 4-bit
matmul penalty — which our fused Pallas kernel turns into a *gain*, see
EXPERIMENTS.md §Perf); in the offloading region throughput decays
hyperbolically with the miss volume, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.precision_plan import DEVICE, PrecisionPlan


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Defaults: one TPU v5e chip + PCIe gen4-ish host link (DESIGN.md §2)."""
    peak_flops: float = 197e12          # bf16 FLOP/s
    hbm_bw: float = 819e9               # B/s
    host_link_bw: float = 24e9          # B/s effective host->HBM
    hbm_bytes: float = 16e9
    # Serving decode is memory-bound; effective MBU for weight streaming.
    mbu: float = 0.6
    mfu: float = 0.4
    # 4-bit matmul throughput relative to bf16. The paper (PyTorch/bnb)
    # observed < 1. Our fused kernel reads 4x fewer bytes -> > 1 in the
    # memory-bound decode regime.
    q4_speedup_decode: float = 2.8
    q4_speedup_prefill: float = 0.95


@dataclasses.dataclass(frozen=True)
class QoSEstimate:
    tokens_per_s: float
    t_compute_ms: float
    t_transfer_ms: float
    hit_rate: float
    device_bytes: int
    quality_proxy: float    # predicted perplexity multiplier vs all-16bit


def expert_access_stats(cfg: ModelConfig, plan: PrecisionPlan
                        ) -> Tuple[float, float]:
    """(hit_rate, expected transfer bytes per token)."""
    e = cfg.moe
    assert e is not None
    l, ne = plan.quant.shape
    on_dev = plan.location == DEVICE
    # uniform routing: each of top_k accesses per layer hits a uniformly
    # random expert
    hit = float(on_dev.mean())
    s4 = cfg.expert_param_bytes(plan.bits)
    s16 = cfg.expert_param_bytes(16)
    miss_bytes = 0.0
    for li in range(l):
        for ei in range(ne):
            if not on_dev[li, ei]:
                miss_bytes += (s4 if plan.quant[li, ei] else s16) / ne
    # per token: top_k accesses per layer
    per_token = miss_bytes * e.top_k
    return hit, per_token


def device_bytes(cfg: ModelConfig, plan: PrecisionPlan) -> int:
    """HBM footprint of the plan (non-expert 16-bit + resident experts)."""
    s4 = cfg.expert_param_bytes(plan.bits)
    s16 = cfg.expert_param_bytes(16)
    on_dev = plan.location == DEVICE
    n4 = int((on_dev & plan.quant).sum())
    n16 = int((on_dev & ~plan.quant).sum())
    return cfg.non_expert_bytes() + n4 * s4 + n16 * s16


def quality_proxy(cfg: ModelConfig, plan: PrecisionPlan) -> float:
    """Monotone perplexity-ratio proxy, calibrated on the paper's Table 1:
    all experts 4-bit cost ~= +7% ppl (2.62->2.80 WikiText2); linear in the
    quantized fraction (Fig. 2 is ~linear with noise)."""
    frac = plan.quant.mean()
    per_bit = {4: 0.07, 8: 0.02}[plan.bits]
    return 1.0 + per_bit * float(frac)


def estimate_qos(cfg: ModelConfig, plan: PrecisionPlan,
                 hw: HardwareModel = HardwareModel(),
                 batch_size: int = 1) -> QoSEstimate:
    """Decode-regime tokens/s for one replica under the plan."""
    e = cfg.moe
    assert e is not None, "QoS planner applies to MoE archs (DESIGN.md §5)"
    hit, miss_bytes = expert_access_stats(cfg, plan)

    # compute: read every active weight byte once per token (memory-bound
    # decode); quantized experts read bits/16 of the bytes.
    s16 = cfg.expert_param_bytes(16)
    s4 = cfg.expert_param_bytes(plan.bits)
    frac4 = float(plan.quant.mean())
    active_expert_bytes = cfg.num_layers * e.top_k * (
        frac4 * s4 / hw.q4_speedup_decode * (16 / plan.bits)
        + (1 - frac4) * s16)
    weight_bytes = cfg.non_expert_bytes() + active_expert_bytes
    t_compute = weight_bytes / (hw.hbm_bw * hw.mbu)

    t_transfer = miss_bytes / hw.host_link_bw
    t_token = t_compute + t_transfer
    return QoSEstimate(
        tokens_per_s=batch_size / t_token,
        t_compute_ms=t_compute * 1e3,
        t_transfer_ms=t_transfer * 1e3,
        hit_rate=hit,
        device_bytes=device_bytes(cfg, plan),
        quality_proxy=quality_proxy(cfg, plan),
    )


def pareto_frontier(points: Sequence[Tuple[float, float]]) -> List[int]:
    """Indices of the Pareto-optimal (throughput UP, quality_proxy DOWN)."""
    idx = sorted(range(len(points)), key=lambda i: (-points[i][0], points[i][1]))
    out, best_q = [], float("inf")
    for i in idx:
        if points[i][1] < best_q - 1e-12:
            out.append(i)
            best_q = points[i][1]
    return sorted(out)
