"""Analytic throughput/quality model behind the planner (paper Fig. 3),
rung-indexed over the precision ladder (DESIGN.md §11).

Token-generation time for an offloading MoE server decomposes as

    t_token = t_compute + max(0, t_transfer - overlap_window)
    overlap_window = overlap_efficiency * t_compute

with ``t_transfer = E[misses per token] * t_expert_transfer``,
``E[misses] = L * top_k * (1 - hit_rate)`` under the paper's
uniform-expert-access assumption, where the hit rate equals the fraction of
(access-weighted) experts resident on the accelerator.
``overlap_efficiency`` models the async transfer pipeline (DESIGN.md §12):
the fraction of the compute window under which transfers hide. At the
default ``0.0`` the expression collapses BIT-FOR-BIT to the paper's serial
additive model ``t_compute + t_transfer`` (the frontier golden fixture
pins this); a calibrated ``> 0`` value re-ranks transfer-dominated
configurations, whose exposed transfer shrinks. In the all-resident
region the model reproduces Fig. 3's plateau (max throughput, slight 4-bit
matmul penalty — which our fused Pallas kernel turns into a *gain*, see
EXPERIMENTS.md §Perf); in the offloading region throughput decays
hyperbolically with the miss volume, as in the paper.

Every term is a sum over the plan's ladder rungs: per-rung byte sizes,
per-rung decode speedups (int4 and int8 read 4x/2x fewer HBM bytes) and a
per-rung quality cost. The binary ladder reproduces the historical
two-term expressions bit-for-bit (the frontier golden fixture pins this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.precision_plan import (DEVICE, HOST, PEER, PrecisionPlan,
                                       quantized_rungs)

#: perplexity-multiplier cost per fully-quantized model at each rung,
#: calibrated on the paper's Table 1 (all-4-bit ~= +7% ppl on WikiText2)
#: and the int8 rows (~+2%); 16-bit costs nothing by definition.
RUNG_QUALITY_COST: Dict[int, float] = {4: 0.07, 8: 0.02, 16: 0.0}


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Defaults: one TPU v5e chip + PCIe gen4-ish host link (DESIGN.md §2)."""
    peak_flops: float = 197e12          # bf16 FLOP/s
    hbm_bw: float = 819e9               # B/s
    host_link_bw: float = 24e9          # B/s effective host->HBM
    hbm_bytes: float = 16e9
    # Serving decode is memory-bound; effective MBU for weight streaming.
    mbu: float = 0.6
    mfu: float = 0.4
    # Quantized matmul throughput relative to bf16, per rung. The paper
    # (PyTorch/bnb) observed < 1. Our fused kernel reads bits/16 of the
    # bytes -> > 1 in the memory-bound decode regime; int8 reads 2x fewer
    # bytes than bf16 so its ceiling is lower than int4's.
    q4_speedup_decode: float = 2.8
    q4_speedup_prefill: float = 0.95
    q8_speedup_decode: float = 1.6
    q8_speedup_prefill: float = 0.98
    # Async transfer pipeline (DESIGN.md §12): fraction of t_compute
    # usable as the overlap window that hides expert transfers. 0.0 =
    # serial staging — the paper's additive token time, bit-for-bit
    # (golden-fixture pinned). The engine calibrates a measured value via
    # AdaptiveServingEngine.calibrate_overlap().
    overlap_efficiency: float = 0.0
    # Per-kernel dispatch overhead of the expert FFN (DESIGN.md §13).
    # 0.0 (default) keeps the historical model bit-for-bit (golden-fixture
    # pinned). With a calibrated value, grouped_ffn=True charges one
    # launch per ladder rung PRESENT per layer (the grouped multi-expert
    # kernel), grouped_ffn=False one per resident expert (the per-expert
    # loop) — the term the grouped kernel collapses from E_resident to
    # n_rungs.
    kernel_launch_s: float = 0.0
    grouped_ffn: bool = True
    # EP peer tier (DESIGN.md §16). Experts on PEER devices stay in
    # accelerator HBM; only the token ACTIVATIONS travel (all2all), so
    # the peer tier is charged activation bytes at the inter-device
    # bandwidth plus a per-sharded-layer all2all launch latency — never
    # weight streaming. Both terms multiply by the plan's peer
    # occupancy, so any plan without PEER experts (every single-device
    # plan, every ep=1 frontier) contributes exactly +0.0 and the
    # historical model — and the frontier golden fixture — is untouched
    # bit-for-bit, regardless of these defaults. Defaults: ICI-class
    # inter-device link (~10x the PCIe host link) + a few-microsecond
    # collective launch.
    interconnect_bw: float = 300e9
    all2all_latency_s: float = 2e-6
    # Ladder-draft self-speculative decoding (DESIGN.md §17). ``spec_k``
    # draft tokens per cycle run with EVERY expert forced to the lowest
    # ladder rung (banks already resident — zero extra weight bytes,
    # zero host transfers), then one verify forward at the serving plan
    # scores all k+1 positions. Expected emitted tokens per cycle is the
    # geometric partial sum (1 - a^(k+1)) / (1 - a) at acceptance rate
    # ``a`` — the ``t_token / (1 + E[accepted])`` pricing. ``spec_k=0``
    # (default) prices plain decode bit-for-bit (golden-fixture pinned);
    # ``spec_acceptance`` comes from measurement (the engine's
    # ``acceptance_rate`` metric), not from an analytic guess.
    spec_k: int = 0
    spec_acceptance: float = 0.0

    def q_speedup_decode(self, bits: int) -> float:
        """Decode-regime matmul speedup of rung ``bits`` vs bf16."""
        if bits >= 16:
            return 1.0
        return {4: self.q4_speedup_decode, 8: self.q8_speedup_decode}[bits]


@dataclasses.dataclass(frozen=True)
class QoSEstimate:
    tokens_per_s: float
    t_compute_ms: float
    t_transfer_ms: float    # TOTAL transfer time (demand volume / link bw)
    hit_rate: float
    device_bytes: int
    quality_proxy: float    # predicted perplexity multiplier vs all-16bit
    #: transfer time left EXPOSED on the token critical path after the
    #: overlap window (== t_transfer_ms when overlap_efficiency is 0).
    t_exposed_ms: float = 0.0
    #: all2all time for PEER-resident expert accesses (activation bytes
    #: over the inter-device link + per-sharded-layer collective
    #: latency — DESIGN.md §16). Exactly 0.0 when the plan has no PEER
    #: experts (every single-device plan).
    t_peer_ms: float = 0.0
    #: speculative decode (DESIGN.md §17): compute-only token time of the
    #: all-lowest-rung draft pass, and expected emitted tokens per
    #: draft+verify cycle. ``spec_k=0``: 0.0 / 1.0 (plain decode).
    t_draft_ms: float = 0.0
    spec_tokens_per_cycle: float = 1.0


def expert_access_stats(cfg: ModelConfig, plan: PrecisionPlan
                        ) -> Tuple[float, float]:
    """(hit_rate, expected transfer bytes per token)."""
    e = cfg.moe
    assert e is not None
    ne = plan.bits.shape[1]
    # a "hit" is any access that does NOT stream over the host link:
    # LOCAL- and PEER-resident experts both live in accelerator HBM
    # (PEER costs all2all activation bytes instead — peer_access_stats).
    # Single-device plans have no PEER experts, so this is the
    # historical ``location == DEVICE`` mask bit-for-bit.
    on_dev = plan.location != HOST
    # uniform routing: each of top_k accesses per layer hits a uniformly
    # random expert
    hit = float(on_dev.mean())
    # exact rational accumulation: every off-device expert contributes
    # size/ne; summing the integer numerators first and dividing once is
    # the correctly-rounded value of the rational sum, which coincides
    # with the historical per-element float loop whenever the per-expert
    # terms are exactly representable (ne a power of two — every config
    # the golden fixture pins), while running as a few numpy reductions
    # instead of an O(L*E) Python loop per enumerated frontier point.
    off = ~on_dev
    numerator = 0
    for b in plan.ladder:
        numerator += int((off & (plan.bits == b)).sum()) \
            * cfg.expert_param_bytes(b)
    miss_bytes = numerator / ne
    # per token: top_k accesses per layer
    per_token = miss_bytes * e.top_k
    return hit, per_token


def peer_access_stats(cfg: ModelConfig, plan: PrecisionPlan
                      ) -> Tuple[float, float, int]:
    """(peer_fraction, all2all activation bytes per token, # layers with
    any PEER expert) — the EP peer tier's demand volume (DESIGN.md §16).

    A PEER access ships the token activation to the owning device and
    the weighted expert output back: ``2 * d_model`` elements at the
    activation itemsize, per routed access, scaled by the layer's peer
    occupancy under uniform routing. Integer-numerator accumulation
    mirrors :func:`expert_access_stats` (exactly-rounded rational sum).
    All three results are exactly zero for plans without PEER experts.
    """
    e = cfg.moe
    assert e is not None
    ne = plan.bits.shape[1]
    on_peer = plan.location == PEER
    itemsize = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    per_access = 2 * cfg.d_model * itemsize
    numerator = int(on_peer.sum()) * per_access * e.top_k
    peer_layers = int(on_peer.any(axis=1).sum())
    return float(on_peer.mean()), numerator / ne, peer_layers


def device_bytes(cfg: ModelConfig, plan: PrecisionPlan) -> int:
    """LOCAL HBM footprint of the plan (non-expert 16-bit + DEVICE-
    resident experts, each at its own rung's size). PEER experts consume
    a peer device's HBM, not this one's — the per-device budget is what
    frontier feasibility checks against, which is exactly why EP widens
    the residency axis (DESIGN.md §16)."""
    on_dev = plan.location == DEVICE
    total = cfg.non_expert_bytes()
    for b in sorted(plan.ladder):
        total += int((on_dev & (plan.bits == b)).sum()) \
            * cfg.expert_param_bytes(b)
    return total


def quality_proxy(cfg: ModelConfig, plan: PrecisionPlan,
                  profile=None) -> float:
    """Monotone perplexity-ratio proxy, calibrated on the paper's Table 1
    (all experts 4-bit ~= +7% ppl, 2.62->2.80 WikiText2; int8 ~= +2%);
    linear per rung in the rung's expert fraction (Fig. 2 is ~linear with
    noise), summed over the ladder's quantized rungs ascending.

    With a calibrated :class:`~repro.core.sensitivity.SensitivityProfile`
    the flat per-rung price becomes the traffic-weighted per-expert sum
    ``1 + sum freq[l,e] * sens[l,e,bits]`` (DESIGN.md §15). A ``None`` or
    *uniform* profile executes the historical code path verbatim — the
    frontier golden fixture pins this bit-for-bit."""
    if profile is not None and not profile.is_uniform():
        return 1.0 + profile.quality_cost(plan)
    proxy = 1.0
    for b in quantized_rungs(plan.ladder):
        frac = float((plan.bits == b).mean())
        proxy += RUNG_QUALITY_COST[b] * frac
    return proxy


def ffn_kernel_launches(plan: PrecisionPlan, grouped: bool = True) -> int:
    """Expert-FFN kernel dispatches per decode token. Grouped (DESIGN.md
    §13): one launch per ladder rung present in each layer's bank, so the
    count is bounded by L x n_rungs regardless of expert count. Looped:
    one per device-resident expert (the legacy vmap spelling)."""
    if not grouped:
        return int((plan.location == DEVICE).sum())
    launches = 0
    for b in plan.ladder:
        launches += int((plan.bits == b).any(axis=1).sum())
    return launches


def speculative_tokens_per_cycle(k: int, acceptance: float) -> float:
    """Expected tokens emitted per draft+verify cycle (DESIGN.md §17).

    Under the i.i.d.-acceptance model (each draft token independently
    matches the verify target with probability ``acceptance``) the
    longest accepted prefix plus the guaranteed corrected/bonus token
    gives the geometric partial sum ``(1 - a^(k+1)) / (1 - a)`` —
    Leviathan et al.'s E[#generated]. ``k=0`` returns exactly 1.0 (plain
    decode emits one token per cycle); ``a=1`` returns ``k + 1``."""
    if k <= 0:
        return 1.0
    a = min(max(float(acceptance), 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def draft_token_time(cfg: ModelConfig, plan: PrecisionPlan,
                     hw: HardwareModel = HardwareModel()) -> float:
    """Compute-only token time of the ladder-draft pass (DESIGN.md §17):
    every expert forced to the LOWEST ladder rung. The rung banks are
    already resident for the serving plan, so the draft streams zero
    bytes over the host link and pays zero peer all2all — it reads the
    non-expert weights plus ``L * top_k`` lowest-rung experts from HBM,
    at the rung's fused-kernel decode speedup."""
    e = cfg.moe
    assert e is not None
    qr = quantized_rungs(plan.ladder)
    low = qr[0] if qr else 16
    per_active = cfg.expert_param_bytes(low) \
        / hw.q_speedup_decode(low) * (16 / low) if low < 16 \
        else float(cfg.expert_param_bytes(16))
    weight_bytes = cfg.non_expert_bytes() \
        + cfg.num_layers * e.top_k * per_active
    t = weight_bytes / (hw.hbm_bw * hw.mbu)
    if hw.kernel_launch_s > 0.0:
        # all experts on one rung: one grouped launch per layer.
        launches = cfg.num_layers if hw.grouped_ffn \
            else int((plan.location == DEVICE).sum())
        t += launches * hw.kernel_launch_s
    return t


def kv_token_bytes(cfg: ModelConfig) -> int:
    """KV bytes one cached token costs across the stack (k + v)."""
    a = cfg.attention
    itemsize = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    return cfg.num_layers * 2 * a.num_kv_heads * a.head_dim * itemsize


def kv_bytes_bucketed(cfg: ModelConfig, slots: int, window: int) -> int:
    """Slot-cache KV footprint: every slot holds its full window whether
    used or not — the padding waste the paged cache eliminates."""
    return slots * window * kv_token_bytes(cfg)


def kv_bytes_paged(cfg: ModelConfig, pages: int, page_size: int) -> int:
    """Paged KV footprint priced per page (DESIGN.md §13): ``pages``
    mapped pages of ``page_size`` tokens (the reserved null page is
    shared and free)."""
    return pages * page_size * kv_token_bytes(cfg)


def estimate_qos(cfg: ModelConfig, plan: PrecisionPlan,
                 hw: HardwareModel = HardwareModel(),
                 batch_size: int = 1, profile=None) -> QoSEstimate:
    """Decode-regime tokens/s for one replica under the plan."""
    e = cfg.moe
    assert e is not None, "QoS planner applies to MoE archs (DESIGN.md §5)"
    hit, miss_bytes = expert_access_stats(cfg, plan)

    # compute: read every active weight byte once per token (memory-bound
    # decode); a rung-``b`` expert reads b/16 of the bytes, sped up by the
    # fused kernel's rung speedup. The 16-bit fraction is the REMAINDER
    # (1 - sum of quantized fractions) so the binary ladder reproduces the
    # historical ``(1 - frac4) * s16`` term bit-for-bit.
    s16 = cfg.expert_param_bytes(16)
    per_active = 0.0
    frac_q_sum = 0.0
    for b in quantized_rungs(plan.ladder):
        frac = float((plan.bits == b).mean())
        per_active += frac * cfg.expert_param_bytes(b) \
            / hw.q_speedup_decode(b) * (16 / b)
        frac_q_sum += frac
    per_active += (1 - frac_q_sum) * s16
    active_expert_bytes = cfg.num_layers * e.top_k * per_active
    weight_bytes = cfg.non_expert_bytes() + active_expert_bytes
    t_compute = weight_bytes / (hw.hbm_bw * hw.mbu)
    if hw.kernel_launch_s > 0.0:
        # dispatch overhead (DESIGN.md §13): n_rungs launches per layer
        # under the grouped kernel vs one per resident expert looped.
        # Gated on the default 0.0 so the historical model (and the
        # frontier golden fixture) is untouched bit-for-bit.
        t_compute += ffn_kernel_launches(plan, hw.grouped_ffn) \
            * hw.kernel_launch_s

    t_transfer = miss_bytes / hw.host_link_bw
    # EP peer tier (DESIGN.md §16): PEER accesses move token activations
    # over the inter-device link (all2all), synchronous on the decode
    # critical path — never hidden by the host-transfer overlap window.
    # Both terms are exactly 0.0 when the plan has no PEER experts, so
    # t_token below reproduces the historical sum bit-for-bit (golden
    # fixture pinned).
    _, peer_bytes, peer_layers = peer_access_stats(cfg, plan)
    t_peer = peer_bytes / hw.interconnect_bw \
        + peer_layers * hw.all2all_latency_s
    # async overlap (DESIGN.md §12): only the transfer time the pipeline
    # cannot hide under compute reaches the token critical path; at
    # overlap_efficiency == 0 this is exactly the additive paper model.
    t_exposed = max(0.0, t_transfer - hw.overlap_efficiency * t_compute)
    t_token = t_compute + t_peer + t_exposed
    # speculative decode (DESIGN.md §17): a cycle of spec_k all-lowest-
    # rung draft steps plus ONE verify forward at the serving plan
    # (t_token — the verify is the plain decode step batched over k+1
    # positions; decode is weight-bound, so scoring extra positions is
    # ~free) emits E = (1 - a^(k+1)) / (1 - a) tokens in expectation.
    # Gated on the spec_k=0 default so the historical token time — and
    # the frontier golden fixture — is untouched bit-for-bit.
    t_draft = 0.0
    spec_tokens = 1.0
    if hw.spec_k > 0:
        t_draft = draft_token_time(cfg, plan, hw)
        spec_tokens = speculative_tokens_per_cycle(hw.spec_k,
                                                   hw.spec_acceptance)
        t_token = (hw.spec_k * t_draft + t_token) / spec_tokens
    return QoSEstimate(
        tokens_per_s=batch_size / t_token,
        t_compute_ms=t_compute * 1e3,
        t_transfer_ms=t_transfer * 1e3,
        t_exposed_ms=t_exposed * 1e3,
        t_peer_ms=t_peer * 1e3,
        t_draft_ms=t_draft * 1e3,
        spec_tokens_per_cycle=spec_tokens,
        hit_rate=hit,
        device_bytes=device_bytes(cfg, plan),
        quality_proxy=quality_proxy(cfg, plan, profile),
    )


def pareto_frontier(points: Sequence[Tuple[float, float]]) -> List[int]:
    """Indices of the Pareto-optimal (throughput UP, quality_proxy DOWN)."""
    idx = sorted(range(len(points)), key=lambda i: (-points[i][0], points[i][1]))
    out, best_q = [], float("inf")
    for i in idx:
        if points[i][1] < best_q - 1e-12:
            out.append(i)
            best_q = points[i][1]
    return sorted(out)
