"""Per-expert quantization sensitivity + traffic-weighted quality
objective (DESIGN.md §15).

The flat ``RUNG_QUALITY_COST`` table prices every expert's quality loss
identically, so the planner assigns rungs by balanced permutation — the
paper's uniform-random choice. In reality per-expert sensitivity varies
by an order of magnitude (MxMoE, arXiv 2505.05799) and routing traffic
is far from uniform, so the *measured* quality loss of a plan is

    quality_cost(plan) = sum_{l,e} freq[l,e] * sens[l,e, bits[l,e]]

with ``freq`` the (normalized) routing frequency and ``sens`` the
activation-weighted relative RMSE each rung inflicts on that expert's
FFN output. This module provides

* :func:`calibrate_sensitivity` — the offline calibration pass: run a
  small seeded token batch through the model eagerly, capture every MoE
  layer's router inputs (``capture_moe_inputs``), replay the captured
  tokens through each expert's FFN at every ladder rung in float32
  numpy, and score ``sens[l, e, b]`` as the router-probability-weighted
  relative RMSE vs the 16-bit output. Deterministic per seed —
  byte-identical :class:`SensitivityProfile` serialization is a CI
  acceptance.
* :class:`SensitivityProfile` — the serializable artifact. A *uniform*
  profile (every expert priced at ``RUNG_QUALITY_COST``, uniform freq)
  makes ``quality_cost`` collapse to the legacy rung-fraction sum, and
  ``cost_model.quality_proxy`` short-circuits to the historical code
  path in that case so the frontier golden fixture stays bit-identical
  (the §11.4 compat guarantee extended to §15).

Serialization uses ``float.hex()`` (lossless, locale-independent) with
sorted keys and fixed layout, so equal profiles are equal *bytes*.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import RUNG_QUALITY_COST
from repro.core.precision_plan import PrecisionPlan, quantized_rungs

__all__ = ["SensitivityProfile", "calibrate_sensitivity"]

#: floor for the reference-output energy in the relative-RMSE denominator
_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class SensitivityProfile:
    """Per-(layer, expert) quality prices + routing frequencies.

    ``sens`` maps each QUANTIZED ladder rung to a ``[L, E]`` float64
    array (16-bit costs 0 by definition and is not stored); ``freq`` is
    a ``[L, E]`` float64 array normalized to sum to 1.
    """
    ladder: Tuple[int, ...]
    sens: Dict[int, np.ndarray]
    freq: np.ndarray

    # -- constructors ------------------------------------------------------
    @classmethod
    def uniform(cls, cfg: ModelConfig,
                ladder: Optional[Tuple[int, ...]] = None
                ) -> "SensitivityProfile":
        """The profile equivalent to the legacy flat table: every expert
        priced at ``RUNG_QUALITY_COST[b]``, uniform traffic."""
        assert cfg.moe is not None
        ladder = tuple(ladder if ladder is not None else cfg.mop.precision_ladder)
        shape = (cfg.num_layers, cfg.moe.num_experts)
        sens = {int(b): np.full(shape, RUNG_QUALITY_COST[int(b)], np.float64)
                for b in quantized_rungs(ladder)}
        freq = np.full(shape, 1.0 / (shape[0] * shape[1]), np.float64)
        return cls(ladder=ladder, sens=sens, freq=freq)

    # -- queries -----------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return tuple(self.freq.shape)  # type: ignore[return-value]

    def is_uniform(self) -> bool:
        """True iff this profile is *exactly* the legacy flat objective:
        every quantized rung priced at the constant ``RUNG_QUALITY_COST``
        and traffic exactly uniform. ``cost_model.quality_proxy`` uses
        this to short-circuit to the bit-identical historical formula."""
        n = self.freq.size
        if not bool((self.freq == 1.0 / n).all()):
            return False
        for b, s in self.sens.items():
            if b not in RUNG_QUALITY_COST:
                return False
            if not bool((s == RUNG_QUALITY_COST[b]).all()):
                return False
        return True

    def quality_cost(self, plan: PrecisionPlan) -> float:
        """Traffic-weighted quality loss of ``plan``:
        ``sum_{l,e} freq[l,e] * sens[l,e, bits[l,e]]`` (16-bit rungs are
        free). With a uniform profile this equals the legacy
        ``sum_b RUNG_QUALITY_COST[b] * frac_b`` mathematically (the
        bitwise guarantee lives in the quality_proxy short-circuit)."""
        total = 0.0
        for b in quantized_rungs(plan.ladder):
            s = self.sens.get(int(b))
            if s is None:
                # rung outside the calibrated ladder: legacy flat price
                total += RUNG_QUALITY_COST[int(b)] \
                    * float((plan.bits == b).mean())
                continue
            total += float((self.freq * s * (plan.bits == b)).sum())
        return total

    def with_freq(self, freq: np.ndarray) -> "SensitivityProfile":
        """Same sensitivities, new traffic weights (normalized to sum 1;
        an all-zero histogram keeps the current weights). The dynamic
        controller folds the engine's measured routing histogram in
        through this."""
        freq = np.asarray(freq, np.float64)
        if freq.shape != self.freq.shape:
            raise ValueError(f"freq shape {freq.shape} != {self.freq.shape}")
        tot = float(freq.sum())
        if tot <= 0.0:
            return self
        return dataclasses.replace(self, freq=freq / tot)

    # -- serialization (byte-deterministic) --------------------------------
    def to_json_bytes(self) -> bytes:
        obj = {
            "ladder": [int(b) for b in self.ladder],
            "shape": [int(d) for d in self.freq.shape],
            "freq": [v.hex() for v in self.freq.ravel().tolist()],
            "sens": {str(int(b)): [v.hex() for v in s.ravel().tolist()]
                     for b, s in sorted(self.sens.items())},
        }
        return (json.dumps(obj, sort_keys=True, indent=1) + "\n").encode()

    def save(self, path) -> None:
        Path(path).write_bytes(self.to_json_bytes())

    @classmethod
    def load(cls, path) -> "SensitivityProfile":
        obj = json.loads(Path(path).read_text())
        shape = tuple(obj["shape"])
        parse = np.vectorize(float.fromhex, otypes=[np.float64])

        def arr(vals):
            return parse(np.asarray(vals, dtype=object)).reshape(shape)

        return cls(ladder=tuple(obj["ladder"]),
                   sens={int(b): arr(v) for b, v in obj["sens"].items()},
                   freq=arr(obj["freq"]))


# ---------------------------------------------------------------------------
# Offline calibration
# ---------------------------------------------------------------------------

def _silu(x: np.ndarray) -> np.ndarray:
    return x * (1.0 / (1.0 + np.exp(-x)))


def _ffn(x: np.ndarray, w: Dict[str, np.ndarray]) -> np.ndarray:
    """The expert swiglu FFN in float32 numpy (mirrors layers.ffn)."""
    return (_silu(x @ w["w_gate"]) * (x @ w["w_up"])) @ w["w_down"]


def calibrate_sensitivity(cfg: ModelConfig, params, *, seed: int = 0,
                          batch_size: int = 2, seq_len: int = 32,
                          ladder: Optional[Tuple[int, ...]] = None,
                          group_size: Optional[int] = None,
                          anchor: bool = True) -> SensitivityProfile:
    """Offline calibration pass (DESIGN.md §15).

    Runs a seeded token batch through ``loss_fn`` EAGERLY (capture only
    works unjitted), captures each MoE layer's ``(x, probs)``, then for
    every (layer, expert, quantized rung) computes the activation-
    weighted relative RMSE of the expert's FFN output under
    quantize->dequantize at that rung:

        sens = sqrt( sum_t p_t ||y16_t - yb_t||^2
                     / max(sum_t p_t ||y16_t||^2, eps) )

    with ``p_t = probs[t, e]`` — tokens the router would send to the
    expert dominate its score. ``freq[l, e]`` is the summed router
    probability mass, normalized globally.

    ``anchor=True`` rescales each rung's scores so their mean equals
    ``RUNG_QUALITY_COST[b]``: the profile then lives on the same
    perplexity-multiplier scale as the legacy table, so existing
    ``max_quality_loss`` targets keep their meaning while the *relative*
    per-expert prices become data-driven. Deterministic per seed: same
    (cfg, params, seed, sizes) => byte-identical profile.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import mixed_moe
    from repro.core.quantization import dequantize, quantize
    from repro.models.model import build_model

    assert cfg.moe is not None, "sensitivity calibration needs a MoE arch"
    ladder = tuple(ladder if ladder is not None else cfg.mop.precision_ladder)
    gs = int(group_size if group_size is not None else cfg.mop.group_size)
    num_layers, num_experts = cfg.num_layers, cfg.moe.num_experts

    rng = np.random.default_rng(seed)
    tokens = rng.integers(1, cfg.vocab_size,
                          size=(batch_size, seq_len), dtype=np.int32)
    labels = rng.integers(1, cfg.vocab_size,
                          size=(batch_size, seq_len), dtype=np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}

    # scan_layers would trace the layer body (capture sees only tracers);
    # the unrolled python loop is numerically identical and runs eagerly.
    model = build_model(dataclasses.replace(cfg, scan_layers=False))
    with mixed_moe.capture_moe_inputs() as captured:
        model.loss_fn(params, batch)   # eager: capture sees concrete arrays
    if len(captured) != num_layers:
        raise RuntimeError(
            f"captured {len(captured)} MoE layers, expected {num_layers} "
            f"— calibration assumes every layer is MoE")

    moe_p = params["layers"]["moe"]
    q_rungs = [int(b) for b in quantized_rungs(ladder)]
    sens = {b: np.zeros((num_layers, num_experts), np.float64)
            for b in q_rungs}
    freq = np.zeros((num_layers, num_experts), np.float64)

    for li in range(num_layers):
        x, probs = captured[li]                      # (T, d), (T, E)
        x = x.astype(np.float64)
        for ei in range(num_experts):
            w16 = {k: np.asarray(moe_p[k][li, ei], np.float32)
                   .astype(np.float64)
                   for k in ("w_gate", "w_up", "w_down")}
            p = probs[:, ei].astype(np.float64)      # (T,)
            freq[li, ei] = float(p.sum())
            y16 = _ffn(x, w16)
            ref = float((p * (y16 ** 2).sum(axis=-1)).sum())
            for b in q_rungs:
                wq = {k: np.asarray(
                    dequantize(quantize(jnp.asarray(v, jnp.float32), b, gs)),
                    np.float32).astype(np.float64)
                    for k, v in w16.items()}
                yb = _ffn(x, wq)
                err = float((p * ((y16 - yb) ** 2).sum(axis=-1)).sum())
                sens[b][li, ei] = float(np.sqrt(err / max(ref, _EPS)))

    tot = float(freq.sum())
    freq = freq / tot if tot > 0 else np.full_like(freq, 1.0 / freq.size)
    if anchor:
        for b in q_rungs:
            mean = float(sens[b].mean())
            if mean > 0:
                sens[b] = sens[b] * (RUNG_QUALITY_COST[b] / mean)
    return SensitivityProfile(ladder=ladder, sens=sens, freq=freq)
