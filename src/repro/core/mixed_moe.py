"""Mixed-precision MoE layer: N expert banks (one per ladder rung, e.g.
int4 | int8 | bf16) + explicit expert-parallel dispatch under shard_map.

The paper's partial expert quantization turns each MoE layer into per-rung
banks — ``q4`` (packed int4 + scales), ``q8`` (int8 + scales) and ``f16``
(bf16) — in ASCENDING-bits bank order, with a per-layer expert permutation
mapping routed ids into bank slots (``PrecisionPlan.expert_order``). Bank
sizes are static per plan — one recompile per ladder-rung-count signature
(``PrecisionPlan.bank_sizes()``), placement changes are graph-free. The
binary ladder degenerates to the historical dual-bank ``[q4 | f16]``
layout bit-for-bit (DESIGN.md §11).

Dispatch (DESIGN.md §4) runs under shard_map over (dp..., model):

  * routing (tiny matmul) happens at jit level, sharded over dp;
  * **EP** (num_experts >= model-axis size, e.g. Kimi 384e/16): experts are
    sharded over ``model``; every rank selects the assignments that hit its
    local experts, packs them into a capacity-bounded (E_loc, C, d) buffer
    (sort + scatter — all local ops), runs the dual-bank FFN, scatters back
    weighted outputs, and one psum over ``model`` combines the per-rank
    contributions. Activations stay replicated over ``model``;
  * **TP** (num_experts < model-axis size, e.g. Mixtral 8e/16): every rank
    holds all experts on a 1/16 slice of d_ff; same local dispatch with all
    experts local; the identical psum now reduces partial down-projections.

Both paths cost exactly one (T_loc, d) all-reduce per MoE layer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.core.quantization import QTensor, dequantize, quantize


# --------------------------------------------------------------------------
# Routing (jit level)
# --------------------------------------------------------------------------

_TRACE = __import__("threading").local()


class capture_routing:
    """Collect concrete routing ids from eager (unjitted) forwards —
    benchmarks/cache_sim.py uses this to test the paper's uniform-access
    assumption on a *trained* router."""

    def __enter__(self):
        _TRACE.ids = []
        return _TRACE.ids

    def __exit__(self, *exc):
        _TRACE.ids = None


class capture_moe_inputs:
    """Collect each MoE layer's router inputs from eager forwards: one
    ``(x (T,d) f32, probs (T,E) f32)`` pair per layer, in layer order.
    The sensitivity calibration pass (core/sensitivity.py, DESIGN.md
    §15) replays the captured tokens through each expert's FFN at every
    ladder rung to measure activation-weighted quantization error."""

    def __enter__(self):
        _TRACE.moe = []
        return _TRACE.moe

    def __exit__(self, *exc):
        _TRACE.moe = None


def route(router_w: jax.Array, x: jax.Array, moe: MoEConfig, *,
          train: bool) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """x: (T, d) -> (weights (T,k) f32, ids (T,k) i32, aux losses)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, ids = jax.lax.top_k(probs, moe.top_k)
    trace = getattr(_TRACE, "ids", None)
    if trace is not None and not isinstance(ids, jax.core.Tracer):
        trace.append(np.asarray(ids))
    moe_trace = getattr(_TRACE, "moe", None)
    if moe_trace is not None and not isinstance(probs, jax.core.Tracer):
        moe_trace.append((np.asarray(x, np.float32),
                          np.asarray(probs, np.float32)))
    weights = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    aux: Dict[str, jax.Array] = {}
    if train:
        e = moe.num_experts
        # Switch-style load-balance: E * sum_e f_e * P_e
        dispatch = jax.nn.one_hot(ids, e, dtype=jnp.float32).sum(1)  # (T,E)
        f_e = dispatch.mean(0)
        p_e = probs.mean(0)
        aux["load_balance"] = moe.load_balance_loss * e * jnp.sum(f_e * p_e)
        lse = jax.nn.logsumexp(logits, axis=-1)
        aux["router_z"] = moe.router_z_loss * jnp.mean(lse ** 2)
    return weights, ids, aux


# --------------------------------------------------------------------------
# Local dispatch (inside shard_map): sort -> capacity scatter -> FFN ->
# weighted combine. Everything here is per-device.
# --------------------------------------------------------------------------

def _bank_bits(name: str) -> int:
    """Bank key -> bit-width: 'f16' -> 16, 'qN' -> N."""
    return 16 if name == "f16" else int(name[1:])


def _bank_name(bits: int) -> str:
    return "f16" if bits >= 16 else f"q{bits}"


def bank_keys(banks) -> list:
    """Non-empty bank keys in ascending-bits BANK ORDER (the expert
    storage order: cheapest rung first — binary: ['q4', 'f16'])."""
    return sorted((k for k in banks if banks.get(k) is not None),
                  key=_bank_bits)


def _local_slot(flat_e, *, rank, totals, locs):
    """Map global (permuted) expert ids to this rank's local bank slots.

    ``totals``/``locs`` are per-bank global/per-rank expert counts in
    bank order. Each bank is sharded over the EP axis independently:
    within bank b (global offset O_b), rank r owns experts
    [O_b + r*loc_b, O_b + (r+1)*loc_b) -> local slots
    [sum(loc_<b), sum(loc_<b) + loc_b). Returns (slot, is_local)."""
    slot = jnp.zeros_like(flat_e)
    ok = jnp.zeros(flat_e.shape, bool)
    g_off = l_off = 0
    for tot, loc in zip(totals, locs):
        rel = flat_e - g_off - rank * loc
        in_bank = (flat_e >= g_off) & (flat_e < g_off + tot)
        bank_ok = in_bank & (rel >= 0) & (rel < loc)
        slot = jnp.where(bank_ok, l_off + rel, slot)
        ok = ok | bank_ok
        g_off += tot
        l_off += loc
    return slot, ok


def _dispatch_local(x, ids, weights, *, rank, totals, locs, capacity):
    """Pack routed tokens into (e_loc, capacity, d); returns buffers +
    metadata needed for the combine."""
    t, d = x.shape
    e_loc = sum(locs)
    k = ids.shape[1]
    flat_e = ids.reshape(-1)                                  # (T*k,)
    flat_w = weights.reshape(-1)
    local_e, is_local = _local_slot(flat_e, rank=rank, totals=totals,
                                    locs=locs)
    key = jnp.where(is_local, local_e, e_loc)
    order = jnp.argsort(key, stable=True)                     # (T*k,)
    sorted_e = key[order]
    counts = jnp.bincount(sorted_e, length=e_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)])[:-1]
    pos = jnp.arange(t * k) - starts[sorted_e]
    valid = (sorted_e < e_loc) & (pos < capacity)
    dest = jnp.where(valid, sorted_e * capacity + pos, e_loc * capacity)
    tok = order // k
    xbuf = jnp.zeros((e_loc * capacity, d), x.dtype)
    xbuf = xbuf.at[dest].set(x[tok], mode="drop")
    return xbuf.reshape(e_loc, capacity, d), dest, tok, flat_w[order]


def _combine_local(ybuf, dest, tok, w_sorted, t, d):
    flat = ybuf.reshape(-1, ybuf.shape[-1])
    contrib = jnp.take(flat, dest, axis=0, mode="fill", fill_value=0)
    contrib = contrib * w_sorted[:, None].astype(contrib.dtype)
    return jnp.zeros((t, d), ybuf.dtype).at[tok].add(contrib)


# --------------------------------------------------------------------------
# N-bank expert FFN (one bank per ladder rung, ascending-bits order)
# --------------------------------------------------------------------------

def _ffn_bf16(bank, xb, act, use_kernel: bool = False):
    """(E, C, d) x (E, d, f) -> (E, C, d).

    ``use_kernel=True`` routes through the grouped bf16 Pallas kernel
    (one launch for the whole f16 bank — DESIGN.md §13); numerics are
    allclose to the einsum (f32 accumulation either way), not bitwise."""
    if use_kernel:
        from repro.kernels.ops import grouped_bf16_matmul
        mm = grouped_bf16_matmul
    else:
        mm = functools.partial(jnp.einsum, "ecd,edf->ecf")
    up = mm(xb, bank["w_up"])
    if act == "swiglu":
        h = jax.nn.silu(mm(xb, bank["w_gate"])) * up
    elif act == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        h = jnp.square(jax.nn.relu(up))
    return mm(h, bank["w_down"])


def _ffn_q(bank, xb, act, use_kernel: bool):
    """Quantized bank: fused Pallas kernel (serving) or dequant reference
    (dry-run lowering — FLOP/byte-equivalent, see kernels/ops.py)."""
    if use_kernel:
        from repro.kernels.ops import q_expert_matmul
        up = q_expert_matmul(xb, bank["w_up"])
        if act == "swiglu":
            h = jax.nn.silu(q_expert_matmul(xb, bank["w_gate"])) * up
        elif act == "gelu":
            h = jax.nn.gelu(up, approximate=True)
        else:
            h = jnp.square(jax.nn.relu(up))
        return q_expert_matmul(h, bank["w_down"])
    deq = {k: dequantize(v) for k, v in bank.items()}
    return _ffn_bf16(deq, xb, act)


def _expert_ffn(banks, xb, act, use_kernel):
    """banks: {"q4"|"q8": {...QTensor...}|None, "f16": {...bf16...}|None}
    with expert storage in ascending-bits bank order along E (quantized
    rungs first); ``xb`` is sliced per bank accordingly.

    With ``use_kernel`` each rung's whole bank is ONE grouped kernel
    launch (expert-group grid axis, dequant in VMEM — DESIGN.md §13), so
    the decode FFN dispatches n_rungs kernels regardless of expert count
    instead of one per expert."""
    outs = []
    off = 0
    for key in bank_keys(banks):
        bank = banks[key]
        n = bank["w_up"].shape[0]
        if not n:
            continue
        sl = xb[off:off + n]
        if _bank_bits(key) < 16:
            outs.append(_ffn_q(bank, sl, act, use_kernel))
        else:
            outs.append(_ffn_bf16(bank, sl, act, use_kernel))
        off += n
    return jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]


# --------------------------------------------------------------------------
# The shard_map'd MoE apply
# --------------------------------------------------------------------------

# Token-gather pays only while the gathered activations stay ~cache-scale;
# above this the dispatch-buffer amplification dominates (see moe_apply).
TOKEN_GATHER_MAX_BYTES = 64 << 20


@dataclasses.dataclass(frozen=True)
class MoEParallelism:
    mesh: Any                      # jax Mesh
    dp_axes: Tuple[str, ...]       # token axes ("pod","data") / ("data",)
    ep_axis: str = "model"
    # Second weight-sharding axis for EP banks (ZeRO/FSDP dimension): the
    # d_ff dim of every expert is sharded over it. Token-gather dispatch
    # (below) keeps the weights fully sharded and moves ACTIVATIONS over
    # this axis instead — 1T-scale experts never cross the wire.
    fsdp_axis: Optional[str] = None

    @property
    def ep_size(self) -> int:
        return self.mesh.shape[self.ep_axis]

    @property
    def fsdp_size(self) -> int:
        if self.fsdp_axis is None or self.fsdp_axis not in self.mesh.shape:
            return 1
        return self.mesh.shape[self.fsdp_axis]


def _fsdp_active(banks, moe: MoEConfig, par: MoEParallelism, ep: bool):
    """Token-gather EP applies when experts are also d_ff-sharded over the
    fsdp axis (kimi-1T: (E/16 on model) x (f/16 on data) per device)."""
    if not ep or par.fsdp_size <= 1:
        return False
    fs = par.fsdp_size

    def ok(leaf_shape, fdim):
        return leaf_shape[fdim] % fs == 0

    for key in bank_keys(banks):
        b = banks[key]
        for name, w in b.items():
            arr = w.q if isinstance(w, QTensor) else w
            fdim = 1 if name == "w_down" else 2
            if not ok(arr.shape, fdim):
                return False
            if isinstance(w, QTensor) and w.scales.shape[fdim] % fs:
                return False
    return True


def _bank_specs(banks, moe: MoEConfig, par: MoEParallelism,
                fsdp: bool = False):
    """PartitionSpecs for the bank pytree: EP shards the leading E dim
    (+ d_ff over the fsdp axis in token-gather mode), TP shards the d_ff
    dim (dim 2 for up/gate & their scales, dim 1 for down & its scales)."""
    ep = moe.num_experts >= par.ep_size
    fx = par.fsdp_axis if fsdp else None

    def spec_for(path, leaf):
        if ep:
            is_down = "w_down" in path
            return P(par.ep_axis, fx, None) if is_down \
                else P(par.ep_axis, None, fx)
        is_down = "w_down" in path
        return P(None, par.ep_axis, None) if is_down \
            else P(None, None, par.ep_axis)

    def walk(tree, path=""):
        if isinstance(tree, QTensor):
            return QTensor(q=spec_for(path, tree.q),
                           scales=spec_for(path, tree.scales),
                           bits=tree.bits, group_size=tree.group_size)
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if tree is None:
            return None
        return spec_for(path, tree)

    return walk(banks), ep


def moe_apply(banks, x: jax.Array, weights: jax.Array, ids: jax.Array,
              moe: MoEConfig, par: MoEParallelism, *, act: str = "swiglu",
              use_kernel: bool = False,
              capacity: Optional[int] = None) -> jax.Array:
    """x: (T, d) sharded over dp_axes; returns (T, d) same sharding.

    ``banks`` is either the train layout {"f16": {...(E,d,f) bf16...}} or
    the rung-keyed serve layout {"q4": ..., "q8": ..., "f16": ...}
    (bank order = ascending bits, cheapest rung first).

    ``capacity`` overrides the capacity-factor formula with an explicit
    per-expert slot count (callers that must be drop-free — e.g. the
    speculative verify forward, DESIGN.md §17 — pass ``>= T`` so no
    routed assignment can be displaced).
    """
    t, d = x.shape
    ep = moe.num_experts >= par.ep_size
    fsdp = _fsdp_active(banks, moe, par, ep)
    if fsdp:
        # Regime split (§Perf kimi iterations 1-2): token-gather wins when
        # the gathered token set is small (decode: MBs vs the layer's GBs
        # of expert weights — measured 257x less wire). At train/prefill
        # token counts the gathered-x + amplified dispatch buffers blow
        # HBM (measured: kimi prefill peak 45 -> 322 GiB), so the weights
        # are gathered once per layer instead (ZeRO-3) and amortized over
        # the whole microbatch.
        n_dp_pre = int(np.prod([par.mesh.shape[a] for a in par.dp_axes]))
        t_disp_pre = (t // n_dp_pre) * par.fsdp_size
        fsdp = t_disp_pre * d * 2 <= TOKEN_GATHER_MAX_BYTES
    bank_specs, _ = _bank_specs(banks, moe, par, fsdp=fsdp)
    lead = par.dp_axes if len(par.dp_axes) > 1 else \
        (par.dp_axes[0] if par.dp_axes else None)
    dp = P(lead, None)
    n_dp = int(np.prod([par.mesh.shape[a] for a in par.dp_axes]))
    t_loc = t // n_dp
    keys = bank_keys(banks)
    totals = tuple(banks[k]["w_up"].shape[0] for k in keys)
    shards = par.ep_size if ep else 1
    if any(tot % shards for tot in totals):
        raise ValueError(
            f"EP banks must split evenly: "
            f"{dict(zip(keys, totals))} over {shards} shards "
            f"(planner rounds per-layer counts)")
    locs = tuple(tot // shards for tot in totals)
    # Token-gather mode: the fsdp axis contributes its tokens instead of
    # its weight shards (§Perf 'kimi-decode' iteration: for 1T-scale
    # experts, tokens are ~4 orders of magnitude lighter than weights).
    t_disp = t_loc * (par.fsdp_size if fsdp else 1)
    # static per-shard capacity (tokens replicated over model: each rank
    # sees all dispatched assignments, keeps only its local experts' share)
    if capacity is None:
        cap = int(np.ceil(t_disp * moe.top_k * moe.capacity_factor
                          / moe.num_experts))
    else:
        cap = int(capacity)
    cap = max(4, ((cap + 3) // 4) * 4)

    def local_fn(banks_l, x_l, w_l, ids_l):
        rank = jax.lax.axis_index(par.ep_axis) if ep else 0
        if fsdp:
            # tokens in, weights stationary: gather the fsdp axis's token
            # shards; every rank computes its (E_loc x f_loc) weight slice
            # for ALL gathered tokens.
            x_l = jax.lax.all_gather(x_l, par.fsdp_axis, axis=0, tiled=True)
            w_l = jax.lax.all_gather(w_l, par.fsdp_axis, axis=0, tiled=True)
            ids_l = jax.lax.all_gather(ids_l, par.fsdp_axis, axis=0,
                                       tiled=True)
        xbuf, dest, tok, w_sorted = _dispatch_local(
            x_l, ids_l, w_l, rank=rank, totals=totals, locs=locs,
            capacity=cap)
        # the expert FFN is shape-polymorphic in f: gate/up/silu are
        # elementwise on this rank's f-slice, w_down yields partial sums
        ybuf = _expert_ffn(banks_l, xbuf, act, use_kernel)
        y = _combine_local(ybuf, dest, tok, w_sorted, t_disp, d)
        if fsdp:
            # partial over d_ff shards AND scattered back to this rank's
            # token shard in one collective
            y = jax.lax.psum_scatter(y, par.fsdp_axis, scatter_dimension=0,
                                     tiled=True)
        return jax.lax.psum(y, par.ep_axis)

    if hasattr(jax, "shard_map"):                    # jax >= 0.6
        fn = jax.shard_map(
            local_fn,
            mesh=par.mesh,
            in_specs=(bank_specs, dp, dp, dp),
            out_specs=dp,
            check_vma=False,
        )
    else:                                            # 0.4.x compat
        from jax.experimental.shard_map import shard_map as _shard_map
        fn = _shard_map(
            local_fn,
            mesh=par.mesh,
            in_specs=(bank_specs, dp, dp, dp),
            out_specs=dp,
            check_rep=False,
        )
    return fn(banks, x, weights, ids)


# --------------------------------------------------------------------------
# Bank construction from a PrecisionPlan (serve) or plain params (train)
# --------------------------------------------------------------------------

def train_banks(moe_params: Dict[str, jax.Array]) -> Dict[str, Any]:
    return {"q4": None,
            "f16": {k: moe_params[k] for k in ("w_gate", "w_up", "w_down")}}


def build_ladder_banks(moe_params: Dict[str, jax.Array], bits_row,
                       *, ladder=(16, 4), group_size: int = 64):
    """Split one layer's experts into per-rung banks in ascending-bits
    bank order (DESIGN.md §11).

    ``bits_row``: (E,) int — each expert's ladder rung. Returns
    (banks, order) where ``order`` is the expert permutation (cheapest
    rung first) — the caller permutes the router columns with it. Every
    ladder rung gets a bank key (``None`` when empty) so per-layer bank
    pytrees stack cleanly across a balanced plan."""
    bits_row = np.asarray(bits_row)
    rungs = sorted(ladder)
    order = np.concatenate(
        [np.where(bits_row == b)[0] for b in rungs]).astype(np.int32)
    perm = {k: jnp.take(moe_params[k], order, axis=0)
            for k in ("w_gate", "w_up", "w_down")}
    banks: Dict[str, Any] = {}
    off = 0
    for b in rungs:
        cnt = int((bits_row == b).sum())
        name = _bank_name(b)
        if cnt == 0:
            banks[name] = None
            continue
        sl = {k: v[off:off + cnt] for k, v in perm.items()}
        banks[name] = sl if b >= 16 else \
            {k: quantize(v, b, group_size) for k, v in sl.items()}
        off += cnt
    return banks, order


def build_mixed_banks(moe_params: Dict[str, jax.Array], quant_mask,
                      *, bits: int = 4, group_size: int = 64):
    """Legacy binary spelling of :func:`build_ladder_banks`:
    quant_mask (E,) bool -> [q4 | f16] banks, quantized first."""
    quant_mask = np.asarray(quant_mask).astype(bool)
    bits_row = np.where(quant_mask, bits, 16)
    return build_ladder_banks(moe_params, bits_row, ladder=(16, bits),
                              group_size=group_size)


def moe_dense_ref(moe_params, x, moe: MoEConfig, act: str = "swiglu"):
    """O(T*E) oracle: every expert computes every token (tests only)."""
    weights, ids, _ = route(moe_params["router"], x, moe, train=False)
    w_full = jnp.zeros((x.shape[0], moe.num_experts), jnp.float32)
    w_full = jax.vmap(lambda w, i, row: row.at[i].add(w))(
        weights, ids, w_full)
    banks = {"w_gate": moe_params["w_gate"], "w_up": moe_params["w_up"],
             "w_down": moe_params["w_down"]}
    y_all = _ffn_bf16(banks, jnp.broadcast_to(
        x[None], (moe.num_experts,) + x.shape), act)       # (E, T, d)
    return jnp.einsum("etd,te->td", y_all.astype(jnp.float32), w_full
                      ).astype(x.dtype)
