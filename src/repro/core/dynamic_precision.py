"""Online hotness-driven rung promotion/demotion (DESIGN.md §15).

The offline :class:`~repro.core.sensitivity.SensitivityProfile` prices
quality per (layer, expert); the engine's routing histogram says where
traffic actually lands. This controller closes the loop between decode
iterations (Dynamic Expert Quantization, arXiv 2511.15015):

1. **window** — diff the engine's accumulated ``route_counts`` against
   the last snapshot; an empty window is a no-op;
2. **EMA fold** — ``ema = decay * ema + (1 - decay) * window_freq``,
   then ``profile = profile.with_freq(ema)`` so the quality objective
   re-weights toward measured traffic while old evidence ages out;
3. **swap search** — per layer, consider swapping the rungs of an
   expert pair at DIFFERENT rungs but the SAME placement (both
   device-resident or both offloaded): a swap keeps every per-layer
   rung count, every location, and hence the exact byte budget — it
   only moves WHICH expert pays the quantization tax. The gain of
   giving hot-and-sensitive expert *i* (low rung) cold expert *j*'s
   high rung is

       gain = (freq_i * sens[b_lo][i] + freq_j * sens[b_hi][j])
            - (freq_i * sens[b_hi][i] + freq_j * sens[b_lo][j])

   i.e. the measured quality-cost reduction under the traffic-weighted
   objective;
4. **hysteresis** — a swap only applies when its gain clears
   ``margin`` × the plan's current quality cost, and neither expert
   flipped within the last ``min_dwell_steps`` controller steps; at
   most ``max_swaps_per_step`` swaps apply per step. Under alternating
   hotness the EMA + margin + dwell guards keep the plan still
   (no flip-flapping — tested);
5. **apply** — ``engine.apply_bits_update()`` (diff-only: banks rebuilt
   in place, flipped cache entries re-staged through
   ``ExpertCache.update()`` at the exact byte delta), promotions/
   demotions mirrored into the QoS controller's
   ``rung_promotions``/``rung_demotions`` metrics, and a placement-only
   :class:`~repro.serving.multi.ReplanReport` emitted via
   ``on_report``.

Works unchanged against the real ``AdaptiveServingEngine`` and the
deterministic ``SimulatedEngine`` — both expose ``route_counts``,
``current_plan`` and ``apply_bits_update``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.sensitivity import SensitivityProfile

__all__ = ["DynamicPrecisionConfig", "DynamicPrecisionController"]


@dataclasses.dataclass(frozen=True)
class DynamicPrecisionConfig:
    #: routing-frequency EMA decay per controller step: higher = slower
    #: to chase traffic shifts, stiffer against oscillation.
    ema_decay: float = 0.8
    #: an expert that just flipped may not flip again for this many
    #: controller steps (per-expert dwell — the anti-flap guard).
    min_dwell_steps: int = 4
    #: a swap must improve the measured quality cost by at least this
    #: fraction of the plan's current cost to apply.
    margin: float = 0.10
    #: rung swaps applied per controller step, best-gain first.
    max_swaps_per_step: int = 4


class DynamicPrecisionController:
    """Fold measured routing traffic into the sensitivity profile and
    issue hysteresis-guarded in-place rung swaps (DESIGN.md §15)."""

    def __init__(self, engine, profile: SensitivityProfile,
                 config: DynamicPrecisionConfig = DynamicPrecisionConfig(),
                 metrics: Optional[Dict[str, Any]] = None,
                 tenant: str = "default",
                 on_report: Optional[Callable[[Any], None]] = None):
        self.engine = engine
        self.profile = profile
        self.config = config
        #: external metrics sink — pass ``QoSController.metrics`` to
        #: count swap promotions/demotions in the existing
        #: ``rung_promotions``/``rung_demotions`` keys.
        self.sink = metrics
        self.tenant = tenant
        self.on_report = on_report
        self.metrics: Dict[str, float] = {
            "steps": 0, "updates": 0, "swaps": 0,
            "rung_promotions": 0, "rung_demotions": 0,
            "cache_bytes_delta": 0,
        }
        self._ema: Optional[np.ndarray] = None
        self._snapshot: Optional[np.ndarray] = None
        self._step = 0
        #: controller step at which each (l, e) last flipped
        self._last_flip: Dict[Tuple[int, int], int] = {}
        #: replan reports emitted (newest last) — assertable trace
        self.reports: List[Any] = []

    # -- observability ------------------------------------------------------
    def measured_freq(self) -> Optional[np.ndarray]:
        """The EMA-folded routing frequency (None before any traffic)."""
        return self._ema

    def quality_cost_measured(self, plan=None) -> float:
        """The active plan's quality cost under the traffic-folded
        profile — the objective the swap search descends."""
        plan = plan if plan is not None else self.engine.current_plan
        return self.profile.quality_cost(plan)

    # -- the loop -----------------------------------------------------------
    def step(self) -> bool:
        """One control decision; returns True iff a bits update was
        applied. Call between decode iterations (the QoSController's
        ``dynamic=`` hook does this automatically)."""
        self._step += 1
        self.metrics["steps"] += 1
        counts = getattr(self.engine, "route_counts", None)
        plan = self.engine.current_plan
        if counts is None or plan is None:
            return False
        counts = np.asarray(counts, np.float64)
        if counts.shape != self.profile.shape:
            return False
        window = counts if self._snapshot is None \
            else counts - self._snapshot
        self._snapshot = counts.copy()
        total = float(window.sum())
        if total <= 0:
            return False
        wf = window / total
        d = float(self.config.ema_decay)
        self._ema = wf if self._ema is None else d * self._ema + (1 - d) * wf
        self.profile = self.profile.with_freq(self._ema)

        swaps = self._select_swaps(plan)
        if not swaps:
            return False
        new_bits = plan.bits.copy()
        for gain, li, i, j in swaps:
            new_bits[li, i], new_bits[li, j] = \
                new_bits[li, j], new_bits[li, i]
            self._last_flip[(li, i)] = self._step
            self._last_flip[(li, j)] = self._step
        report = self.engine.apply_bits_update(new_bits)
        self.metrics["updates"] += 1
        self.metrics["swaps"] += len(swaps)
        # each swap promotes exactly one expert and demotes one
        self.metrics["rung_promotions"] += report["promotions"]
        self.metrics["rung_demotions"] += report["demotions"]
        self.metrics["cache_bytes_delta"] += report["cache_bytes_delta"]
        if self.sink is not None:
            self.sink["rung_promotions"] = \
                self.sink.get("rung_promotions", 0) + report["promotions"]
            self.sink["rung_demotions"] = \
                self.sink.get("rung_demotions", 0) + report["demotions"]
        self._emit_report(report, swaps)
        return True

    # -- internals ----------------------------------------------------------
    def _select_swaps(self, plan) -> List[Tuple[float, int, int, int]]:
        """Best same-layer same-location rung swaps clearing the margin
        and dwell guards, greedy by gain, at most one flip per expert
        per step."""
        cfg = self.config
        sens = self.profile.sens
        freq = self.profile.freq
        floor = cfg.margin * max(self.profile.quality_cost(plan), 1e-12)
        num_layers = plan.bits.shape[0]
        candidates: List[Tuple[float, int, int, int]] = []
        for li in range(num_layers):
            bits_l = plan.bits[li]
            loc_l = plan.location[li]
            for bi, bj in _rung_pairs(plan.ladder, bits_l):
                lo = np.flatnonzero(bits_l == bi)
                hi = np.flatnonzero(bits_l == bj)
                for i in lo:
                    for j in hi:
                        if loc_l[i] != loc_l[j]:
                            continue   # swap would move device bytes
                        gain = self._swap_gain(sens, freq, li,
                                               int(i), int(j),
                                               int(bi), int(bj))
                        if gain > floor:
                            candidates.append((gain, li, int(i), int(j)))
        candidates.sort(key=lambda c: (-c[0], c[1], c[2], c[3]))
        chosen: List[Tuple[float, int, int, int]] = []
        touched: set = set()
        for gain, li, i, j in candidates:
            if len(chosen) >= cfg.max_swaps_per_step:
                break
            ki, kj = (li, i), (li, j)
            if ki in touched or kj in touched:
                continue
            if self._step - self._last_flip.get(ki, -10**9) \
                    < cfg.min_dwell_steps:
                continue
            if self._step - self._last_flip.get(kj, -10**9) \
                    < cfg.min_dwell_steps:
                continue
            chosen.append((gain, li, i, j))
            touched.update((ki, kj))
        return chosen

    @staticmethod
    def _swap_gain(sens, freq, li: int, i: int, j: int,
                   b_lo: int, b_hi: int) -> float:
        """Quality-cost reduction of giving expert ``i`` (at low rung
        ``b_lo``) expert ``j``'s high rung ``b_hi``. A 16-bit rung
        prices 0 (not stored in ``sens``)."""
        def price(b: int, e: int) -> float:
            s = sens.get(b)
            return float(freq[li, e] * s[li, e]) if s is not None else 0.0

        before = price(b_lo, i) + price(b_hi, j)
        after = price(b_hi, i) + price(b_lo, j)
        return before - after

    def _emit_report(self, report: Dict[str, Any], swaps) -> None:
        from repro.serving.multi import ReplanReport   # lazy: layering

        rr = ReplanReport(
            tenant=self.tenant,
            migrated_experts=int(report["restaged"]),
            evicted_experts=0,
            migrated_bytes=int(abs(report["cache_bytes_delta"])),
            downtime_s=0.0,
            placement_only=True,
        )
        self.reports.append(rr)
        if self.on_report is not None:
            self.on_report(rr)


def _rung_pairs(ladder, bits_l: np.ndarray):
    """(low, high) rung pairs both PRESENT in this layer's assignment,
    low < high — the swap search space."""
    present = sorted({int(b) for b in np.unique(bits_l)})
    for a in range(len(present)):
        for b in range(a + 1, len(present)):
            yield present[a], present[b]
