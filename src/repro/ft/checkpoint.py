"""Fault-tolerant checkpointing: msgpack+zstd codec, atomic commit, keep-N
retention, async save thread, and reshard-on-load for elastic rescaling.

Layout:  <dir>/step_<N>/ {manifest.json, shard_000.msgpack.zst, ...}
         <dir>/step_<N>.COMMITTED        (atomic marker, written last)

Restore never requires the saving mesh: arrays are stored unsharded
(gathered) in the manifest shards and re-placed with the *target* sharding
via jax.device_put — a checkpoint written on (16,16) restores onto
(2,16,16) or a single CPU device (tests/test_checkpoint.py proves both
directions). For 1T-scale models a production deployment would write
per-shard files; the codec layer supports that via ``shard_arrays``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # container without the wheel: stdlib fallback
    zstandard = None
    import zlib

_FLAG = "COMMITTED"


def _dtype(name: str) -> np.dtype:
    """numpy dtype by name, including ml_dtypes extras (bfloat16)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


# --------------------------------------------------------------------------
# Codec: pytree <-> bytes
# --------------------------------------------------------------------------

def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif tree is None:
        out[prefix[:-1] + "!none"] = None
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, v in flat.items():
        if key.endswith("!none"):
            key, v = key[:-5], None
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def encode_tree(tree, level: int = 3) -> bytes:
    flat = _flatten(tree)
    payload = {}
    for k, v in flat.items():
        if v is None:
            payload[k] = None
            continue
        arr = np.asarray(v)
        payload[k] = {"d": arr.dtype.name, "s": list(arr.shape),
                      "b": arr.tobytes()}
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is None:
        return zlib.compress(raw, level)
    return zstandard.ZstdCompressor(level=level).compress(raw)


def decode_tree(data: bytes):
    if data[:4] == b"\x28\xb5\x2f\xfd":        # zstd frame magic
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "zstandard module is unavailable")
        raw = zstandard.ZstdDecompressor().decompress(data)
    else:                                       # zlib fallback frame
        import zlib as _zlib
        raw = _zlib.decompress(data)
    payload = msgpack.unpackb(raw, raw=False)
    flat = {}
    for k, v in payload.items():
        if v is None:
            flat[k] = None
        else:
            flat[k] = np.frombuffer(v["b"], dtype=_dtype(v["d"])
                                    ).reshape(v["s"])
    return _unflatten(flat)


# --------------------------------------------------------------------------
# Manager
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        self.dir = Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree, *, extra: Optional[Dict] = None,
             block: bool = False):
        """Snapshot to host (synchronous gather), then commit to disk on a
        background thread (training continues during compression/IO)."""
        self.wait()                              # one in-flight save max
        host = jax.tree_util.tree_map(
            lambda x: np.asarray(x) if hasattr(x, "dtype") else x, tree)
        extra = dict(extra or {})

        def _write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            (tmp / "tree.msgpack.zst").write_bytes(encode_tree(host))
            (tmp / "manifest.json").write_text(json.dumps(
                {"step": step, "time": time.time(), "extra": extra}))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)               # atomic on POSIX
            (self.dir / f"step_{step}.{_FLAG}").touch()
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=self._guard(_write),
                                            daemon=True)
            self._thread.start()
        else:
            _write()

    def _guard(self, fn):
        def wrapped():
            try:
                fn()
            except BaseException as e:  # noqa: BLE001
                self._error = e
        return wrapped

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {err!r}")

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
            (self.dir / f"step_{s}.{_FLAG}").unlink(missing_ok=True)

    # -- restore ----------------------------------------------------------
    def all_steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1].split(".")[0])
                      for p in self.dir.glob(f"step_*.{_FLAG}"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *,
                shardings=None, target=None):
        """Load a committed checkpoint; reshard onto ``shardings`` (a pytree
        of NamedSharding matching the stored tree) — elastic restore onto a
        different mesh. ``target`` (SDS pytree) validates shapes/dtypes."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        path = self.dir / f"step_{step}"
        if not (self.dir / f"step_{step}.{_FLAG}").exists():
            raise FileNotFoundError(f"step {step} not committed")
        tree = decode_tree((path / "tree.msgpack.zst").read_bytes())
        manifest = json.loads((path / "manifest.json").read_text())
        if target is not None:
            def chk(p, t):
                if t is not None and (tuple(p.shape) != tuple(t.shape)
                                      or str(p.dtype) != str(t.dtype)):
                    raise ValueError(
                        f"checkpoint/target mismatch: {p.shape}/{p.dtype}"
                        f" vs {t.shape}/{t.dtype}")
                return p
            tree = jax.tree_util.tree_map(chk, tree, target)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(jnp.asarray(x), s),
                tree, shardings)
        return tree, manifest
