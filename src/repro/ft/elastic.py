"""Elastic scaling, failure handling, and straggler mitigation.

Large-scale runnability substrate (DESIGN.md §4). On a real cluster these
components consume the platform's health signals (GCE maintenance events,
ICI link errors); here the detector interface is driven by heartbeats so
the whole policy layer is unit-testable on CPU.

  * HeartbeatFailureDetector — per-worker deadline detector
  * StragglerMonitor        — per-step worker timings -> robust z-score ->
                              slow-worker quarantine recommendation
  * ElasticPlan             — given the healthy worker set, choose the
                              largest runnable mesh and the data-shard
                              remapping; restore goes through
                              ft.checkpoint's reshard-on-load
  * run_with_recovery       — the supervision loop: step -> on failure,
                              shrink mesh, restore latest checkpoint, replay
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class HeartbeatFailureDetector:
    def __init__(self, workers: Sequence[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last: Dict[str, float] = {w: clock() for w in workers}
        self.dead: set = set()

    def heartbeat(self, worker: str):
        if worker not in self.dead:
            self.last[worker] = self.clock()

    def mark_failed(self, worker: str):
        self.dead.add(worker)

    def failed(self) -> List[str]:
        now = self.clock()
        for w, t in self.last.items():
            if w not in self.dead and now - t > self.timeout:
                self.dead.add(w)
        return sorted(self.dead)

    def healthy(self) -> List[str]:
        self.failed()
        return sorted(set(self.last) - self.dead)


class StragglerMonitor:
    """Robust z-score on per-worker step times (median/MAD over a window).
    Workers slower than ``z_thresh`` for ``patience`` consecutive steps are
    recommended for quarantine (checkpoint-evict-rescale, not blocking)."""

    def __init__(self, workers: Sequence[str], window: int = 16,
                 z_thresh: float = 4.0, patience: int = 3):
        self.window, self.z, self.patience = window, z_thresh, patience
        self.times: Dict[str, List[float]] = {w: [] for w in workers}
        self.strikes: Dict[str, int] = {w: 0 for w in workers}

    def record_step(self, timings: Dict[str, float]):
        for w, t in timings.items():
            buf = self.times.setdefault(w, [])
            buf.append(t)
            del buf[:-self.window]
        med = np.median([b[-1] for b in self.times.values() if b])
        mad = np.median([abs(b[-1] - med)
                         for b in self.times.values() if b]) + 1e-9
        for w, b in self.times.items():
            if not b:
                continue
            if (b[-1] - med) / (1.4826 * mad) > self.z:
                self.strikes[w] = self.strikes.get(w, 0) + 1
            else:
                self.strikes[w] = 0

    def quarantine(self) -> List[str]:
        return sorted(w for w, s in self.strikes.items()
                      if s >= self.patience)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Mesh choice for a healthy-worker count. The model axis is fixed by
    the sharding rules (16); elasticity happens on (pod x data)."""
    n_workers: int
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    dropped_workers: int

    @property
    def degraded(self) -> bool:
        return self.dropped_workers > 0


def plan_mesh(n_healthy: int, model: int = 16,
              data_choices: Sequence[int] = (32, 16, 8, 4, 2, 1)
              ) -> ElasticPlan:
    """Largest (data, model) mesh that fits the healthy workers; data dim
    shrinks in powers of two (global batch is preserved by increasing
    grad-accumulation microbatches — see train driver)."""
    for d in data_choices:
        need = d * model
        if need <= n_healthy:
            if d > 16:
                shape, axes = (d // 16, 16, model), ("pod", "data", "model")
            else:
                shape, axes = (d, model), ("data", "model")
            return ElasticPlan(n_workers=need, mesh_shape=shape,
                               mesh_axes=axes,
                               dropped_workers=n_healthy - need)
    raise RuntimeError(f"cannot build any mesh from {n_healthy} workers")


def remap_data_shards(old_dp: int, new_dp: int, step: int
                      ) -> List[List[int]]:
    """Which old data shards each new rank takes over after a rescale —
    deterministic and gap-free so no documents are skipped or repeated."""
    return [[s for s in range(old_dp) if s % new_dp == r]
            for r in range(new_dp)]


def run_with_recovery(*, step_fn, save_fn, restore_fn, detector,
                      max_steps: int, checkpoint_every: int = 50,
                      on_rescale=None, max_failures: int = 8):
    """Supervision loop (simulation-grade): run step_fn(step); on raised
    WorkerFailure (or detector-reported deaths) -> restore from the last
    checkpoint onto the shrunken mesh and continue. Returns history."""
    history = {"completed": 0, "failures": 0, "rescales": []}
    step = 0
    while step < max_steps:
        try:
            dead = detector.failed()
            if dead and on_rescale is not None:
                plan = plan_mesh(len(detector.healthy()))
                on_rescale(plan, dead)
                history["rescales"].append((step, tuple(dead),
                                            plan.mesh_shape))
                step = restore_fn()
                detector.dead.clear()
                for w in dead:
                    detector.last.pop(w, None)
                continue
            step_fn(step)
            step += 1
            history["completed"] += 1
            if step % checkpoint_every == 0:
                save_fn(step)
        except WorkerFailure as e:
            history["failures"] += 1
            if history["failures"] > max_failures:
                raise
            detector.mark_failed(e.worker)
    return history


class WorkerFailure(RuntimeError):
    def __init__(self, worker: str, msg: str = ""):
        super().__init__(f"worker {worker} failed {msg}")
        self.worker = worker
