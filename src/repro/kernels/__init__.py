"""Pallas TPU kernels for the MoP compute hot spots.

q4_matmul — fused in-VMEM dequant + MXU matmul for int4/int8 group-quantized
weights (kernel body), with ops.py as the jit'd public wrapper and ref.py as
the pure-jnp oracle. Validated in interpret mode on CPU; targets Mosaic/TPU.
"""
from repro.kernels.ops import q_expert_matmul, q_matmul  # noqa: F401
from repro.kernels.ref import expert_matmul_ref, quantized_matmul_ref  # noqa: F401
