"""Fused dequant-matmul Pallas TPU kernel: x @ dequant(Wq) for int4/int8
group-wise quantized weights (the MoP compute hot spot).

Design (DESIGN.md §2, hardware adaptation):
  * Weights are stored packed (int4: 2 nibbles/byte along K; int8: raw) with
    per-(group, column) bf16 scales, group_size | BK. The kernel unpacks and
    scales *inside VMEM* right before the MXU dot, so HBM traffic for a
    4-bit expert is ~4x lower than bf16 — this turns the paper's observed
    4-bit *slowdown* (PyTorch/bnb dequant-to-global-memory) into a speedup
    in the memory-bound decode regime.
  * Grid (M/BM, N/BN, K/BK), revolving f32 accumulator in VMEM scratch;
    K is the innermost (fastest) grid axis so the accumulator tile stays
    resident while weight tiles stream through.
  * Default tiles (BM, BN, BK) = (128, 256, 128): MXU-aligned (128 lanes),
    VMEM footprint = x(128x128xbf16 = 32 KiB) + w(64x256 = 16 KiB packed)
    + scales(2x256) + acc(128x256xf32 = 128 KiB) ~ 176 KiB << 16 MiB VMEM,
    leaving room for double-buffered pipelining.
  * ``dot(int8-ish bf16 values)`` uses preferred_element_type=f32 so the MXU
    accumulates in f32.

The pure-jnp oracle lives in ``repro.kernels.ref``; jit'd public wrappers in
``repro.kernels.ops``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer jax renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _q4_kernel(x_ref, wq_ref, sc_ref, o_ref, acc_ref, *, nk: int,
               group_size: int, block_k: int):
    """One (BM, BN) output tile; K streamed over grid axis 2."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # unpack int4: byte b holds K indices (2b, 2b+1) as (low, high) nibbles
    w8 = wq_ref[...]                                   # (BK//2, BN) uint8
    lo = (w8 & 0xF).astype(jnp.int8) - 8
    hi = (w8 >> 4).astype(jnp.int8) - 8
    w_int = jnp.stack([lo, hi], axis=1).reshape(block_k, w8.shape[1])

    # group-wise scale: (BK/G, BN) -> broadcast over each group's rows
    sc = sc_ref[...].astype(jnp.float32)               # (BK/G, BN)
    w_f = w_int.astype(jnp.float32).reshape(
        block_k // group_size, group_size, -1) * sc[:, None, :]
    w_f = w_f.reshape(block_k, -1)

    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w_f,
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _q8_kernel(x_ref, wq_ref, sc_ref, o_ref, acc_ref, *, nk: int,
               group_size: int, block_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_int = wq_ref[...]                                # (BK, BN) int8
    sc = sc_ref[...].astype(jnp.float32)
    w_f = w_int.astype(jnp.float32).reshape(
        block_k // group_size, group_size, -1) * sc[:, None, :]
    w_f = w_f.reshape(block_k, -1)
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w_f,
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quantized_matmul(
    x: jax.Array,            # (M, K) bf16/f32
    wq: jax.Array,           # int4: (K//2, N) uint8 | int8: (K, N) int8
    scales: jax.Array,       # (K//G, N)
    *,
    bits: int = 4,
    group_size: int = 64,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 128,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    """``x @ dequant(wq, scales)`` with in-VMEM dequantization.

    Shape requirements: BM|M, BN|N, BK|K, group_size|BK. Callers pad via
    :mod:`repro.kernels.ops`.
    """
    m, kdim = x.shape
    if bits == 4:
        n = wq.shape[1]
        k_w = wq.shape[0] * 2
    elif bits == 8:
        k_w, n = wq.shape
    else:
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if k_w != kdim:
        raise ValueError(f"K mismatch: x {kdim} vs w {k_w}")
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales {scales.shape} != {(kdim//group_size, n)}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, kdim)
    if m % block_m or n % block_n or kdim % block_k:
        raise ValueError(f"blocks must divide dims: "
                         f"{(m, n, kdim)} vs {(block_m, block_n, block_k)}")
    if block_k % group_size:
        raise ValueError(f"group_size {group_size} must divide BK {block_k}")

    grid = (m // block_m, n // block_n, kdim // block_k)
    kern = _q4_kernel if bits == 4 else _q8_kernel
    w_rows = block_k // 2 if bits == 4 else block_k

    return pl.pallas_call(
        functools.partial(kern, nk=grid[2], group_size=group_size,
                          block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((w_rows, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k // group_size, block_n),
                         lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wq, scales)
