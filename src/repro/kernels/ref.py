"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, dequantize


def quantized_matmul_ref(x: jax.Array, wq: jax.Array, scales: jax.Array,
                         *, bits: int = 4, group_size: int = 64,
                         out_dtype=jnp.bfloat16) -> jax.Array:
    """Dequantize-then-matmul in f32 — oracle for kernels.q4_matmul."""
    qt = QTensor(q=wq, scales=scales, bits=bits, group_size=group_size)
    w = dequantize(qt).astype(jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def expert_matmul_ref(x: jax.Array, wq: jax.Array, scales: jax.Array,
                      *, bits: int = 4, group_size: int = 64,
                      out_dtype=jnp.bfloat16) -> jax.Array:
    """(E, C, K) x (E, K, N) batched variant."""
    qt = QTensor(q=wq, scales=scales, bits=bits, group_size=group_size)
    w = dequantize(qt).astype(jnp.float32)            # (E, K, N)
    return jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32), w
                      ).astype(out_dtype)
