"""Grouped multi-expert matmul Pallas kernels: ONE launch per ladder rung
(DESIGN.md §13).

The per-expert spelling (``ops.q_matmul`` under ``vmap``, or a Python loop
of per-expert calls) dispatches one kernel instance per expert, so decode
FFN launch + dequant overhead scales with the bank's expert count — the
wrong scaling for kimi-scale configs (384 experts). These kernels fuse all
of a rung's experts into a single ``pallas_call`` with the expert-group as
the leading grid axis:

    grid = (G, C/BM, N/BN, K/BK)

where ``G`` is the number of experts in the rung's bank and ``C`` the
capacity-bounded tokens-per-expert buffer the MoE dispatch packs
(``mixed_moe._dispatch_local``). Each grid step indexes that group's packed
weights/scales through its BlockSpec and dequantizes **in VMEM** right
before the MXU dot, exactly like the per-expert kernel body
(``q4_matmul``) — per-tile arithmetic is identical, so the grouped q4/q8
results are bit-exact against the per-expert loop (tested). The bf16 bank
gets the same grouped layout without the dequant (f32 accumulation, so
parity with the jnp einsum is allclose, not bitwise).

An expert with zero routed tokens occupies an all-zero slice of the packed
activation buffer; its tiles compute ``0 @ dequant(W) == 0`` exactly, so
empty groups contribute exact zeros (tested) — no host-side compaction is
needed to keep the launch count at one.

K stays the innermost (fastest) grid axis so the revolving f32 accumulator
tile stays resident in VMEM scratch while weight tiles stream; the group
axis is outermost and fully parallel. VMEM per step is the same as the
per-expert kernel (leading block of 1 on the group axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer jax renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _dequant_tile(wq, sc, *, bits: int, group_size: int, block_k: int):
    """Unpack + scale one (BK, BN) weight tile in VMEM (f32) — the same
    arithmetic as the per-expert kernel bodies in ``q4_matmul``."""
    if bits == 4:
        # byte b holds K indices (2b, 2b+1) as (low, high) nibbles
        lo = (wq & 0xF).astype(jnp.int8) - 8
        hi = (wq >> 4).astype(jnp.int8) - 8
        w_int = jnp.stack([lo, hi], axis=1).reshape(block_k, wq.shape[1])
    else:
        w_int = wq                                     # (BK, BN) int8
    sc = sc.astype(jnp.float32)                        # (BK/G, BN)
    w_f = w_int.astype(jnp.float32).reshape(
        block_k // group_size, group_size, -1) * sc[:, None, :]
    return w_f.reshape(block_k, -1)


def _grouped_q_kernel(x_ref, wq_ref, sc_ref, o_ref, acc_ref, *, nk: int,
                      group_size: int, block_k: int, bits: int):
    """One (BM, BN) output tile of one expert group; K streamed over grid
    axis 3. All refs carry a leading group-block of 1."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_f = _dequant_tile(wq_ref[0], sc_ref[0], bits=bits,
                        group_size=group_size, block_k=block_k)
    acc_ref[...] += jax.lax.dot(
        x_ref[0].astype(jnp.float32), w_f,
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _grouped_bf16_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_quantized_matmul(
    x: jax.Array,            # (G, C, K) bf16/f32
    wq: jax.Array,           # int4: (G, K//2, N) uint8 | int8: (G, K, N)
    scales: jax.Array,       # (G, K//group_size, N)
    *,
    bits: int = 4,
    group_size: int = 64,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 128,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    """``einsum('gck,gkn->gcn', x, dequant(wq, scales))`` in ONE launch.

    Shape requirements match :func:`~repro.kernels.q4_matmul.
    quantized_matmul` per group: BM|C, BN|N, BK|K, group_size|BK. Callers
    pad via :mod:`repro.kernels.ops`.
    """
    g, c, kdim = x.shape
    if bits == 4:
        n = wq.shape[2]
        k_w = wq.shape[1] * 2
    elif bits == 8:
        _, k_w, n = wq.shape
    else:
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if wq.shape[0] != g or scales.shape[0] != g:
        raise ValueError(f"group mismatch: x {g} vs w {wq.shape[0]} "
                         f"vs scales {scales.shape[0]}")
    if k_w != kdim:
        raise ValueError(f"K mismatch: x {kdim} vs w {k_w}")
    if scales.shape[1:] != (kdim // group_size, n):
        raise ValueError(
            f"scales {scales.shape[1:]} != {(kdim // group_size, n)}")
    block_m = min(block_m, c)
    block_n = min(block_n, n)
    block_k = min(block_k, kdim)
    if c % block_m or n % block_n or kdim % block_k:
        raise ValueError(f"blocks must divide dims: "
                         f"{(c, n, kdim)} vs {(block_m, block_n, block_k)}")
    if block_k % group_size:
        raise ValueError(f"group_size {group_size} must divide BK {block_k}")

    grid = (g, c // block_m, n // block_n, kdim // block_k)
    w_rows = block_k // 2 if bits == 4 else block_k

    return pl.pallas_call(
        functools.partial(_grouped_q_kernel, nk=grid[3],
                          group_size=group_size, block_k=block_k,
                          bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k),
                         lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, w_rows, block_n),
                         lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, block_k // group_size, block_n),
                         lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, c, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, wq, scales)


def grouped_bf16_matmul(
    x: jax.Array,            # (G, C, K)
    w: jax.Array,            # (G, K, N)
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 128,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    """``einsum('gck,gkn->gcn', x, w)`` in one launch — the f16 bank's
    grouped path (f32 accumulation in VMEM scratch)."""
    g, c, kdim = x.shape
    gw, k_w, n = w.shape
    if gw != g or k_w != kdim:
        raise ValueError(f"shape mismatch: x {x.shape} vs w {w.shape}")
    block_m = min(block_m, c)
    block_n = min(block_n, n)
    block_k = min(block_k, kdim)
    if c % block_m or n % block_n or kdim % block_k:
        raise ValueError(f"blocks must divide dims: "
                         f"{(c, n, kdim)} vs {(block_m, block_n, block_k)}")
    grid = (g, c // block_m, n // block_n, kdim // block_k)
    return pl.pallas_call(
        functools.partial(_grouped_bf16_kernel, nk=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k),
                         lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, c, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
