"""jit'd public wrappers around the Pallas kernels.

These handle padding to tile boundaries, the QTensor container, batching
over experts (vmap adds a leading grid dimension to the pallas_call), and
CPU fallback (interpret mode executes the kernel body in Python — used for
tests and for this CPU container; on TPU the same code JITs to Mosaic).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor
from repro.kernels import grouped_matmul as _gk
from repro.kernels import q4_matmul as _k

# On the CPU container Pallas must run in interpret mode; flip to False on
# real TPU (dryrun lowering for TPU targets uses the jnp reference path —
# see mixed_moe.use_kernel).
_DEFAULT_INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _with_padded_m(call, x: jax.Array, *, block_m: int, m_axis: int):
    """Centralized padded-M wrapper (decode batches are small and rarely
    tile-aligned). Picks the effective M tile, zero-pads ``x`` along
    ``m_axis`` to it, runs ``call(x_padded, block_m_eff)`` and slices the
    result back to the true M. Shared by the per-expert and grouped paths
    so both see identical tile choices (a parity requirement)."""
    m = x.shape[m_axis]
    block_m_eff = min(block_m, _round_up(m, 8))
    xp = _pad_to(x, block_m_eff, m_axis)
    out = call(xp, block_m_eff)
    return jax.lax.slice_in_dim(out, 0, m, axis=m_axis)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def q_matmul(x: jax.Array, qt: QTensor, *, block_m: int = 128,
             block_n: int = 256, block_k: int = 128,
             out_dtype=jnp.bfloat16,
             interpret: Optional[bool] = None) -> jax.Array:
    """``x @ dequant(qt)`` — (M, K) x Q(K, N) -> (M, N).

    M is padded to the tile size (decode batches are small); K and N must
    already satisfy tile divisibility (true for every config in the zoo —
    d_model/d_ff are multiples of 256).
    """
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    k, n = qt.shape[-2:]
    # shrink tiles to divisors (TP-sharded d_ff slices, e.g. 14336/16=896,
    # are multiples of 128 but not of 256)
    block_n = _largest_divisor(n, block_n, qt.group_size)
    block_k = _largest_divisor(k, block_k, qt.group_size)
    return _with_padded_m(
        lambda xp, bm: _k.quantized_matmul(
            xp, qt.q, qt.scales, bits=qt.bits, group_size=qt.group_size,
            block_m=bm, block_n=block_n, block_k=block_k,
            out_dtype=out_dtype, interpret=interpret),
        x, block_m=block_m, m_axis=0)


def _largest_divisor(dim: int, cap: int, step: int) -> int:
    """Largest multiple of ``step`` that divides ``dim`` and is <= cap."""
    best = step if dim % step == 0 else dim
    b = step
    while b <= min(cap, dim):
        if dim % b == 0:
            best = b
        b += step
    return min(best, dim)


def q_expert_matmul(x: jax.Array, qt: QTensor, *, block_m: int = 128,
                    block_n: int = 256, block_k: int = 128,
                    out_dtype=jnp.bfloat16,
                    interpret: Optional[bool] = None,
                    grouped: bool = True) -> jax.Array:
    """Batched experts: (E, C, K) x Q(E, K, N) -> (E, C, N).

    ``grouped=True`` (default) fuses the whole bank into ONE kernel launch
    with the expert-group as the leading grid axis (DESIGN.md §13) —
    decode FFN cost stops scaling with expert count. ``grouped=False``
    keeps the legacy per-expert spelling (vmap over pallas_call); it is
    bit-identical to the grouped path and retained as the A/B baseline for
    ``benchmarks/kernel_bench.py``.
    """
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if grouped:
        return grouped_q_matmul(
            x, qt, block_m=block_m, block_n=block_n, block_k=block_k,
            out_dtype=out_dtype, interpret=interpret)
    fn = functools.partial(
        q_matmul, block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=interpret)
    return jax.vmap(lambda xe, qe, se: fn(
        xe, QTensor(q=qe, scales=se, bits=qt.bits, group_size=qt.group_size))
    )(x, qt.q, qt.scales)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def grouped_q_matmul(x: jax.Array, qt: QTensor, *, block_m: int = 128,
                     block_n: int = 256, block_k: int = 128,
                     out_dtype=jnp.bfloat16,
                     interpret: Optional[bool] = None) -> jax.Array:
    """One-launch grouped ``(E, C, K) x Q(E, K, N) -> (E, C, N)``. Tile
    selection mirrors :func:`q_matmul` exactly so the grouped result is
    bit-identical to the per-expert loop."""
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    k, n = qt.shape[-2:]
    block_n = _largest_divisor(n, block_n, qt.group_size)
    block_k = _largest_divisor(k, block_k, qt.group_size)
    return _with_padded_m(
        lambda xp, bm: _gk.grouped_quantized_matmul(
            xp, qt.q, qt.scales, bits=qt.bits, group_size=qt.group_size,
            block_m=bm, block_n=block_n, block_k=block_k,
            out_dtype=out_dtype, interpret=interpret),
        x, block_m=block_m, m_axis=1)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def grouped_bf16_matmul(x: jax.Array, w: jax.Array, *, block_m: int = 128,
                        block_n: int = 256, block_k: int = 128,
                        out_dtype=jnp.bfloat16,
                        interpret: Optional[bool] = None) -> jax.Array:
    """One-launch grouped bf16 ``(E, C, K) x (E, K, N) -> (E, C, N)`` —
    the f16 bank's grouped path (no dequant; f32 VMEM accumulation, so
    parity with the einsum reference is allclose, not bitwise)."""
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    _, k, n = w.shape
    block_n = _largest_divisor(n, block_n, 8)
    block_k = _largest_divisor(k, block_k, 8)
    return _with_padded_m(
        lambda xp, bm: _gk.grouped_bf16_matmul(
            xp, w, block_m=bm, block_n=block_n, block_k=block_k,
            out_dtype=out_dtype, interpret=interpret),
        x, block_m=block_m, m_axis=1)


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m
