"""Adaptive MoE serving engine — the paper's Fig. 1 system.

Pipeline: request queue -> batch assembly -> prefill -> decode loop, with
the Adaptive Partitioner & Planner deciding {#4-bit experts, residency}
from the live memory budget + task preference, and *incremental*
reconfiguration when constraints change.

Fidelity model on this CPU container (DESIGN.md §2):
  * model compute is REAL (jitted prefill/decode with the plan's dual-bank
    mixed-precision params; tokens/s from wall-clock);
  * host<->HBM expert streaming cost is ACCOUNTED from (a) the measured
    device_put bandwidth of an expert-sized buffer and (b) the expected
    miss rate under the paper's uniform-routing assumption (the same
    assumption eq. 1 rests on). The LRU cache itself is real and unit
    tested (core/expert_cache.py); on a TPU deployment the fetches run
    through it per layer.

Reconfiguration: placement-only changes are graph-free; changing the
(E4, E16) bank split re-specializes the jitted step (one compile per bank
signature, cached) — this is the "minimal downtime" path the paper
describes, measured in metrics["reconfig_s"].
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import HardwareModel
from repro.core.planner import AdaptivePlanner, PlanResult
from repro.models.model import Model, apply_precision_plan, build_model
from repro.serving.sampler import sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: Optional[float] = None


def measure_host_link_bw(nbytes: int = 1 << 24) -> float:
    """Measured device_put bandwidth (host->device), B/s."""
    buf = np.ones(nbytes, np.uint8)
    dev = jax.devices()[0]
    jax.block_until_ready(jax.device_put(buf[:1024], dev))  # warm
    t0 = time.perf_counter()
    jax.block_until_ready(jax.device_put(buf, dev))
    return nbytes / max(time.perf_counter() - t0, 1e-9)


class AdaptiveServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 hw: Optional[HardwareModel] = None,
                 max_batch: int = 8, max_len: int = 256,
                 use_kernel: bool = False):
        if cfg.moe is None:
            raise ValueError("the adaptive engine serves MoE models")
        self.cfg = cfg
        self.params_train = params        # train-layout master copy
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_len = max_len
        self.use_kernel = use_kernel
        self.hw = hw or HardwareModel(host_link_bw=measure_host_link_bw())
        self.planner = AdaptivePlanner(cfg, hw=self.hw)
        self.model: Model = build_model(cfg, mesh, use_kernel=use_kernel)
        self.queue: deque = deque()
        self.done: Dict[int, Request] = {}
        self._rid = 0
        self._serve_params = None
        self._plan_result: Optional[PlanResult] = None
        self._compiled: Dict[Tuple[int, int], Any] = {}
        self.metrics: Dict[str, Any] = {
            "tokens_generated": 0, "decode_s": 0.0, "prefill_s": 0.0,
            "transfer_s_est": 0.0, "reconfig_s": 0.0, "reconfigs": 0,
            "miss_rate": 0.0,
        }

    # ------------------------------------------------------------------
    # Planner integration
    # ------------------------------------------------------------------
    def configure(self, mem_budget_bytes: float, preference: str,
                  num_q_experts: Optional[int] = None) -> PlanResult:
        t0 = time.perf_counter()
        result, delta = self.planner.replan(
            mem_budget_bytes, preference, num_q_experts,
            batch_size=self.max_batch)
        plan = result.plan
        sig = plan.bank_sizes()
        rebuild = (self._plan_result is None
                   or self._plan_result.plan.bank_sizes() != sig
                   or self._plan_result.plan.seed != plan.seed)
        if rebuild:
            # bank split changed -> re-specialize the step functions
            self._serve_params = apply_precision_plan(
                self.params_train, self.cfg, plan)
            self._compiled.clear()
        self._plan_result = result
        self.metrics["reconfig_s"] += time.perf_counter() - t0
        self.metrics["reconfigs"] += 1
        if delta is not None:
            self.metrics["last_delta_traffic_gib"] = \
                delta["traffic_bytes"] / 2**30
        return result

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        self._rid += 1
        self.queue.append(Request(rid=self._rid,
                                  prompt=np.asarray(prompt, np.int32),
                                  max_new_tokens=max_new_tokens,
                                  t_submit=time.perf_counter()))
        return self._rid

    def _take_batch(self) -> List[Request]:
        batch = []
        while self.queue and len(batch) < self.max_batch:
            batch.append(self.queue.popleft())
        return batch

    def _jit(self, name, fn):
        if name not in self._compiled:
            self._compiled[name] = jax.jit(fn)
        return self._compiled[name]

    def step(self, *, temperature: float = 0.0, seed: int = 0) -> int:
        """Serve one batch to completion; returns #requests finished."""
        if self._plan_result is None:
            raise RuntimeError("configure() the engine first")
        reqs = self._take_batch()
        if not reqs:
            return 0
        b = len(reqs)
        s_max = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, s_max), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s_max - len(r.prompt):] = r.prompt   # left-pad
        batch = {"tokens": jnp.asarray(toks),
                 "labels": jnp.zeros_like(jnp.asarray(toks))}
        cache = self.model.init_cache(
            b, s_max + max(r.max_new_tokens for r in reqs))

        t0 = time.perf_counter()
        logits, cache = self._jit("prefill", self.model.prefill)(
            self._serve_params, batch, cache)
        jax.block_until_ready(logits)
        self.metrics["prefill_s"] += time.perf_counter() - t0

        key = jax.random.key(seed)
        positions = jnp.full((b,), s_max, jnp.int32)
        tok = sample(logits, key=key, temperature=temperature,
                     vocab_size=self.cfg.vocab_size)
        n_steps = max(r.max_new_tokens for r in reqs)
        decode = self._jit("decode", self.model.decode_step)
        t0 = time.perf_counter()
        for step_i in range(n_steps):
            for i, r in enumerate(reqs):
                if step_i < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i]))
            if step_i == n_steps - 1:
                break
            key, sub = jax.random.split(key)
            logits, cache = decode(self._serve_params, cache,
                                   tok[:, None], positions)
            tok = sample(logits, key=sub, temperature=temperature,
                         vocab_size=self.cfg.vocab_size)
            positions = positions + 1
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        self.metrics["decode_s"] += dt
        ntok = sum(min(n_steps, r.max_new_tokens) for r in reqs)
        self.metrics["tokens_generated"] += ntok

        # expected streaming cost under the plan (paper's uniform-routing
        # assumption; see module docstring)
        from repro.core.cost_model import expert_access_stats
        hit, miss_bytes_per_tok = expert_access_stats(
            self.cfg, self._plan_result.plan)
        self.metrics["miss_rate"] = 1.0 - hit
        self.metrics["transfer_s_est"] += \
            ntok / b * miss_bytes_per_tok / self.hw.host_link_bw

        now = time.perf_counter()
        for r in reqs:
            r.t_done = now
            self.done[r.rid] = r
        return len(reqs)

    # ------------------------------------------------------------------
    def throughput_tokens_per_s(self, include_transfer: bool = True) -> float:
        t = self.metrics["decode_s"]
        if include_transfer:
            t += self.metrics["transfer_s_est"]
        return self.metrics["tokens_generated"] / max(t, 1e-9)

    def summary(self) -> str:
        p = self._plan_result
        return (f"plan[{p.preference} E4={p.plan.num_q_experts}"
                f"/{p.plan.quant.size} res={p.plan.resident_fraction():.0%}]"
                f" gen={self.metrics['tokens_generated']}tok"
                f" decode={self.metrics['decode_s']:.2f}s"
                f" +transfer~{self.metrics['transfer_s_est']:.2f}s"
                f" -> {self.throughput_tokens_per_s():.2f} tok/s")
