"""Adaptive MoE serving engine — continuous batching over fixed decode
slots (the paper's Fig. 1 system + an iteration-level scheduler,
DESIGN.md §3).

Architecture:

  * ``ContinuousScheduler`` (serving/scheduler.py) owns requests: the
    admission queue, per-slot request state, join/retire at EVERY decode
    iteration.
  * this engine owns the model side: one slot-based KV cache of
    ``max_slots`` rows, a jitted decode step specialized ONCE for the full
    slot count (idle slots ride along masked by position=-1), and
    per-bucket jitted prefill-into-slot functions so a new request joins a
    live batch without recompiling or re-padding it.
  * the runtime expert path: non-resident experts under the active
    ``PrecisionPlan`` are fetched through the real
    ``ExpertCache``/``PrefetchingExpertCache`` (core/expert_cache.py) from
    the routed expert ids of every decode iteration. ``metrics`` reports
    the MEASURED ``transfer_s``/``miss_rate_measured`` next to the
    retained analytical ``transfer_s_est``/``miss_rate`` so the cost model
    is cross-validated on every run.
  * ASYNC OVERLAP mode (``EngineConfig(overlap=True)``, DESIGN.md §12):
    staging moves to an ``AsyncExpertCache`` worker pool and the decode
    step runs through the model's per-layer hooks as a lookahead
    pipeline — while layer L computes, layer L+1's predicted experts
    (the previous iteration's captured routes: decode re-demands most of
    them for adjacent tokens) stage in the background; each layer's
    ACTUAL routed demand is then awaited, exposing only what prediction
    could not hide. ``metrics`` splits the transfer time into
    ``transfer_exposed_s`` (blocked the critical path) and
    ``transfer_overlapped_s`` (hidden under compute); throughput charges
    only the exposed part. The sync path survives as ``overlap=False``
    for A/B comparison, and ``close()`` joins the transfer workers.

Fidelity model on this CPU container (DESIGN.md §2): model compute is
REAL (jitted decode with the plan's dual-bank mixed-precision params);
expert streaming runs through the real LRU cache with real ``device_put``
staging — on this single-memory container the jitted banks stay resident,
so the transfers are measured but not consumed by the matmuls; on a TPU
deployment the fetched buffers are donated into the step.

Reconfiguration is safe mid-flight: placement-only replans apply between
decode iterations without touching in-flight requests (placement never
changes outputs — tested); a bank-split change first DRAINS the active
slots (finishing their requests, admitting no new ones), then
re-specializes the step functions — the paper's "minimal downtime" path,
measured in ``metrics["reconfig_s"]``.

The DECLARATIVE entry points (DESIGN.md §9) are ``apply_target`` (resolve
a ``QoSTarget`` on the engine's ``ParetoFrontier`` and apply the selected
point) and ``apply_frontier_point`` (the ``QoSController``'s walk step);
the imperative ``configure(mem_budget_bytes, preference, num_q)`` is a
deprecated shim that builds a ``QoSTarget`` internally.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import threading
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import (HardwareModel, estimate_qos,
                                   expert_access_stats, kv_bytes_bucketed,
                                   kv_token_bytes)
from repro.core.expert_cache import (AsyncExpertCache, ExpertCache,
                                     PrefetchingExpertCache)
from repro.core.pareto import FrontierPoint, ParetoFrontier, QoSTarget
from repro.core.planner import AdaptivePlanner, PlanResult
from repro.core.precision_plan import (DEVICE, HOST, PrecisionPlan,
                                       quantized_rungs)
from repro.models.model import Model, apply_precision_plan, build_model
from repro.serving.api import EngineConfig, ServeRequest, ServeResult
from repro.serving.metrics import base_metrics
from repro.serving.paged_kv import PageAllocator
from repro.serving.sampler import sample, speculative_verify
from repro.serving.scheduler import (ContinuousScheduler, Request,
                                     RequestSLO, SamplingParams,
                                     SchedulerConfig)

__all__ = ["AdaptiveServingEngine", "Request", "measure_host_link_bw"]

# per-process cache: the engine is constructed once per test/benchmark
# point, and a 16 MiB device_put probe per construction both slows the
# suite and skews short benchmarks. Keyed by probe size.
_HOST_LINK_BW_CACHE: Dict[int, float] = {}


def measure_host_link_bw(nbytes: int = 1 << 24, *,
                         refresh: bool = False) -> float:
    """Measured device_put bandwidth (host->device), B/s. Cached per
    process (the link does not change under our feet); ``refresh=True``
    forces a re-probe."""
    if not refresh and nbytes in _HOST_LINK_BW_CACHE:
        return _HOST_LINK_BW_CACHE[nbytes]
    buf = np.ones(nbytes, np.uint8)
    dev = jax.devices()[0]
    jax.block_until_ready(jax.device_put(buf[:1024], dev))  # warm
    t0 = time.perf_counter()
    jax.block_until_ready(jax.device_put(buf, dev))
    bw = nbytes / max(time.perf_counter() - t0, 1e-9)
    _HOST_LINK_BW_CACHE[nbytes] = bw
    return bw


def _bucket(n: int, lo: int = 8, hi: Optional[int] = None) -> int:
    """Next power-of-two >= n: bounds prefill recompiles to log(max_len).
    ``hi`` clamps to the KV-cache window so a prompt near ``max_len``
    can't request a bucket wider than the cache (the prompt itself was
    already validated to fit by the scheduler)."""
    b = lo
    while b < n:
        b *= 2
    return b if hi is None else min(b, hi)


class AdaptiveServingEngine:
    """Continuous-batching adaptive engine.

    Preferred construction is the typed surface (DESIGN.md §9):
    ``AdaptiveServingEngine(cfg, params, config=EngineConfig(...))`` or
    ``repro.serving.api.build_engine``. The flat keyword arguments
    (``max_batch`` — the number of decode slots —, ``max_len``, ...) are
    the backward-compatible spelling and populate an ``EngineConfig``
    internally."""

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 config: Optional[EngineConfig] = None,
                 hw: Optional[HardwareModel] = None,
                 max_batch: int = 8, max_len: int = 256,
                 use_kernel: bool = False,
                 max_active_tokens: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 swap_bytes: Optional[int] = None,
                 prefetch: bool = False,
                 expert_cache=None):
        if cfg.moe is None:
            raise ValueError("the adaptive engine serves MoE models")
        if config is None:
            config = EngineConfig(
                max_slots=max_batch, max_len=max_len,
                use_kernel=use_kernel,
                max_active_tokens=max_active_tokens, max_queue=max_queue,
                swap_bytes=swap_bytes, prefetch=prefetch, hw=hw)
        if config.ladder is not None:
            # the deployment declares its precision ladder on the typed
            # surface; it overrides the config default (DESIGN.md §11)
            cfg = cfg.replace(mop=dataclasses.replace(
                cfg.mop, ladder=tuple(config.ladder)))
        self.config = config
        self.cfg = cfg
        self.params_train = params        # train-layout master copy
        self.mesh = mesh
        self.max_slots = config.max_slots
        self.max_len = config.max_len
        self.use_kernel = config.use_kernel
        if config.hw is not None:
            # an explicit hardware model wins, but an explicit
            # overlap_efficiency knob still applies on top (it would
            # otherwise be silently dropped and the frontier would rank
            # under the additive model while the pipeline runs)
            self.hw = config.hw
            if config.overlap_efficiency is not None:
                self.hw = dataclasses.replace(
                    self.hw,
                    overlap_efficiency=float(config.overlap_efficiency))
        else:
            # overlap mode seeds the analytic overlap window (refined at
            # runtime by calibrate_overlap, DESIGN.md §12); sync keeps
            # the additive model exactly.
            eff = config.overlap_efficiency
            if eff is None:
                eff = 0.85 if config.overlap else 0.0
            self.hw = HardwareModel(host_link_bw=measure_host_link_bw(),
                                    overlap_efficiency=float(eff))
        self.planner = AdaptivePlanner(cfg, hw=self.hw,
                                       ep=getattr(config, "ep", 1))
        self.model: Model = build_model(cfg, mesh,
                                        use_kernel=self.use_kernel)
        if self.model.prefill_into_slot is None:
            raise ValueError(f"{cfg.arch_id}: family {cfg.family} has no "
                             "slot-cache decode path")
        # KV cache: paged (fixed-size pages + per-slot page table,
        # DESIGN.md §13) by default; the fully-windowed slot cache
        # survives as the A/B baseline (paged_kv=False). Decode through
        # the pages is bit-identical to the slot cache (tested).
        self.paged = bool(config.paged_kv
                          and self.model.paged_decode_step_routed
                          is not None)
        max_active = config.max_active_tokens
        self._kv_token_bytes = kv_token_bytes(cfg)
        if self.paged:
            self.kv_pool, self.kv_meta = self.model.init_paged_cache(
                self.max_slots, self.max_len,
                page_size=config.page_size,
                num_pages=config.kv_pool_pages)
            self.window = self.kv_meta.window
            self.kv_alloc = PageAllocator(
                self.max_slots, self.kv_meta.chunks_per_slot,
                self.kv_meta.num_pages, self.kv_meta.page_size)
            self.cache = None
            worst = self.max_slots * self.kv_meta.chunks_per_slot
            if self.kv_meta.num_pages - 1 < worst:
                # sub-worst-case pool: cap admitted tokens so ensure()
                # can never dead-end mid-flight (per-slot ceil rounding
                # costs at most one page each, hence the max_slots term)
                derived = (self.kv_meta.num_pages - 1 - self.max_slots) \
                    * self.kv_meta.page_size
                max_active = derived if max_active is None \
                    else min(max_active, derived)
        else:
            self.kv_pool = self.kv_meta = self.kv_alloc = None
            self.cache = self.model.init_cache(self.max_slots,
                                               self.max_len)
            self.window = int(self.cache["k"].shape[2])
        self.scheduler = ContinuousScheduler(SchedulerConfig(
            max_slots=self.max_slots, max_len=self.max_len,
            max_prompt_len=self.window,
            max_active_tokens=max_active,
            max_queue=config.max_queue))
        # runtime expert streaming: host master store + device LRU swap.
        # A multi-tenant deployment passes a tenant-scoped VIEW of the
        # shared swap space instead (core/expert_cache.py, DESIGN.md §10)
        # — same interface, namespaced keys, jointly shared byte budget.
        self._swap_bytes = config.swap_bytes
        self._owns_cache = expert_cache is None
        if expert_cache is not None:
            if config.prefetch and not hasattr(expert_cache, "hint"):
                raise ValueError(
                    "EngineConfig(prefetch=True) needs an expert cache "
                    "with hint() support; the provided shared view has "
                    "none")
            if config.overlap and not getattr(expert_cache, "is_async",
                                              False):
                raise ValueError(
                    "EngineConfig(overlap=True) needs an async expert "
                    "cache (AsyncExpertCache, or a scoped view of one — "
                    "DESIGN.md §12); the provided cache stages "
                    "synchronously")
            self.expert_cache = expert_cache
            if hasattr(expert_cache, "bind_fetch"):
                expert_cache.bind_fetch(self._fetch_expert)
        else:
            cache_cls = AsyncExpertCache if config.overlap \
                else (PrefetchingExpertCache if config.prefetch
                      else ExpertCache)
            self.expert_cache = cache_cls(
                self._fetch_expert,
                capacity_bytes=config.swap_bytes
                or 4 * max(cfg.expert_param_bytes(16), 1))
        self._prefetch = config.prefetch and hasattr(self.expert_cache,
                                                     "hint")
        # per-layer lookahead pipeline: overlap mode + the model's
        # per-layer decode hooks (DESIGN.md §12)
        self._pipeline = bool(config.overlap
                              and self.model.decode_layer_routed
                              is not None)
        self._prev_demanded: List[Tuple[int, int]] = []
        #: pipelined mode's per-layer prediction: the previous
        #: iteration's demanded (non-resident) keys, layer-indexed
        self._prev_layer_keys: Optional[List[List[Tuple[int, int]]]] = None
        #: accumulated routed-access histogram [L, E] over TRUE expert
        #: ids (bank slots mapped back through the plan's expert order) —
        #: the dynamic precision controller's traffic signal (DESIGN.md
        #: §15). Deliberately NOT reset by ``_reconfigure``: the
        #: histogram must survive (placement-only) replans; callers
        #: window it via ``reset_route_counts()`` / their own snapshots.
        self.route_counts: np.ndarray = np.zeros(
            (cfg.num_layers, cfg.moe.num_experts if cfg.moe else 0),
            np.int64)
        self._host_store: Dict[Tuple[int, int], Any] = {}
        self._resident: set = set()
        self._miss_bytes_per_tok = 0.0
        self._order: Optional[np.ndarray] = None   # bank slot -> expert id
        self._serve_params = None
        self._plan_result: Optional[PlanResult] = None
        self._frontier: Optional[ParetoFrontier] = None
        self._target: Optional[QoSTarget] = None
        self._active_point: Optional[FrontierPoint] = None
        self._compiled: Dict[Any, Any] = {}
        self._key = jax.random.key(0)
        # ladder-draft self-speculative decoding (DESIGN.md §17): draft
        # depth K per iteration; 0 = plain decode, byte-identical to the
        # pre-speculation engine. Draft params (every expert at the
        # LOWEST ladder rung) build lazily on first speculative
        # iteration and survive replans (they depend only on the ladder
        # and group size, not on the serving rung assignment).
        self.speculate_k = max(0, int(getattr(config, "speculate", 0)
                                      or 0))
        self._draft_params = None
        self._draft_sig: Optional[Tuple] = None
        # async transfer workers call _fetch_expert concurrently: its
        # host-store insert is per-key-unique (one in-flight future per
        # key) but the stage_s accumulation needs the lock
        self._stage_lock = threading.Lock()
        # the shared sim/real metric schema (repro.serving.metrics,
        # DESIGN.md §14.2) — controllers see the same dict shape against
        # the deterministic SimulatedEngine. KV notes (DESIGN.md §13):
        # "kv_allocated_bytes" is what the cache layout holds (mapped
        # pages for paged; slots x window always for the slot cache),
        # "kv_used_bytes" the valid cached tokens — their gap is the
        # padding waste the paged cache eliminates; the *_byte_iters
        # sums give run averages.
        self.metrics: Dict[str, Any] = base_metrics()
        self.metrics["kv_capacity_bytes"] = (
            (self.kv_meta.num_pages - 1) * self.kv_meta.page_size
            * self._kv_token_bytes if self.paged
            else kv_bytes_bucketed(cfg, self.max_slots, self.window))

    # ------------------------------------------------------------------
    # Compatibility surface
    # ------------------------------------------------------------------
    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def done(self) -> Dict[int, Request]:
        return self.scheduler.done

    @property
    def max_batch(self) -> int:
        return self.max_slots

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    # ------------------------------------------------------------------
    # Planner integration / mid-flight reconfiguration
    # ------------------------------------------------------------------
    @property
    def frontier(self) -> ParetoFrontier:
        """The engine's Pareto frontier over the MoP config space
        (DESIGN.md §9), built lazily once per (hardware model, slot
        count) and shared with the QoSController."""
        if self._frontier is None:
            self._frontier = self.planner.frontier(
                batch_size=self.max_slots)
        return self._frontier

    @property
    def target(self) -> Optional[QoSTarget]:
        """The active declarative target (set by ``apply_target`` or the
        ``configure`` shim)."""
        return self._target

    @property
    def active_point(self) -> Optional[FrontierPoint]:
        """The frontier point currently applied; None when the active
        plan came through the imperative shim (possibly off-frontier)."""
        return self._active_point

    def apply_target(self, target: QoSTarget) -> FrontierPoint:
        """Declarative reconfiguration (DESIGN.md §9): resolve ``target``
        on the frontier and apply the selected point via the mid-flight
        replan path. Raises
        :class:`~repro.core.pareto.InfeasibleTarget` when the hard
        constraints admit no configuration."""
        if self.config.kv_reserve:
            # paged KV reserve (DESIGN.md §13): HBM a sub-worst-case page
            # pool reclaims vs the bucketed slot cache widens the expert
            # residency budget the frontier resolves against
            target = target.with_kv_reclaimed(self.kv_reclaimed_bytes())
        point = self.frontier.select(target)
        self._target = target
        self.apply_frontier_point(point)
        return point

    def kv_reclaimed_bytes(self) -> int:
        """HBM the paged pool reclaims vs the fully-windowed slot cache
        (0 for the slot cache or a worst-case-sized pool)."""
        if not self.paged:
            return 0
        bucketed = kv_bytes_bucketed(self.cfg, self.max_slots, self.window)
        return max(0, bucketed - int(self.metrics["kv_capacity_bytes"]))

    def apply_frontier_point(self, point: FrontierPoint) -> PlanResult:
        """Apply one frontier point (the QoSController's walk step).
        Frontier plans are bit-identical to planner plans for the same
        knobs, so this routes through the ordinary replan path: the
        point's exact device footprint is the budget, the point's
        per-rung counts are the quality knobs (a multi-rung point is not
        expressible through Num_E4 alone — DESIGN.md §11), and surplus
        HBM is returned to the pool."""
        counts = point.quantized_counts() if point.counts_per_rung \
            else None
        peer = int(getattr(point, "peer_experts", 0) or 0)
        if peer or self.planner.ep > 1:
            # EP apply path (DESIGN.md §16): pin the point's exact
            # (total resident, peer) split — the budget-derived
            # residency cannot reconstruct a peer slice. Single-device
            # points keep the historical budget-derived path untouched.
            result = self._reconfigure(
                float(point.qos.device_bytes), "quality",
                point.num_q_experts, counts=counts,
                resident_experts=point.resident_experts,
                peer_experts=peer)
        else:
            result = self._reconfigure(float(point.qos.device_bytes),
                                       "quality", point.num_q_experts,
                                       counts=counts)
        self._active_point = point
        return result

    def configure(self, mem_budget_bytes: float, preference: str,
                  num_q_experts: Optional[int] = None) -> PlanResult:
        """DEPRECATED imperative shim (use ``apply_target``): builds the
        equivalent ``QoSTarget`` — "as fast as possible inside the
        budget" for the throughput preference, "this quality level inside
        the budget" for the quality preference — records it as the active
        target, and replans through the legacy eq.(1) path so existing
        callers see bit-identical plans."""
        warnings.warn(
            "AdaptiveServingEngine.configure() is deprecated; declare a "
            "QoSTarget and use apply_target() (DESIGN.md §9)",
            DeprecationWarning, stacklevel=2)
        if preference == "throughput":
            self._target = QoSTarget(mem_budget_bytes=mem_budget_bytes,
                                     min_tokens_per_s=math.inf)
        else:
            loss = None
            if num_q_experts is not None:
                from repro.core.cost_model import RUNG_QUALITY_COST
                from repro.core.precision_plan import quantized_rungs
                frac = num_q_experts / max(self.planner.num_experts_total,
                                           1)
                # legacy shim: Num_E4 counts experts at the LOWEST rung
                low = quantized_rungs(self.planner.ladder)[0]
                per_bit = RUNG_QUALITY_COST.get(low, 0.07)
                loss = per_bit * min(max(frac, 0.0), 1.0)
            self._target = QoSTarget(mem_budget_bytes=mem_budget_bytes,
                                     max_quality_loss=loss)
        result = self._reconfigure(mem_budget_bytes, preference,
                                   num_q_experts)
        self._active_point = None    # imperative plans may be off-frontier
        return result

    def _reconfigure(self, mem_budget_bytes: float, preference: str,
                     num_q_experts: Optional[int] = None,
                     counts=None, resident_experts: Optional[int] = None,
                     peer_experts: Optional[int] = None) -> PlanResult:
        """Replan under new constraints; safe to call with requests in
        flight. Placement-only changes apply immediately (between decode
        iterations); a bank-split change drains the active slots first."""
        t0 = time.perf_counter()
        # async staging barrier (DESIGN.md §12): every enqueued transfer
        # must land BEFORE the plan changes, or a stale-plan blob could
        # be admitted after the invalidate below (no-op for sync caches)
        self.expert_cache.drain()
        result, delta = self.planner.replan(
            mem_budget_bytes, preference, num_q_experts,
            batch_size=self.max_slots, counts=counts,
            resident_experts=resident_experts,
            peer_experts=peer_experts)
        plan = result.plan
        prev_plan = self._plan_result.plan \
            if self._plan_result is not None else None
        sig = plan.bank_sizes()
        rebuild = (prev_plan is None
                   or prev_plan.bank_sizes() != sig
                   or prev_plan.seed != plan.seed)
        drain_s = 0.0
        if rebuild:
            if self.scheduler.num_active:
                # graceful drain: finish in-flight requests on the OLD
                # banks; the queue holds until the new plan is live. The
                # drain is ordinary decoding (counted in decode_s/drain_s),
                # NOT reconfiguration downtime.
                self.metrics["drains"] += 1
                t_drain = time.perf_counter()
                while self.scheduler.num_active:
                    self.run_iteration(admit=False)
                drain_s = time.perf_counter() - t_drain
                self.metrics["drain_s"] += drain_s
                # the drain iterations enqueued fresh async fetches on
                # the OLD plan — barrier again before invalidating
                self.expert_cache.drain()
            # bank split changed -> re-specialize the step functions
            self._serve_params = apply_precision_plan(
                self.params_train, self.cfg, plan)
            self._compiled.clear()
            self._host_store.clear()
            self.expert_cache.invalidate()
        self._plan_result = result
        self._order = plan.expert_order()
        # accelerator-resident = LOCAL + PEER (DESIGN.md §16): under EP
        # the banks are physically sharded over the mesh, so a PEER
        # expert is served by the all2all dispatch, never streamed over
        # the host link. Single-device plans have no PEER entries, so
        # this is the historical DEVICE mask bit-for-bit.
        newly_resident = {
            (li, ei) for li, ei in np.argwhere(plan.location != HOST)}
        if not rebuild:
            # Same bank shapes does NOT imply the same bits ASSIGNMENT:
            # an earlier apply_bits_update may have swapped rungs between
            # experts, while the planner's fresh plan carries the
            # canonical assignment for these counts. Banks and staged
            # host blobs must follow the new assignment or stale-rung
            # weights get served (shapes unchanged, so no recompile).
            rung_changed = set()
            if (prev_plan.bits != plan.bits).any():
                self._serve_params = apply_precision_plan(
                    self.params_train, self.cfg, plan)
                rung_changed = {
                    (int(l), int(e)) for l, e in
                    np.argwhere(prev_plan.bits != plan.bits)}
                for k in list(self._host_store):
                    if (k[0], k[1]) in rung_changed:
                        del self._host_store[k]
            # placement-only: swap entries that moved on-device are now
            # HBM-resident — drop them from the swap cache, along with
            # any entry staged at a rung the new plan no longer assigns
            self.expert_cache.invalidate(
                [k for k in self.expert_cache.resident_keys()
                 if k[:2] in newly_resident or k[:2] in rung_changed])
        self._resident = newly_resident
        self._prev_demanded = []     # stale-plan hints must not re-stage
        self._prev_layer_keys = None
        hit, self._miss_bytes_per_tok = expert_access_stats(self.cfg, plan)
        self.metrics["miss_rate"] = 1.0 - hit
        downtime = time.perf_counter() - t0 - drain_s
        self.metrics["reconfig_s"] += downtime
        self.metrics["reconfigs"] += 1
        if delta is not None:
            # partial-reconfiguration report (DESIGN.md §10.3): only the
            # diffed experts migrate; everything else stays in place
            self.metrics["last_delta_traffic_gib"] = \
                delta["traffic_bytes"] / 2**30
            self.metrics["last_migrated_experts"] = len(delta["migrated"])
            self.metrics["last_migrated_bytes"] = delta["traffic_bytes"]
            self.metrics["last_reconfig_downtime_s"] = downtime
            self.metrics["migrated_bytes_total"] = \
                self.metrics.get("migrated_bytes_total", 0) \
                + delta["traffic_bytes"]
        return result

    # ------------------------------------------------------------------
    # Dynamic precision (DESIGN.md §15)
    # ------------------------------------------------------------------
    @property
    def current_plan(self) -> Optional[PrecisionPlan]:
        """The active precision plan (None before the first replan)."""
        return self._plan_result.plan if self._plan_result is not None \
            else None

    def reset_route_counts(self) -> None:
        """Zero the accumulated routing histogram (callers that window
        it — like the dynamic controller — snapshot instead)."""
        self.route_counts[...] = 0

    def apply_bits_update(self, new_bits: np.ndarray) -> Dict[str, Any]:
        """In-place rung flips (DESIGN.md §15): same expert locations,
        same per-layer rung counts, only the bits[L, E] ASSIGNMENT
        changes. This is the :class:`DynamicPrecisionController`'s apply
        path — diff-only, no planner replan, no drain, no recompile:

        * bank shapes are unchanged (per-layer rung counts preserved by
          contract), so the jitted step functions stay specialized and
          only the serve-layout banks + router permutation rebuild;
        * flipped experts resident in the swap cache are re-staged at
          their new rung through ``ExpertCache.update()``, which charges
          exactly the byte delta (byte-conservation is tested).

        Returns a report dict: flipped/promotions/demotions counts, the
        summed cache byte delta, and the number of re-staged entries.
        """
        assert self._plan_result is not None, "no active plan"
        old_plan = self._plan_result.plan
        new_bits = np.asarray(new_bits, old_plan.bits.dtype)
        if new_bits.shape != old_plan.bits.shape:
            raise ValueError(f"bits shape {new_bits.shape} != "
                             f"{old_plan.bits.shape}")
        for b in np.unique(new_bits).tolist():
            if int(b) not in old_plan.ladder:
                raise ValueError(f"rung {b} not on ladder "
                                 f"{old_plan.ladder}")
        for li in range(new_bits.shape[0]):
            for b in old_plan.ladder:
                if int((new_bits[li] == b).sum()) \
                        != int((old_plan.bits[li] == b).sum()):
                    raise ValueError(
                        "apply_bits_update must preserve per-layer rung "
                        f"counts (layer {li}, rung {b}): a count change "
                        "is a bank split — use apply_frontier_point")
        flipped = new_bits != old_plan.bits
        promotions = int((new_bits > old_plan.bits).sum())
        demotions = int((new_bits < old_plan.bits).sum())
        report: Dict[str, Any] = {
            "flipped": int(flipped.sum()), "promotions": promotions,
            "demotions": demotions, "cache_bytes_delta": 0,
            "restaged": 0,
        }
        if not report["flipped"]:
            return report
        t0 = time.perf_counter()
        # async staging barrier: in-flight transfers carry OLD-rung blobs
        self.expert_cache.drain()
        new_plan = dataclasses.replace(old_plan, bits=new_bits)
        # same bank shapes -> the jitted step functions stay valid; only
        # the bank contents and the router permutation change
        self._serve_params = apply_precision_plan(
            self.params_train, self.cfg, new_plan)
        self._plan_result = dataclasses.replace(
            self._plan_result, plan=new_plan,
            qos=estimate_qos(self.cfg, new_plan, self.planner.hw,
                             self.max_slots, self.planner.profile))
        # keep the planner's replan diffing anchored on the live plan
        self.planner.current = self._plan_result
        self._order = new_plan.expert_order()
        flipped_keys = {(int(l), int(e)) for l, e in np.argwhere(flipped)}
        for k in list(self._host_store):
            if (k[0], k[1]) in flipped_keys:
                del self._host_store[k]     # re-quantize at the new rung
        for key in list(self.expert_cache.resident_keys()):
            if (key[0], key[1]) in flipped_keys:
                report["cache_bytes_delta"] += \
                    self.expert_cache.update(key, self._fetch_expert(key))
                report["restaged"] += 1
        hit, self._miss_bytes_per_tok = expert_access_stats(self.cfg,
                                                            new_plan)
        self.metrics["miss_rate"] = 1.0 - hit
        self.metrics["reconfig_s"] += time.perf_counter() - t0
        self.metrics["bits_updates"] = \
            self.metrics.get("bits_updates", 0) + 1
        self.metrics["rung_flips"] = \
            self.metrics.get("rung_flips", 0) + report["flipped"]
        return report

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16, *,
               sampling: Optional[SamplingParams] = None,
               slo: Optional[RequestSLO] = None,
               now: Optional[float] = None) -> int:
        """Submit a request; forwards sampling/SLO/arrival-time to the
        scheduler's richer ``submit`` (admission is by priority with
        deadline-aware ordering — DESIGN.md §9)."""
        return self.scheduler.submit(prompt, max_new_tokens, now,
                                     sampling=sampling, slo=slo)

    def submit_request(self, request: ServeRequest) -> int:
        """Typed-surface spelling of ``submit`` (serving/api.py)."""
        return self.submit(request.prompt, request.max_new_tokens,
                           sampling=request.sampling, slo=request.slo)

    def result(self, rid: int) -> ServeResult:
        """The ServeResult of a completed request (KeyError while the
        request is queued or in flight)."""
        return ServeResult.from_request(self.scheduler.done[rid])

    def _jit(self, name, fn, donate=()):
        if name not in self._compiled:
            self._compiled[name] = jax.jit(fn, donate_argnums=donate)
        return self._compiled[name]

    # -- expert streaming ----------------------------------------------
    def _fetch_expert(self, key):
        """Host loader for the expert swap cache: the expert's weights in
        the precision RUNG the active plan assigns it (packed int4/int8 +
        scales or bf16), staged from the train-layout master copy."""
        li, ei = key[0], key[1]
        blob = self._host_store.get((li, ei))
        if blob is None:
            t0 = time.perf_counter()
            moe_p = self.params_train["layers"]["moe"]
            w = {k: np.asarray(moe_p[k][li, ei])
                 for k in ("w_gate", "w_up", "w_down")}
            bits = int(self._plan_result.plan.bits[li, ei])
            if bits < 16:
                from repro.core.quantization import quantize
                gs = self._plan_result.plan.group_size
                blob = {}
                for k, v in w.items():
                    qt = quantize(jnp.asarray(v), bits, gs)
                    blob[k] = {"q": np.asarray(qt.q),
                               "scales": np.asarray(qt.scales)}
            else:
                blob = w
            self._host_store[(li, ei)] = blob
            # host-side staging (extraction + on-the-fly quantization) is
            # real request-latency but neither decode nor transfer time;
            # locked: async transfer workers run this loader concurrently
            with self._stage_lock:
                self.metrics["stage_s"] += time.perf_counter() - t0
        return blob

    def _stream_experts(self, route_ids: np.ndarray, rows: List[int]):
        """Feed the routed (layer, expert) accesses of one decode
        iteration through the runtime cache; resident experts are HBM
        hits, the rest go through the LRU swap space.

        Metric semantics: ``miss_rate`` (analytic) assumes every
        non-resident access streams (the paper's memoryless model);
        ``miss_rate_measured`` counts accesses that actually transferred —
        LRU swap hits don't stream, so measured < estimated quantifies the
        temporal locality the paper's uniform-routing model ignores.
        Caveat at smoke scale: ``transfer_s`` can exceed ``transfer_s_est``
        because the bandwidth term is calibrated on a bulk transfer while
        smoke-scale experts are small enough that per-``device_put``
        latency dominates; at paper-scale expert sizes (hundreds of MB)
        the bandwidth term is the honest model."""
        st = self.expert_cache.stats
        blocked0 = st.transfer_s + st.prefetch_s
        if self._prefetch and self._prev_demanded:
            # temporal-locality prefetch BEFORE this iteration's demand:
            # decode re-demands most of the previous iteration's experts
            # (same requests, adjacent tokens); anything evicted since is
            # re-staged speculatively so the demand below hits.
            self.expert_cache.hint(self._prev_demanded)
        order = self._order
        demanded = set()
        for li in range(route_ids.shape[0]):
            for b in rows:
                for slot_id in route_ids[li, b]:
                    ei = int(order[li, int(slot_id)])
                    demanded.add((li, ei))
                    self.route_counts[li, ei] += 1
        misses0 = st.misses
        for key in sorted(demanded):
            self.metrics["expert_accesses"] += 1
            if key in self._resident:
                continue
            self.expert_cache.get(key)
        self.metrics["expert_fetches"] += st.misses - misses0
        self._prev_demanded = [k for k in sorted(demanded)
                               if k not in self._resident]
        # serial staging blocks the critical path for every transferred
        # second (speculative hints included) — all of it is EXPOSED
        self.metrics["transfer_exposed_s"] += \
            st.transfer_s + st.prefetch_s - blocked0
        self._finish_stream_metrics()

    def _finish_stream_metrics(self):
        """Fold the cache's counters into engine metrics. ``transfer_s``
        is DEMAND transfer only (speculative staging reports separately
        as ``prefetch_s`` — DESIGN.md §12); ``transfer_overlapped_s`` is
        the transferred time that did NOT block the critical path."""
        st = self.expert_cache.stats
        self.metrics["transfer_s"] = st.transfer_s
        self.metrics["prefetch_s"] = st.prefetch_s
        self.metrics["transfer_overlapped_s"] = max(
            st.transfer_s + st.prefetch_s
            - self.metrics["transfer_exposed_s"], 0.0)
        if self.metrics["expert_accesses"]:
            self.metrics["miss_rate_measured"] = \
                self.metrics["expert_fetches"] \
                / self.metrics["expert_accesses"]

    def _decode_pipelined(self, toks, pos, rows):
        """Per-layer lookahead pipeline (DESIGN.md §12): while layer L
        computes, layer L+1's PREDICTED experts (the previous iteration's
        captured routes for that layer) stage on the async cache's
        workers; each layer's ACTUAL routed demand is then awaited, so
        only the transfer time prediction could not hide is exposed.
        Numerically identical to the scanned decode step (tested
        bit-exact). Returns the next-token logits (B, V).

        Exposed-time semantics: ``transfer_exposed_s`` is BLOCKED
        WALL-CLOCK, so on a cold host store it also covers the demand
        fetch's host-side staging (extraction + quantization) that the
        sync path books under ``stage_s`` — exposed can then exceed the
        device-transfer counters and ``transfer_overlapped_s`` clamps to
        0. The host store is warm after first touch per (expert, plan),
        so at steady state exposed converges to true transfer waits;
        calibrate_overlap() should run on a warm store (same spirit as
        the smoke-scale transfer_s vs transfer_s_est caveat above)."""
        m, params = self.model, self._serve_params
        cache = self.expert_cache
        st = cache.stats
        embed = self._jit("decode_embed", m.decode_embed)
        # the cache argument is DONATED: each per-layer call rebinds
        # self.cache (or the paged pool), so XLA aliases the per-layer
        # update in place instead of copying the whole multi-layer KV
        # cache L times per token (nothing else holds the old buffer)
        if self.paged:
            layer_fn = self._jit(
                "decode_layer_paged", functools.partial(
                    m.paged_decode_layer_routed, window=self.window),
                donate=(1,))
            pt_dev = jnp.asarray(self.kv_alloc.table)
        else:
            layer_fn = self._jit("decode_layer", m.decode_layer_routed,
                                 donate=(1,))
        finish = self._jit("decode_logits", m.decode_logits)
        pos_j = jnp.asarray(pos)
        n_layers = self.cfg.num_layers
        predicted = self._prev_layer_keys
        misses0 = st.misses
        exposed = 0.0
        t_loop0 = time.perf_counter()
        x = embed(params, jnp.asarray(toks))
        if predicted is not None and n_layers:
            cache.prefetch(predicted[0])
        new_layer_keys: List[List[Tuple[int, int]]] = []
        for li in range(n_layers):
            if self.paged:
                x, self.kv_pool, ids = layer_fn(
                    params, self.kv_pool, pt_dev, x, pos_j, jnp.int32(li))
            else:
                x, self.cache, ids = layer_fn(params, self.cache, x,
                                              pos_j, jnp.int32(li))
            if predicted is not None and li + 1 < n_layers:
                # lookahead: stage layer li+1's predicted demand while
                # layer li's compute is still in flight
                cache.prefetch(predicted[li + 1])
            ids_np = np.asarray(ids)       # blocks on layer li's compute
            order = self._order[li]
            np.add.at(self.route_counts[li],
                      order[ids_np[rows].astype(np.int64).ravel()], 1)
            demanded = sorted({(li, int(order[int(s)]))
                               for b in rows for s in ids_np[b]})
            self.metrics["expert_accesses"] += len(demanded)
            need = [k for k in demanded if k not in self._resident]
            t0 = time.perf_counter()
            cache.wait(need)
            exposed += time.perf_counter() - t0
            new_layer_keys.append(need)
        logits = finish(params, x)
        jax.block_until_ready(logits)
        t_loop = time.perf_counter() - t_loop0
        self.metrics["decode_s"] += max(t_loop - exposed, 0.0)
        self.metrics["transfer_exposed_s"] += exposed
        self.metrics["expert_fetches"] += st.misses - misses0
        self._prev_layer_keys = new_layer_keys
        self._finish_stream_metrics()
        return logits

    # -- iteration-level serving ----------------------------------------
    @staticmethod
    def _sampling_of(req: Request, default_temperature: float
                     ) -> Tuple[float, int]:
        """(temperature, top_k) for a request: its own SamplingParams win
        over the engine-level default."""
        if req.sampling is not None:
            return req.sampling.temperature, req.sampling.top_k
        return default_temperature, 0

    def _prefill_slot(self, slot: int, req: Request,
                      temperature: float) -> Optional[int]:
        """Join ``req`` into ``slot``; returns its rid if it already
        retired (max_new_tokens == 1 — the prefill logit is the whole
        generation), else None."""
        s = len(req.prompt)
        if self.paged:
            # page-sized compile buckets replace the power-of-two ones
            # (DESIGN.md §13): pad waste per prefill is < one page
            ps = self.kv_meta.page_size
            sb = min(-(-s // ps) * ps, self.window)
            sb = max(sb, s)           # window may not be a page multiple
            self.kv_alloc.ensure_prefix(slot, min(s, self.window))
            fn = self._jit(("prefill_slot_paged", sb), functools.partial(
                self.model.paged_prefill_into_slot, window=self.window))
        else:
            sb = _bucket(s, hi=self.window)
            fn = self._jit(("prefill_slot", sb),
                           self.model.prefill_into_slot)
        toks = np.zeros((1, sb), np.int32)
        pos = np.full((1, sb), -1, np.int32)
        toks[0, :s] = req.prompt
        pos[0, :s] = np.arange(s)
        t0 = time.perf_counter()
        if self.paged:
            logits, self.kv_pool = fn(
                self._serve_params, self.kv_pool,
                jnp.asarray(self.kv_alloc.table[slot]),
                jnp.asarray(toks), jnp.asarray(pos), jnp.int32(s - 1))
        else:
            logits, self.cache = fn(self._serve_params, self.cache,
                                    jnp.asarray(toks), jnp.asarray(pos),
                                    jnp.int32(slot), jnp.int32(s - 1))
        jax.block_until_ready(logits)
        self.metrics["prefill_s"] += time.perf_counter() - t0
        self._key, sub = jax.random.split(self._key)
        temp, top_k = self._sampling_of(req, temperature)
        tok = int(sample(logits, key=sub, temperature=temp, top_k=top_k,
                         vocab_size=self.cfg.vocab_size)[0])
        now = time.perf_counter()
        req.out_tokens.append(tok)
        req.t_first = now
        self.metrics["tokens_generated"] += 1
        st = self.scheduler.slots[slot]
        st.last_token = tok
        if req.done():                      # max_new_tokens == 1
            self.scheduler.retire(slot, now=now)
            self._release_slot_kv(slot)
            return req.rid
        return None

    def _release_slot_kv(self, slot: int):
        """Retire a slot's KV: paged -> free its pages (tags invalidated
        on device before reuse); slot cache -> invalidate the row."""
        if self.paged:
            freed = self.kv_alloc.free_slot(slot)
            buf = np.zeros(self.kv_meta.chunks_per_slot, np.int32)
            buf[:len(freed)] = freed
            self.kv_pool = self._jit(
                "paged_reset", self.model.paged_reset_pages)(
                    self.kv_pool, jnp.asarray(buf))
        else:
            self.cache = self._jit("reset_slot", self.model.reset_slot)(
                self.cache, jnp.int32(slot))

    def _update_kv_metrics(self, active):
        """Per-iteration KV padding accounting (DESIGN.md §13)."""
        tb = self._kv_token_bytes
        used = sum(min(st.position + 1, self.window)
                   for _, st in active) * tb
        if self.paged:
            alloc = self.kv_alloc.pages_in_use \
                * self.kv_meta.page_size * tb
        else:
            alloc = self.max_slots * self.window * tb
        self.metrics["kv_used_bytes"] = used
        self.metrics["kv_allocated_bytes"] = alloc
        self.metrics["kv_used_byte_iters"] += used
        self.metrics["kv_alloc_byte_iters"] += alloc

    def kv_waste_fraction(self) -> float:
        """Run-averaged fraction of allocated KV bytes never holding a
        valid token (bucket padding waste; ~0 under the paged cache)."""
        alloc = self.metrics["kv_alloc_byte_iters"]
        if alloc <= 0:
            return 0.0
        return 1.0 - self.metrics["kv_used_byte_iters"] / alloc

    # -- self-speculative decoding (DESIGN.md §17) ----------------------
    def set_speculation(self, k: int) -> None:
        """Set the draft depth for ladder-draft speculative decoding;
        ``0`` falls back to plain decode (the QoSController's low-
        acceptance auto-fallback calls this). Takes effect from the next
        iteration — no drain, no recompile (the plain step functions
        stay cached)."""
        self.speculate_k = max(0, int(k))

    def _draft_serve_params(self):
        """Serve-layout params with EVERY expert at the lowest ladder
        rung — the paper's all-quantized configuration, i.e. the free
        draft model (the low-rung banks already exist as quantized
        views of the same master weights; no new information, just the
        all-low layout). Cached across replans: the draft depends only
        on (ladder, group_size), never on the serving rung assignment
        or placement."""
        plan = self._plan_result.plan
        low = quantized_rungs(plan.ladder)[0]
        sig = (tuple(plan.ladder), plan.group_size, low)
        if self._draft_params is None or self._draft_sig != sig:
            draft_plan = dataclasses.replace(
                plan, bits=np.full_like(plan.bits, low),
                location=np.full_like(plan.location, DEVICE))
            self._draft_params = apply_precision_plan(
                self.params_train, self.cfg, draft_plan)
            self._draft_sig = sig
        return self._draft_params

    def _greedy_np(self, row: np.ndarray) -> int:
        """Host-side greedy pick, identical to ``sampler.sample``'s
        temperature<=0 branch (same -1e30 vocab-pad mask, same
        first-max tie-break) — the acceptance comparison must match
        what plain decode would emit, bit for bit."""
        v = self.cfg.vocab_size
        if v and row.shape[-1] > v:
            row = np.where(np.arange(row.shape[-1]) >= v, -1e30, row)
        return int(np.argmax(row))

    def _probs_np(self, row: np.ndarray, temp: float, top_k: int
                  ) -> np.ndarray:
        """Host-side mirror of ``sampler.sample_probs`` (f64): the
        categorical distribution the engine samples from at this
        temperature/top_k — both the draft proposal q and the verify
        target p for the rejection-sampled acceptance."""
        x = np.asarray(row, np.float64).copy()
        v = self.cfg.vocab_size
        if v and x.shape[-1] > v:
            x[v:] = -1e30
        x = x / temp
        if top_k:
            thresh = np.partition(x, -top_k)[-top_k]
            x = np.where(x < thresh, -1e30, x)
        x -= x.max()
        e = np.exp(x)
        return e / e.sum()

    def _spec_iteration(self, active, temperature: float,
                        retired: List[int]) -> List[int]:
        """One speculative iteration (DESIGN.md §17): K draft tokens per
        slot at the lowest ladder rung, ONE batched verify forward at
        the serving plan scoring all K+1 positions against the KV
        cache, longest-prefix acceptance (greedy) or chain rejection
        sampling (temperature>0), then device-side rollback of the
        rejected tail + paged-KV truncation.

        Per-slot draft depth is clamped to ``min(K, remaining-1,
        window-1-position)``: the remaining-token clamp keeps the
        emitted count inside the request's claim, the window clamp
        keeps all speculative writes in the UNWRAPPED ring region so a
        multi-token write can never clobber an entry a same-batch query
        still attends (a slot at the wrap boundary rides the verify as
        plain single-token decode). Overlap mode uses this sync step
        too — the per-layer lookahead pipeline stays plain-decode-only;
        expert streaming still runs through the (async) cache's
        synchronous interface."""
        K = self.speculate_k
        S = K + 1
        B = self.max_slots
        depth: Dict[int, int] = {}
        for i, st in active:
            rem = st.req.max_new_tokens - len(st.req.out_tokens)
            depth[i] = max(0, min(K, rem - 1,
                                  self.window - 1 - st.position))
        if self.paged:
            # map every chunk the draft+verify writes touch up front;
            # the admission claim already covers the full span
            for i, st in active:
                for j in range(depth[i] + 1):
                    self.kv_alloc.ensure_index(
                        i, (st.position + j) % self.window)
            step = self._jit("spec_paged", functools.partial(
                self.model.paged_spec_step_routed, window=self.window))
        else:
            step = self._jit("spec", self.model.spec_step_routed)

        def run_step(params, toks, pos):
            # one jit entry serves both shapes: draft (B,1), verify (B,S)
            if self.paged:
                logits, self.kv_pool, ids = step(
                    params, self.kv_pool,
                    jnp.asarray(self.kv_alloc.table),
                    jnp.asarray(toks), jnp.asarray(pos))
            else:
                logits, self.cache, ids = step(
                    params, self.cache, jnp.asarray(toks),
                    jnp.asarray(pos))
            return logits, ids

        self._key, k_draft, k_verify = jax.random.split(self._key, 3)
        u_draft = u_acc = u_res = None
        t0 = time.perf_counter()
        # -- draft pass: up to K single-token steps at the lowest rung --
        draft_params = self._draft_serve_params()
        drafts: Dict[int, List[int]] = {i: [] for i, _ in active}
        q_rows: Dict[int, List[np.ndarray]] = {i: [] for i, _ in active}
        prev_tok = {i: st.last_token for i, st in active}
        for t in range(max(depth.values(), default=0)):
            toks = np.zeros((B, 1), np.int32)
            pos = np.full((B, 1), -1, np.int32)
            rows = [i for i, st in active if depth[i] > t]
            for i, st in active:
                if depth[i] > t:
                    toks[i, 0] = prev_tok[i]
                    pos[i, 0] = st.position + t
            logits, _ = run_step(draft_params, toks, pos)
            lg = np.asarray(logits)[:, 0]
            for i in rows:
                temp, top_k = self._sampling_of(
                    self.scheduler.slots[i].req, temperature)
                if temp <= 0.0:
                    tok = self._greedy_np(lg[i])
                else:
                    if u_draft is None:
                        u_draft = np.asarray(jax.random.uniform(
                            k_draft, (max(K, 1), B)))
                    q = self._probs_np(lg[i], temp, top_k)
                    cdf = np.cumsum(q)
                    tok = int(min(np.searchsorted(
                        cdf, float(u_draft[t, i]), side="right"),
                        len(cdf) - 1))
                    q_rows[i].append(q)
                drafts[i].append(tok)
                prev_tok[i] = tok
        # -- batched verify at the serving plan (exact) -----------------
        toks = np.zeros((B, S), np.int32)
        pos = np.full((B, S), -1, np.int32)
        for i, st in active:
            toks[i, 0] = st.last_token
            pos[i, 0] = st.position
            for j, d in enumerate(drafts[i]):
                toks[i, j + 1] = d
                pos[i, j + 1] = st.position + j + 1
        logits, route_ids = run_step(self._serve_params, toks, pos)
        jax.block_until_ready(logits)
        lg = np.asarray(logits)                       # (B, S, V)
        self.metrics["decode_s"] += time.perf_counter() - t0
        # only the verify's routes feed the expert stream / histogram:
        # the draft banks are fully resident by construction
        rows = [i * S + j for i, _ in active
                for j in range(depth[i] + 1)]
        self._stream_experts(np.asarray(route_ids), rows)
        n_tok = sum(depth[i] + 1 for i, _ in active)
        e = self.cfg.moe.num_experts
        d = self.cfg.moe.top_k * n_tok
        uniq = e * (1.0 - (1.0 - 1.0 / e) ** d)
        self.metrics["transfer_s_est"] += \
            self._miss_bytes_per_tok * uniq / self.cfg.moe.top_k \
            / self.hw.host_link_bw
        # -- acceptance -------------------------------------------------
        keep = np.full((B,), np.iinfo(np.int32).max // 2, np.int32)
        emitted: Dict[int, List[int]] = {}
        for i, st in active:
            k_i = depth[i]
            temp, top_k = self._sampling_of(st.req, temperature)
            if temp <= 0.0:
                targets = [self._greedy_np(lg[i, j])
                           for j in range(k_i + 1)]
                a = 0
                while a < k_i and drafts[i][a] == targets[a]:
                    a += 1
                out = drafts[i][:a] + [targets[a]]
            else:
                if u_acc is None:
                    k_acc, k_res = jax.random.split(k_verify)
                    u_acc = np.asarray(jax.random.uniform(
                        k_acc, (B, max(K, 1))))
                    u_res = np.asarray(jax.random.uniform(
                        k_res, (B, S)))
                p = np.stack([self._probs_np(lg[i, j], temp, top_k)
                              for j in range(k_i + 1)])
                q = np.stack(q_rows[i]) if k_i \
                    else np.zeros((0, p.shape[1]))
                a, final = speculative_verify(
                    np.asarray(drafts[i][:k_i], np.int64), q, p,
                    u_acc[i, :k_i], u_res[i, :k_i + 1])
                out = drafts[i][:a] + [final]
            emitted[i] = out
            keep[i] = st.position + len(out) - 1   # last accepted pos
            self.metrics["spec_proposed"] += k_i
            self.metrics["spec_accepted"] += len(out) - 1
        # -- device-side rollback of the rejected tail ------------------
        if any(depth[i] for i, _ in active):
            if self.paged:
                self.kv_pool = self._jit(
                    "paged_rollback", self.model.paged_rollback)(
                        self.kv_pool, jnp.asarray(self.kv_alloc.table),
                        jnp.asarray(keep))
            else:
                self.cache = self._jit(
                    "rollback", self.model.rollback_slots)(
                        self.cache, jnp.asarray(keep))
        self._update_kv_metrics(active)
        self.metrics["iterations"] += 1
        if self.metrics["spec_proposed"]:
            self.metrics["acceptance_rate"] = \
                self.metrics["spec_accepted"] \
                / self.metrics["spec_proposed"]
        now = time.perf_counter()
        for i, st in active:
            for tok in emitted[i]:
                st.req.out_tokens.append(int(tok))
            self.metrics["tokens_generated"] += len(emitted[i])
            st.position += len(emitted[i])
            st.last_token = int(emitted[i][-1])
            if st.req.done():
                self.scheduler.retire(i, now=now)
                self._release_slot_kv(i)
                retired.append(st.req.rid)
            elif self.paged and depth[i]:
                # free pages holding only rejected tokens (their tags
                # were invalidated by the rollback above); speculative
                # spans are pre-wrap by the depth clamp, so the live
                # ring is exactly the prefix 0..position-1
                self.kv_alloc.truncate(i, st.position)
        return retired

    def run_iteration(self, *, admit: bool = True,
                      temperature: float = 0.0) -> List[int]:
        """One scheduler iteration: join new requests into free slots,
        decode ONE token for every active slot, retire finished requests.
        Returns the rids retired this iteration."""
        if self._plan_result is None:
            raise RuntimeError(
                "no active plan: apply_target() or configure() first")
        retired: List[int] = []
        if admit:
            for slot, req in self.scheduler.admit():
                rid = self._prefill_slot(slot, req, temperature)
                if rid is not None:
                    retired.append(rid)
        active = self.scheduler.active()
        if not active:
            return retired
        if self.speculate_k > 0:
            # ladder-draft speculation (DESIGN.md §17) replaces the
            # one-token body below; speculate_k == 0 keeps this method
            # byte-identical to the pre-speculation engine.
            return self._spec_iteration(active, temperature, retired)
        toks = np.zeros((self.max_slots, 1), np.int32)
        pos = np.full((self.max_slots,), -1, np.int32)  # idle rows masked
        for i, st in active:
            toks[i, 0] = st.last_token
            pos[i] = st.position
        if self.paged:
            # map the chunk each active slot's ring write lands in BEFORE
            # the jitted step (host-side page table, device-side pool)
            for i, st in active:
                self.kv_alloc.ensure_index(i, st.position % self.window)
        route_ids = None
        if self._pipeline:
            # overlap mode: decode through the per-layer lookahead
            # pipeline; expert streaming happens inside (DESIGN.md §12)
            logits = self._decode_pipelined(toks, pos,
                                            [i for i, _ in active])
        elif self.paged:
            decode = self._jit("decode_paged", functools.partial(
                self.model.paged_decode_step_routed, window=self.window))
            t0 = time.perf_counter()
            logits, self.kv_pool, route_ids = decode(
                self._serve_params, self.kv_pool,
                jnp.asarray(self.kv_alloc.table), jnp.asarray(toks),
                jnp.asarray(pos))
            jax.block_until_ready(logits)
            self.metrics["decode_s"] += time.perf_counter() - t0
        else:
            decode = self._jit("decode", self.model.decode_step_routed)
            t0 = time.perf_counter()
            logits, self.cache, route_ids = decode(
                self._serve_params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos))
            jax.block_until_ready(logits)
            self.metrics["decode_s"] += time.perf_counter() - t0
        self._update_kv_metrics(active)
        self.metrics["iterations"] += 1
        self._key, sub = jax.random.split(self._key)
        if any(st.req.sampling is not None for _, st in active):
            # heterogeneous per-request SamplingParams: sample row-wise
            # (the batched path below stays bit-identical when no request
            # carries its own parameters)
            new_toks = np.zeros((self.max_slots,), np.int32)
            keys = jax.random.split(sub, self.max_slots)
            for i, st in active:
                temp, top_k = self._sampling_of(st.req, temperature)
                new_toks[i] = int(sample(
                    logits[i:i + 1], key=keys[i], temperature=temp,
                    top_k=top_k, vocab_size=self.cfg.vocab_size)[0])
        else:
            new_toks = np.asarray(sample(logits, key=sub,
                                         temperature=temperature,
                                         vocab_size=self.cfg.vocab_size))
        if route_ids is not None:     # sync path (pipelined streams inline)
            self._stream_experts(np.asarray(route_ids),
                                 [i for i, _ in active])
        # analytical cross-check: expected UNIQUE streamed bytes of this
        # iteration under uniform routing. n_active rows draw
        # d = top_k * n_active experts per layer; each off-device expert
        # streams iff drawn at least once, so the per-token expectation
        # (miss_bytes_per_tok = sum_offdev size/E * top_k) is rescaled by
        # E * (1 - (1-1/E)^d) / top_k. Measured below this estimate then
        # isolates CROSS-iteration locality (the LRU's contribution).
        e = self.cfg.moe.num_experts
        d = self.cfg.moe.top_k * len(active)
        uniq = e * (1.0 - (1.0 - 1.0 / e) ** d)
        self.metrics["transfer_s_est"] += \
            self._miss_bytes_per_tok * uniq / self.cfg.moe.top_k \
            / self.hw.host_link_bw
        now = time.perf_counter()
        for i, st in active:
            st.req.out_tokens.append(int(new_toks[i]))
            self.metrics["tokens_generated"] += 1
            st.position += 1
            st.last_token = int(new_toks[i])
            if st.req.done():
                self.scheduler.retire(i, now=now)
                self._release_slot_kv(i)
                retired.append(st.req.rid)
        return retired

    def step(self, *, temperature: float = 0.0, seed: Optional[int] = None
             ) -> int:
        """Serve until the queue and all slots are empty; returns the
        number of requests finished by this call. (Compatibility wrapper —
        iteration-level control lives in ``run_iteration``.)"""
        if self._plan_result is None:
            raise RuntimeError(
                "no active plan: apply_target() or configure() first")
        if seed is not None:
            self._key = jax.random.key(seed)
        finished = 0
        while self.scheduler.has_work():
            finished += len(self.run_iteration(temperature=temperature))
        return finished

    # ------------------------------------------------------------------
    # Reporting
    # ------------------------------------------------------------------
    def throughput_tokens_per_s(self, include_transfer: bool = True
                                ) -> float:
        """Measured tokens/s. ``include_transfer`` charges the EXPOSED
        transfer time only (DESIGN.md §12) — for serial staging that IS
        the total blocked transfer time; in overlap mode the hidden
        portion already overlaps decode wall-clock and must not be
        double-counted."""
        t = self.metrics["decode_s"]
        if include_transfer:
            t += self.metrics["transfer_exposed_s"]
        return self.metrics["tokens_generated"] / max(t, 1e-9)

    def measured_overlap_efficiency(self) -> Optional[float]:
        """Measured overlap window as a fraction of decode compute —
        the runtime counterpart of ``HardwareModel.overlap_efficiency``
        (a LOWER bound when every transfer hid completely). None until
        any expert time was transferred, and None through a SHARED
        scoped cache view: its speculative traffic is accounted
        parent-globally, so the per-tenant hidden/total ratio is not
        measurable — folding the apparent ~0 into the hardware model
        would wrongly revert the frontier to the additive ranking."""
        if not self._owns_cache \
                and getattr(self.expert_cache, "parent", None) is not None:
            return None
        total = self.metrics["transfer_s"] + self.metrics["prefetch_s"]
        if total <= 0 or self.metrics["decode_s"] <= 0:
            return None
        eff = self.metrics["transfer_overlapped_s"] \
            / self.metrics["decode_s"]
        return max(0.0, min(1.0, eff))

    def calibrate_overlap(self) -> Optional[float]:
        """Fold the MEASURED overlap efficiency back into the analytic
        hardware model and invalidate the cached frontier (DESIGN.md
        §12), so subsequent plans/frontier walks rank configurations by
        the transfer time this deployment actually exposes. Returns the
        calibrated efficiency, or None when nothing was measured yet."""
        eff = self.measured_overlap_efficiency()
        if eff is None:
            return None
        self.hw = dataclasses.replace(self.hw, overlap_efficiency=eff)
        self.planner.recalibrate(self.hw)
        self._frontier = None
        return eff

    def close(self):
        """Release the transfer pipeline: join the async cache's worker
        threads (no-op for serial staging). A SHARED scoped view is only
        drained — its owner (e.g. the MultiTenantEngine) closes the
        space. Idempotent; the engine must not decode afterwards."""
        if self._owns_cache:
            self.expert_cache.close()
        else:
            self.expert_cache.drain()

    def latency_percentiles(self, qs=(50, 95),
                            last_n: Optional[int] = None
                            ) -> Dict[str, float]:
        return self.scheduler.latency_percentiles(qs, last_n=last_n)

    def reset_counters(self):
        """Zero the throughput counters (between benchmark operating
        points); plan/reconfig counters are preserved."""
        for k in ("tokens_generated", "decode_s", "prefill_s",
                  "transfer_s", "transfer_s_est", "stage_s",
                  "prefetch_s", "transfer_exposed_s",
                  "transfer_overlapped_s",
                  "expert_accesses", "expert_fetches", "iterations",
                  "kv_alloc_byte_iters", "kv_used_byte_iters",
                  "spec_proposed", "spec_accepted", "acceptance_rate"):
            self.metrics[k] = 0 if isinstance(self.metrics[k], int) else 0.0
        self.expert_cache.stats.reset()

    def summary(self) -> str:
        p = self._plan_result
        lat = self.latency_percentiles()
        m = self.metrics
        overlap = ""
        if self._pipeline or m["prefetch_s"] or m["transfer_overlapped_s"]:
            overlap = (f" xfer[prefetch={m['prefetch_s']:.3f}s"
                       f" exposed={m['transfer_exposed_s']:.3f}s"
                       f" hidden={m['transfer_overlapped_s']:.3f}s]")
        rungs = [b for b in p.plan.ladder if b < 16]
        if len(rungs) <= 1:
            knobs = (f"E{rungs[0] if rungs else 4}="
                     f"{p.plan.num_q_experts}/{p.plan.quant.size}")
        else:
            # multi-rung ladder: num_q_experts conflates the rungs —
            # spell counts per rung like FrontierPoint.summary()
            knobs = "E[" + ",".join(
                f"{b}b={int((p.plan.bits == b).sum())}"
                for b in rungs) + f"]/{p.plan.bits.size}"
        # KV padding accounting (DESIGN.md §13): run-averaged allocated
        # vs used bytes; waste is the padding the paged cache eliminates
        it = max(m["iterations"], 1)
        kv = (f" kv[{'paged' if self.paged else 'slots'}"
              f" alloc={m['kv_alloc_byte_iters'] / it / 2**20:.2f}MiB"
              f" used={m['kv_used_byte_iters'] / it / 2**20:.2f}MiB"
              f" waste={self.kv_waste_fraction():.0%}]")
        spec = ""
        if m["spec_proposed"]:
            spec = (f" spec[k={self.speculate_k}"
                    f" acc={m['acceptance_rate']:.0%}"
                    f" {m['spec_accepted']}/{m['spec_proposed']}]")
        kv += spec
        return (f"plan[{p.preference} {knobs}"
                f" res={p.plan.resident_fraction():.0%}]"
                f" gen={m['tokens_generated']}tok"
                f" decode={m['decode_s']:.2f}s"
                f" +transfer={m['transfer_s']:.3f}s"
                f" (est {m['transfer_s_est']:.3f}s)"
                + overlap + kv +
                f" -> {self.throughput_tokens_per_s():.2f} tok/s"
                f" p50={lat['p50']*1e3:.0f}ms p95={lat['p95']*1e3:.0f}ms")
