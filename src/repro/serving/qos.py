"""QoS feedback controller — keeps a serving engine on its declarative
target at runtime (DESIGN.md §9).

The cost model picks the *initial* frontier point for a
:class:`~repro.core.pareto.QoSTarget`, but analytic tokens/s and the
wall-clock tokens/s of a live deployment drift apart (interference from
co-tenants, cache temperature, real link bandwidth, batch occupancy). The
controller closes the loop: ``step()`` runs BETWEEN decode iterations,
compares the measured throughput (and, when targeted, p95 latency)
against the active target, and when the measurement leaves the tolerance
band walks the :class:`~repro.core.pareto.ParetoFrontier` to the
*adjacent* point — one step at a time, through the engine's ordinary
mid-flight replan path, so a placement-only move applies with zero drain
and a bank-split move drains gracefully. On a multi-rung precision
ladder (DESIGN.md §11) an adjacent point may PROMOTE or DEMOTE experts
between rungs (e.g. 4->8 bit) instead of only swapping counts or
residency; the ``rung_promotions``/``rung_demotions`` metrics count
those steps.

Stability comes from two guards:

* **hysteresis** — after any replan the controller dwells for
  ``min_dwell_iterations`` before moving again, so a bank-split drain
  can't be immediately followed by the opposite move (no thrash);
* **windowed measurement** — decisions use the throughput of the last
  measurement window only (not lifetime averages), and the window resets
  on every replan so stale pre-replan samples never vote.

A *budget drop* (new target with a smaller ``mem_budget_bytes``) is a
feasibility violation, not a drift: it bypasses hysteresis and jumps
straight to ``frontier.select(target)`` — exactly one replan, after which
ordinary banded control resumes.

The controller only needs an engine-shaped object (``metrics`` dict,
``apply_frontier_point``, optionally ``latency_percentiles``); the sim
test drives it with a fake engine whose "measured" throughput is the
analytic estimate times a model-error factor.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

from repro.core.pareto import FrontierPoint, ParetoFrontier, QoSTarget

__all__ = ["QoSController", "QoSControllerConfig", "WalkPolicy",
           "BandedWalkPolicy"]


@dataclasses.dataclass(frozen=True)
class QoSControllerConfig:
    #: relative band around min_tokens_per_s inside which no action is
    #: taken: measured in [target*(1-tol), target*(1+tol)] is "on target".
    tolerance: float = 0.10
    #: hysteresis: iterations to dwell after a replan before moving again
    #: (a bank-split drain must not be followed by the opposite move).
    min_dwell_iterations: int = 16
    #: decisions are taken at most once per this many iterations, on the
    #: throughput measured within the window.
    window_iterations: int = 4
    #: the p95-latency check looks at the most recent completions only
    #: (lifetime percentiles would let cold-start samples vote forever).
    p95_window_requests: int = 16
    #: speculative-decode fallback (DESIGN.md §17): when the WINDOWED
    #: measured acceptance rate drops below this, the draft pass costs
    #: more than the accepted tokens save (the analytic break-even at
    #: k * t_draft ~= t_verify / 2) and the controller turns speculation
    #: off via ``engine.set_speculation(0)``.
    spec_min_acceptance: float = 0.35
    #: drafts that must have been proposed inside the window before the
    #: acceptance fallback may fire — tiny windows are routing noise,
    #: not a regime change.
    spec_min_proposed: int = 64


class WalkPolicy:
    """Pluggable decision strategy for the QoS control loop (DESIGN.md
    §14.4): given the controller (target, active point, frontier,
    config, measured-p95 access) and the windowed measured throughput,
    return the frontier point to move to — or None to hold. The
    controller owns everything around the decision (measurement windows,
    hysteresis dwell, the replan plumbing); the policy owns only the
    judgement, so control-plane experiments can swap it per scenario
    without forking the loop."""

    def decide(self, ctl: "QoSController",
               measured: float) -> Optional[FrontierPoint]:
        raise NotImplementedError


class BandedWalkPolicy(WalkPolicy):
    """The default §9 policy: tolerance-banded walks to the adjacent
    frontier point — faster on a throughput shortfall or a p95 breach,
    back toward quality when the measured headroom (derated by the
    observed model error) predicts the slower point still meets the
    target."""

    def decide(self, ctl: "QoSController",
               measured: float) -> Optional[FrontierPoint]:
        tgt = ctl.target.min_tokens_per_s
        tol = ctl.config.tolerance
        slower, faster = ctl.frontier.neighbors(ctl.point, ctl.target)
        # p95 latency ceiling: only the runtime can see it; treat a
        # violation like a throughput shortfall (walk faster).
        if ctl.target.max_p95_latency_s is not None and faster is not None:
            p95 = ctl._measured_p95()
            if p95 is not None and p95 > ctl.target.max_p95_latency_s:
                ctl._violation()
                return faster
        if tgt is None:
            return None
        if measured < tgt * (1 - tol):
            # an infinite target is "as fast as possible" (best effort),
            # not an SLO that can be violated
            if math.isfinite(tgt):
                ctl._violation()
            # already at the fast end: best effort, keep serving
            return faster
        if measured > tgt * (1 + tol) and slower is not None:
            # headroom: walk back toward quality, but only when (a) the
            # slower point does not DEGRADE quality (adjacent-in-tps
            # points are not always adjacent-in-quality) and (b) it is
            # PREDICTED to still meet the target after derating the
            # analytic estimate by the observed model error.
            derate = measured / max(ctl.point.qos.tokens_per_s, 1e-12)
            if slower.qos.quality_proxy <= ctl.point.qos.quality_proxy \
                    and slower.qos.tokens_per_s * derate >= tgt:
                return slower
        return None


class QoSController:
    """Feedback loop from measured QoS to frontier walks (DESIGN.md §9)."""

    def __init__(self, engine, frontier: Optional[ParetoFrontier] = None,
                 config: QoSControllerConfig = QoSControllerConfig(),
                 on_violation: Optional[Callable[[], None]] = None,
                 policy: Optional[WalkPolicy] = None,
                 dynamic=None):
        self.engine = engine
        self.frontier = frontier if frontier is not None \
            else engine.frontier
        self.config = config
        #: fired whenever a target violation is recorded — the
        #: multi-tenant arbiter's re-arbitration trigger (DESIGN.md §10).
        self.on_violation = on_violation
        #: the pluggable decision strategy (DESIGN.md §14.4)
        self.policy = policy if policy is not None else BandedWalkPolicy()
        #: optional DynamicPrecisionController (DESIGN.md §15): stepped
        #: inside every ``step()`` so hotness-driven rung swaps ride the
        #: same between-iterations cadence as the frontier walks; its
        #: promotions/demotions land in THIS controller's
        #: ``rung_promotions``/``rung_demotions`` via the metrics sink
        #: (bound below, after the metrics dict exists).
        self.dynamic = dynamic
        self.target: Optional[QoSTarget] = None
        self.point: Optional[FrontierPoint] = None
        self._win_iter = 0
        self._win_tokens = 0
        self._win_time = 0.0
        self._win_spec = (0, 0)     # (proposed, accepted) at window start
        self._applied_iter = 0
        self.metrics: Dict[str, float] = {
            "replans": 0, "decisions": 0, "violations": 0,
            "last_measured_tps": 0.0,
            # ladder telemetry (DESIGN.md §11): a walk step whose plan
            # raises the mean expert bit-width is a rung PROMOTION
            # (quality up), lowering it is a DEMOTION — the controller
            # can now trade precision, not only counts/residency.
            "rung_promotions": 0, "rung_demotions": 0,
            # speculative decode (DESIGN.md §17): windowed measured
            # acceptance + times the controller disabled speculation.
            "last_acceptance_rate": 0.0, "spec_fallbacks": 0,
        }
        if self.dynamic is not None and self.dynamic.sink is None:
            self.dynamic.sink = self.metrics

    # -- target management -------------------------------------------------
    def set_target(self, target: QoSTarget) -> FrontierPoint:
        """Activate a target: select + apply its frontier point (one
        replan). Called on tenant (re)negotiation or a budget change
        from the job manager."""
        point = self.frontier.select(target)
        self.target = target
        self._apply(point)
        return point

    def adopt(self, target: QoSTarget, point: FrontierPoint) -> None:
        """Activate an EXTERNALLY selected (target, point) pair — the
        multi-tenant :class:`~repro.serving.multi.ResourceArbiter` picks
        points jointly across tenants, so the local ``select()`` is
        bypassed; ordinary banded control resumes from the adopted
        point (with the usual post-replan dwell)."""
        self.target = target
        self._apply(point)

    # -- the loop ----------------------------------------------------------
    def step(self) -> bool:
        """Run one control decision between decode iterations; returns
        True iff a replan was applied."""
        if self.target is None or self.point is None:
            return False
        if self.dynamic is not None:
            # hotness-driven rung swaps (DESIGN.md §15) are in-place and
            # byte-neutral, so they ride every step OUTSIDE the frontier
            # walk's hysteresis (the dynamic controller has its own
            # EMA/margin/dwell guards)
            self.dynamic.step()
        # feasibility violation (e.g. the active point predates a budget
        # drop): fix immediately, bypassing hysteresis — but only once,
        # select() lands on a feasible point.
        if not self.point.feasible_under(self.target):
            self._apply(self.frontier.select(self.target))
            return True
        m = self.engine.metrics
        it = int(m["iterations"])
        if it - self._win_iter < self.config.window_iterations:
            return False
        dt = self._elapsed(m) - self._win_time
        dtok = m["tokens_generated"] - self._win_tokens
        d_prop = int(m.get("spec_proposed", 0)) - self._win_spec[0]
        d_acc = int(m.get("spec_accepted", 0)) - self._win_spec[1]
        self._snapshot(it)
        self._check_speculation(d_prop, d_acc)
        if dtok <= 0 or dt <= 0:
            return False
        measured = dtok / dt
        self.metrics["decisions"] += 1
        self.metrics["last_measured_tps"] = measured
        if it - self._applied_iter < self.config.min_dwell_iterations:
            return False                    # hysteresis: dwell
        return self._decide(measured)

    def _decide(self, measured: float) -> bool:
        point = self.policy.decide(self, measured)
        if point is None or point is self.point:
            return False
        self._apply(point)
        return True

    # -- internals ---------------------------------------------------------
    def _violation(self):
        self.metrics["violations"] += 1
        if self.on_violation is not None:
            self.on_violation()

    def _measured_p95(self) -> Optional[float]:
        fn = getattr(self.engine, "latency_percentiles", None)
        if fn is None:
            return None
        try:
            pct = fn((95,), last_n=self.config.p95_window_requests)
        except TypeError:       # engine-shaped stub without the kwarg
            pct = fn((95,))
        p95 = pct.get("p95", 0.0)
        return p95 if p95 > 0 else None

    def _check_speculation(self, proposed: int, accepted: int) -> None:
        """Measured acceptance-rate feedback (DESIGN.md §17): per-window
        acceptance below ``spec_min_acceptance`` means the workload's
        draft (lowest-rung) and serve distributions have diverged enough
        that drafting costs more than it saves — fall back to plain
        decode via the engine's ``set_speculation(0)``. Effectively
        one-shot: once off, no window proposes ``spec_min_proposed``
        drafts so the guard cannot re-fire. Engine-shaped objects
        without speculation (no ``set_speculation``) are left alone."""
        if proposed < self.config.spec_min_proposed:
            return
        rate = accepted / proposed
        self.metrics["last_acceptance_rate"] = rate
        if rate >= self.config.spec_min_acceptance:
            return
        fn = getattr(self.engine, "set_speculation", None)
        if fn is None:
            return
        fn(0)
        self.metrics["spec_fallbacks"] += 1

    def _apply(self, point: FrontierPoint):
        if self.point is not None:
            old_bits = float(self.point.plan.bits.mean())
            new_bits = float(point.plan.bits.mean())
            if new_bits > old_bits:
                self.metrics["rung_promotions"] += 1
            elif new_bits < old_bits:
                self.metrics["rung_demotions"] += 1
        self.engine.apply_frontier_point(point)
        self.point = point
        self.metrics["replans"] += 1
        it = int(self.engine.metrics["iterations"])
        self._applied_iter = it
        self._snapshot(it)

    @staticmethod
    def _elapsed(m) -> float:
        """Serving wall-time the window measures throughput over: decode
        plus the EXPOSED transfer time (DESIGN.md §12) — overlapped
        transfers already hide under decode and must not be
        double-counted. Engines without the async pipeline report
        ``transfer_exposed_s == transfer_s`` (or lack the key entirely:
        engine-shaped stubs fall back to total transfer time)."""
        return m["decode_s"] + m.get("transfer_exposed_s", m["transfer_s"])

    def _snapshot(self, it: int):
        m = self.engine.metrics
        self._win_iter = it
        self._win_tokens = m["tokens_generated"]
        self._win_time = self._elapsed(m)
        self._win_spec = (int(m.get("spec_proposed", 0)),
                          int(m.get("spec_accepted", 0)))

    def summary(self) -> str:
        t = self.target.describe() if self.target else "no target"
        p = self.point.summary() if self.point else "no point"
        return (f"QoS[{t}] @ [{p}] measured="
                f"{self.metrics['last_measured_tps']:.2f} tok/s "
                f"replans={self.metrics['replans']:.0f} "
                f"violations={self.metrics['violations']:.0f}")
