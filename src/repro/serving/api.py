"""Public serving surface — declarative QoS API (DESIGN.md §9).

Callers declare *targets*, not knob values: a deployment states a
:class:`~repro.core.pareto.QoSTarget` (min tokens/s, max quality loss,
memory budget), each request states a :class:`RequestSLO` (priority,
optional deadline) and :class:`SamplingParams`; the engine picks the MoP
configuration off its :class:`~repro.core.pareto.ParetoFrontier` and the
:class:`~repro.serving.qos.QoSController` keeps it on target at runtime.

    from repro.serving.api import (EngineConfig, QoSTarget, ServeRequest,
                                   RequestSLO, build_engine)
    engine = build_engine(cfg, params, EngineConfig(max_slots=8))
    engine.apply_target(QoSTarget(min_tokens_per_s=8.0,
                                  mem_budget_bytes=40 * 2**30))
    rid = engine.submit_request(ServeRequest(prompt,
                                             slo=RequestSLO(priority=1)))
    engine.step()
    print(engine.result(rid))

The imperative ``engine.configure(mem_budget_bytes, preference, num_q)``
survives as a deprecated shim that builds a ``QoSTarget`` internally.

Importing this module does not build any jax computation (the model
stack loads only when ``build_engine`` constructs an engine), though jax
itself is transitively imported via the cost model's config types.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import HardwareModel
from repro.core.pareto import (  # noqa: F401  (public re-exports)
    FrontierPoint, InfeasibleTarget, ParetoFrontier, QoSTarget,
)
from repro.serving.multi import (  # noqa: F401  (public re-exports)
    MultiTenantEngine, ReplanReport, ResourceArbiter, TenantSpec,
)
from repro.serving.scheduler import (  # noqa: F401  (public re-exports)
    Request, RequestSLO, SamplingParams,
)

__all__ = [
    "EngineConfig", "SamplingParams", "RequestSLO", "ServeRequest",
    "ServeResult", "QoSTarget", "FrontierPoint", "ParetoFrontier",
    "InfeasibleTarget", "build_engine",
    "MultiTenantEngine", "TenantSpec", "ResourceArbiter", "ReplanReport",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Typed construction parameters for the serving engine — replaces the
    kwarg soup of ``AdaptiveServingEngine.__init__`` (DESIGN.md §9).

    Capacity:
      * ``max_slots`` — decode batch width (rows of the slot KV cache);
      * ``max_len``   — per-slot KV window (prompt + max_new_tokens cap);
      * ``max_active_tokens`` / ``max_queue`` — admission-control knobs
        (see ``serving/scheduler.py``).
    Expert streaming:
      * ``swap_bytes`` — device LRU swap capacity for non-resident
        experts; ``prefetch`` enables the speculative prefetch cache.
      * ``overlap`` — async overlapped expert streaming (DESIGN.md §12):
        transfers run on an ``AsyncExpertCache`` worker pool and the
        engine decodes through the per-layer lookahead pipeline, hiding
        transfer time under layer compute. ``overlap_efficiency`` seeds
        the analytic overlap window (fraction of t_compute; ``None`` =
        0.85 when overlap is on, 0.0 otherwise); the engine refines it
        from measurement via ``calibrate_overlap()``. Off = the paper's
        serial staging, bit-identical to the historical path.
    Precision:
      * ``ladder`` — the deployment's precision ladder (descending rung
        tuple, e.g. ``(16, 8, 4)``; DESIGN.md §11). ``None`` keeps the
        model config's ladder (binary ``(16, bits)`` by default, which
        reproduces the pre-ladder plans bit-identically).
    KV cache (DESIGN.md §13):
      * ``paged_kv`` — serve through the paged KV cache: fixed-size pages
        + a per-slot page table instead of fully-windowed slot rows.
        Decode is bit-identical to the slot cache (tested); allocated KV
        bytes track actual tokens per page instead of slots x window.
        ``False`` keeps the slot cache as the A/B baseline.
      * ``page_size`` — tokens per KV page.
      * ``kv_pool_pages`` — physical pool size (incl. the null page);
        ``None`` = worst case (slots x window). A smaller pool reclaims
        HBM; the engine derives an admission cap from it so allocation
        never dead-ends mid-flight.
      * ``kv_reserve`` — credit the HBM a sub-worst-case pool reclaims
        (vs the bucketed slot cache) to ``QoSTarget.mem_budget_bytes``
        when resolving targets on the frontier, feeding the savings back
        into the expert-residency axis.
    Hardware:
      * ``hw`` — analytic hardware model; None measures the host link
        bandwidth once per process and uses defaults otherwise.
    Speculative decode (DESIGN.md §17):
      * ``speculate`` — draft depth K for ladder-draft self-speculative
        decoding: each iteration drafts K tokens per slot with every
        expert forced to the LOWEST ladder rung (the banks are already
        resident — zero extra weight bytes), then one batched verify
        forward at the serving plan scores all K+1 positions and accepts
        the longest matching prefix. Greedy output is token-identical to
        plain decode (tested); temperature>0 uses rejection sampling via
        ``serving/sampler.py``. ``0`` (default) is plain decode,
        byte-identical to the pre-speculation engine.
    Expert parallelism (DESIGN.md §16):
      * ``ep`` — EP shard count of the mesh the engine decodes over.
        The planner/frontier then round per-rung counts to multiples of
        ``ep`` (rung banks must split evenly over the mesh) and add the
        PEER placement tier (experts in a peer device's HBM, reached
        via the all2all at interconnect bandwidth). ``1`` (default) is
        the single-device engine bit-for-bit; the mesh itself is passed
        to ``build_engine(mesh=...)`` (see ``serving/ep``).
    """
    max_slots: int = 8
    max_len: int = 256
    use_kernel: bool = False
    max_active_tokens: Optional[int] = None
    max_queue: Optional[int] = None
    swap_bytes: Optional[int] = None
    prefetch: bool = False
    overlap: bool = False
    overlap_efficiency: Optional[float] = None
    ladder: Optional[Tuple[int, ...]] = None
    hw: Optional[HardwareModel] = None
    paged_kv: bool = True
    page_size: int = 16
    kv_pool_pages: Optional[int] = None
    kv_reserve: bool = False
    ep: int = 1
    speculate: int = 0


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One generation request on the declarative surface."""
    prompt: np.ndarray
    max_new_tokens: int = 16
    sampling: Optional[SamplingParams] = None
    slo: RequestSLO = dataclasses.field(default_factory=RequestSLO)


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Completed request: tokens + the QoS the request actually got."""
    rid: int
    tokens: List[int]
    latency_s: float
    ttft_s: Optional[float]
    priority: int
    deadline_s: Optional[float]
    deadline_met: Optional[bool]   # None when no deadline was declared

    @classmethod
    def from_request(cls, req: Request) -> "ServeResult":
        if req.t_done is None:
            raise ValueError(f"request {req.rid} is still in flight")
        return cls(rid=req.rid, tokens=list(req.out_tokens),
                   latency_s=req.latency_s, ttft_s=req.ttft_s,
                   priority=req.slo.priority,
                   deadline_s=req.slo.deadline_s,
                   deadline_met=req.deadline_met)

    def summary(self) -> str:
        dl = ("" if self.deadline_met is None else
              f" deadline={'MET' if self.deadline_met else 'MISSED'}")
        return (f"req {self.rid} prio={self.priority}: "
                f"{len(self.tokens)} tok in {self.latency_s * 1e3:.0f} ms"
                + dl)


def results_of(requests: Sequence[Request]) -> List[ServeResult]:
    """Batch conversion helper for completed scheduler requests."""
    return [ServeResult.from_request(r) for r in requests]


def build_engine(cfg, params, config: Optional[EngineConfig] = None, *,
                 mesh=None, expert_cache=None):
    """Construct an :class:`~repro.serving.engine.AdaptiveServingEngine`
    from an :class:`EngineConfig` (lazy import keeps this module jax-free
    until an engine is actually built). ``expert_cache`` attaches a
    tenant-scoped view of a shared swap space
    (:meth:`~repro.core.expert_cache.ExpertCache.scoped`) for
    multi-tenant deployments (DESIGN.md §10)."""
    from repro.serving.engine import AdaptiveServingEngine
    return AdaptiveServingEngine(cfg, params, mesh=mesh,
                                 config=config or EngineConfig(),
                                 expert_cache=expert_cache)
