"""Open-loop request drivers for the continuous-batching engine.

Shared by benchmarks (fig3), examples and the serve CLI so the arrival
bookkeeping lives in exactly one place: requests are submitted when their
exponential inter-arrival clock fires, the engine advances one scheduler
iteration at a time, and (optionally) the tail is left in flight for the
caller. ``on_iteration`` is the QoS hook: the
:class:`~repro.serving.qos.QoSController` steps BETWEEN decode iterations
(DESIGN.md §9), which is exactly where this driver calls it.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Union

import numpy as np

from repro.serving.scheduler import RequestSLO, SamplingParams

IntOrSampler = Union[int, Callable[[np.random.Generator], int]]
SLOSampler = Callable[[np.random.Generator], RequestSLO]


def _draw(v: IntOrSampler, rng: np.random.Generator) -> int:
    return int(v(rng)) if callable(v) else int(v)


def drive_poisson(engine, rng: np.random.Generator, *,
                  n_requests: int, mean_gap_s: float,
                  prompt_len: IntOrSampler = 16,
                  max_new_tokens: IntOrSampler = 16,
                  temperature: float = 0.0,
                  sampling: Optional[SamplingParams] = None,
                  slo: Optional[SLOSampler] = None,
                  on_iteration: Optional[Callable[[], None]] = None,
                  drain: bool = True) -> List[int]:
    """Poisson arrival process against the engine: submit each request
    when its (exponential inter-arrival) clock fires, running decode
    iterations in between. ``drain=False`` returns as soon as the last
    request was submitted, leaving the tail in flight (callers use this
    to exercise mid-flight reconfiguration). ``sampling`` attaches
    per-request SamplingParams, ``slo`` draws a per-request
    :class:`RequestSLO` (priority/deadline) from the rng, and
    ``on_iteration`` runs after every decode iteration (the
    QoSController hook). Returns the submitted rids."""
    arrivals = np.cumsum(rng.exponential(mean_gap_s, n_requests))
    rids: List[int] = []
    t0 = time.perf_counter()
    i = 0
    while i < n_requests or (drain and engine.has_work()):
        now = time.perf_counter() - t0
        while i < n_requests and arrivals[i] <= now:
            rids.append(engine.submit(
                rng.integers(1, engine.cfg.vocab_size,
                             _draw(prompt_len, rng)),
                max_new_tokens=_draw(max_new_tokens, rng),
                sampling=sampling,
                slo=slo(rng) if slo is not None else None))
            i += 1
        if engine.has_work():
            engine.run_iteration(temperature=temperature)
            if on_iteration is not None:
                on_iteration()
        elif i < n_requests:
            time.sleep(min(arrivals[i] - now, 0.005))
    return rids
