"""Shared engine-metric schema — the sim/real parity contract
(DESIGN.md §14.2).

The QoS controller, the multi-tenant arbiter and the control plane are
all written against "an engine-shaped object": a ``metrics`` dict plus
``apply_frontier_point``. That only works if the dict has the SAME key
set whichever engine backs it — the real
:class:`~repro.serving.engine.AdaptiveServingEngine` or the
deterministic :class:`~repro.serving.simulator.SimulatedEngine`. The key
set drifted twice already (the PR 5 ``transfer_exposed_s`` split and the
PR 6 ``kv_*`` accounting landed in the real engine only), so the schema
now lives here, in a module with no jax dependency, and BOTH engines
initialize from :func:`base_metrics`. ``tests/test_simulator.py`` pins
the parity.

Counters are ``int``, accumulated seconds/bytes-x-iterations and rates
are ``float`` — the distinction matters because the real engine resets
metrics by zeroing in place, preserving each value's type.
"""
from __future__ import annotations

from typing import Any, Dict

__all__ = ["ENGINE_METRIC_SCHEMA", "base_metrics"]

#: key -> zero of the right type. One entry per metric the real serving
#: engine maintains from construction; keys added lazily after specific
#: actions (``last_migrated_*`` after a reconfig, ``migrated_bytes_total``)
#: are NOT part of the parity contract.
ENGINE_METRIC_SCHEMA: Dict[str, Any] = {
    # generation counters
    "tokens_generated": 0,
    "iterations": 0,
    # time decomposition (DESIGN.md §2/§12)
    "decode_s": 0.0,
    "prefill_s": 0.0,
    "transfer_s": 0.0,
    "transfer_s_est": 0.0,
    "stage_s": 0.0,
    "prefetch_s": 0.0,
    "transfer_exposed_s": 0.0,
    "transfer_overlapped_s": 0.0,
    # reconfiguration / drains (DESIGN.md §10.3)
    "reconfig_s": 0.0,
    "reconfigs": 0,
    "drains": 0,
    "drain_s": 0.0,
    # expert-streaming hit accounting (DESIGN.md §8.1)
    "miss_rate": 0.0,
    "miss_rate_measured": 0.0,
    "expert_accesses": 0,
    "expert_fetches": 0,
    # KV padding accounting (DESIGN.md §13)
    "kv_allocated_bytes": 0,
    "kv_used_bytes": 0,
    "kv_alloc_byte_iters": 0.0,
    "kv_used_byte_iters": 0.0,
    "kv_capacity_bytes": 0,
    # speculative decode (DESIGN.md §17): drafts proposed / accepted by
    # the verify forward; acceptance_rate = accepted / proposed so far
    "spec_proposed": 0,
    "spec_accepted": 0,
    "acceptance_rate": 0.0,
}


def base_metrics() -> Dict[str, Any]:
    """A fresh metrics dict with every schema key zeroed (typed)."""
    return dict(ENGINE_METRIC_SCHEMA)
