"""Multi-tenant MoE serving under ONE memory envelope (DESIGN.md §10).

The paper's pitch is adaptive serving in multi-tenant environments where
available resources change over time; PR 2 gave one model a declarative
QoS surface, this module arbitrates that surface across N co-hosted
models. Following "QoS-Efficient Serving of Multiple MoE LLMs Using
Partial Runtime Reconfiguration" (Imani et al., 2025) and MoE-Prism's
elastic per-tenant quality/throughput framing (Xia et al., 2025):

* :class:`MultiTenantEngine` hosts N per-tenant engines — each with its
  OWN :class:`~repro.core.pareto.ParetoFrontier`, scheduler and KV slots
  — under a single global byte budget, with one shared expert swap space
  (tenant-namespaced :class:`~repro.core.expert_cache.ExpertCache` views,
  so identical ``(layer, expert)`` keys never collide across tenants).
* :class:`ResourceArbiter` jointly selects one frontier point per tenant
  by **water-filling marginal utility per byte**: every tenant starts at
  its cheapest feasible point, then the globally best upgrade (largest
  weighted utility gain per additional byte) is applied repeatedly until
  the shared budget is exhausted. Utility saturates once a tenant's
  tokens/s floor is met, so spare bytes flow to quality upgrades —
  "marginal quality-per-byte" water-filling. Analytic tokens/s are
  DERATED by each tenant's observed model error (measured/analytic from
  its :class:`~repro.serving.qos.QoSController`), so re-arbitration
  responds to the throughput tenants actually get. The measured side
  charges only EXPOSED transfer time (``transfer_exposed_s``, DESIGN.md
  §12) — under async overlapped streaming a tenant's hidden transfers
  must not deflate its derate and siphon bytes it does not need.
* Reconfiguration is PARTIAL: the old and new precision-and-placement
  plans are diffed per tenant
  (:func:`~repro.core.precision_plan.reconfig_delta`) and only the
  changed experts migrate; every replan emits a :class:`ReplanReport`
  with migrated-expert count, migrated bytes and estimated downtime.

Re-arbitration triggers: a global budget shift (``set_budget`` — exactly
one joint re-arbitration, tested) and a tenant QoS miss (the
controller's ``on_violation`` hook; applied only when the fresh joint
selection actually differs, after a cooldown — no storms).

The engines may be real :class:`~repro.serving.engine.AdaptiveServingEngine`
instances (``examples/multi_tenant.py``, ``launch/serve.py --tenants``)
or the deterministic :class:`~repro.serving.simulator.SimulatedEngine`
(the test harness) — the arbiter only consumes the engine-shaped control
interface.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.expert_cache import ExpertCache, ScopedExpertCache
from repro.core.pareto import (FrontierPoint, InfeasibleTarget,
                               ParetoFrontier, QoSTarget, _fmt_bytes)
from repro.core.precision_plan import (migrated_expert_keys, reconfig_delta)
from repro.serving.qos import QoSController, QoSControllerConfig

__all__ = [
    "TenantSpec", "ReplanReport", "ResourceArbiter", "MultiTenantEngine",
    "GlobalBudgetInfeasible", "UtilityPolicy", "FloorSaturationUtility",
]


class GlobalBudgetInfeasible(ValueError):
    """Even the cheapest feasible point per tenant overflows the shared
    budget — no joint configuration exists."""


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's declarative contract with the arbiter.

    ``target`` is the tenant's own :class:`QoSTarget`; its
    ``mem_budget_bytes`` (if set) is a per-tenant CAP on top of the
    shared global budget. ``weight`` scales the tenant's claim on
    marginal bytes during water-filling (2.0 = upgrades count double)."""
    name: str
    target: QoSTarget
    weight: float = 1.0

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")


@dataclasses.dataclass(frozen=True)
class ReplanReport:
    """What one tenant's partial reconfiguration actually moved."""
    tenant: str
    migrated_experts: int     # experts that streamed (upload/format flip)
    evicted_experts: int      # device -> host demotions (no traffic)
    migrated_bytes: int
    downtime_s: float         # migrated_bytes / host link bw (estimate)
    placement_only: bool      # same bank split: applies with zero drain

    def summary(self) -> str:
        kind = "placement-only" if self.placement_only else "bank-split"
        return (f"[{self.tenant}] {kind} replan: {self.migrated_experts} "
                f"experts migrated ({self.migrated_bytes / 2**20:.2f} MiB, "
                f"~{self.downtime_s * 1e3:.1f} ms), "
                f"{self.evicted_experts} evicted")


class _Tenant:
    """Arbiter-side runtime state of one hosted tenant."""

    def __init__(self, spec: TenantSpec, engine, frontier: ParetoFrontier,
                 controller: QoSController,
                 cache_view: Optional[ScopedExpertCache]):
        self.spec = spec
        self.engine = engine
        self.frontier = frontier
        self.controller = controller
        self.cache_view = cache_view
        #: measured/analytic tokens-per-s ratio (1.0 = perfectly calibrated)
        self.derate = 1.0
        self.allocated_bytes = 0.0
        self.pending_violation = False
        self.reports: List[ReplanReport] = []
        #: optional DynamicPrecisionController (DESIGN.md §15)
        self.dynamic = None

    @property
    def point(self) -> Optional[FrontierPoint]:
        return self.controller.point


class UtilityPolicy:
    """Pluggable per-tenant utility model for the water-filling arbiter
    (DESIGN.md §14.4): ``build`` returns the scalar utility function the
    arbiter maximizes per marginal byte, given the tenant's feasible
    points, its target and its observed derate. Swapping the policy
    changes WHAT bytes buy (SLO floors, latency, fairness experiments)
    without touching the water-filling mechanics."""

    def build(self, feas: Sequence[FrontierPoint], target: QoSTarget,
              derate: float) -> Callable[[FrontierPoint], float]:
        raise NotImplementedError


class FloorSaturationUtility(UtilityPolicy):
    """The default §10.2 utility: ``floor_weight * saturation(tokens/s)
    - (quality_proxy - 1)`` where saturation is ``min(eff_tps / floor,
    1)`` for a finite tokens/s floor, the normalized ``tps / tps_max``
    for the ``inf`` ("as fast as possible") floor, and ``1`` when no
    floor (or a degenerate ``<= 0`` floor) is declared. ``floor_weight``
    makes meeting declared floors dominate quality polish — bytes first
    buy SLO feasibility, then quality."""

    def __init__(self, floor_weight: float = 1000.0):
        self.floor_weight = floor_weight

    def build(self, feas: Sequence[FrontierPoint], target: QoSTarget,
              derate: float) -> Callable[[FrontierPoint], float]:
        tps_max = max(p.qos.tokens_per_s for p in feas)
        floor = target.min_tokens_per_s

        def u(p: FrontierPoint) -> float:
            if floor is None or floor <= 0:
                sat = 1.0
            elif math.isinf(floor):
                sat = p.qos.tokens_per_s / max(tps_max, 1e-12)
            else:
                sat = min(p.qos.tokens_per_s * derate / floor, 1.0)
            return self.floor_weight * sat - (p.qos.quality_proxy - 1.0)

        return u


class ResourceArbiter:
    """Joint frontier-point selection by water-filling marginal utility
    per byte (DESIGN.md §10.2).

    The utility of a point is delegated to a pluggable
    :class:`UtilityPolicy` (default :class:`FloorSaturationUtility`,
    weighting declared SLO floors above quality polish); the arbiter
    itself owns only the water-filling: every tenant starts at its
    cheapest feasible point and the globally best upgrade per marginal
    byte is applied until the budget is exhausted."""

    def __init__(self, floor_weight: float = 1000.0, *,
                 utility: Optional[UtilityPolicy] = None):
        self.floor_weight = floor_weight
        self.utility = utility if utility is not None \
            else FloorSaturationUtility(floor_weight)

    # -- per-tenant upgrade chain -------------------------------------------
    def chain(self, frontier: ParetoFrontier, target: QoSTarget,
              derate: float = 1.0
              ) -> Tuple[List[FrontierPoint], Callable[[FrontierPoint], float]]:
        """(bytes-ascending, strictly utility-increasing) upgrade chain of
        the tenant's feasible frontier points, plus its utility function."""
        feas = [p for p in frontier.points if p.feasible_under(target)]
        if not feas:
            raise InfeasibleTarget(
                f"no frontier point satisfies [{target.describe()}]")
        u = self.utility.build(feas, target, derate)
        feas.sort(key=lambda p: (p.qos.device_bytes, -u(p),
                                 p.num_q_experts, p.resident_experts))
        chain: List[FrontierPoint] = []
        for p in feas:
            if not chain or u(p) > u(chain[-1]) + 1e-12:
                chain.append(p)
        return chain, u

    # -- joint selection ----------------------------------------------------
    def arbitrate(self, entries: Sequence[Tuple[TenantSpec, ParetoFrontier,
                                                float]],
                  budget_bytes: float
                  ) -> Tuple[Dict[str, FrontierPoint], float]:
        """Water-fill ``budget_bytes`` across tenants; returns
        ({tenant: point}, used_bytes). Deterministic: ties go to the
        earlier tenant in ``entries`` order."""
        chains, utils = [], []
        for spec, frontier, derate in entries:
            try:
                c, u = self.chain(frontier, spec.target, derate)
            except InfeasibleTarget as e:
                raise InfeasibleTarget(f"tenant {spec.name!r}: {e}") from e
            chains.append(c)
            utils.append(u)
        idx = [0] * len(chains)
        used = float(sum(c[0].qos.device_bytes for c in chains))
        if used > budget_bytes:
            need = ", ".join(
                f"{spec.name}>={_fmt_bytes(c[0].qos.device_bytes)}"
                for (spec, _, _), c in zip(entries, chains))
            raise GlobalBudgetInfeasible(
                f"minimal joint footprint {_fmt_bytes(used)} exceeds the "
                f"shared budget {_fmt_bytes(max(budget_bytes, 0.0))} "
                f"({need})")
        while True:
            best_rate, best_ti = None, None
            for ti, (spec, _, _) in enumerate(entries):
                c, i = chains[ti], idx[ti]
                if i + 1 >= len(c):
                    continue
                db = float(c[i + 1].qos.device_bytes
                           - c[i].qos.device_bytes)
                if used + db > budget_bytes:
                    continue
                du = utils[ti](c[i + 1]) - utils[ti](c[i])
                rate = math.inf if db <= 0 else spec.weight * du / db
                if best_rate is None or rate > best_rate:
                    best_rate, best_ti = rate, ti
            if best_ti is None:
                break
            used += float(chains[best_ti][idx[best_ti] + 1].qos.device_bytes
                          - chains[best_ti][idx[best_ti]].qos.device_bytes)
            idx[best_ti] += 1
        sel = {spec.name: chains[ti][idx[ti]]
               for ti, (spec, _, _) in enumerate(entries)}
        return sel, used


class MultiTenantEngine:
    """N per-tenant serving engines under one byte budget (DESIGN.md §10).

    Wiring::

        shared = ExpertCache(capacity_bytes=swap)
        mt = MultiTenantEngine(budget_bytes, expert_cache=shared)
        mt.add_tenant(TenantSpec("chat", QoSTarget(min_tokens_per_s=8)),
                      engine_a)
        mt.add_tenant(TenantSpec("batch", QoSTarget(max_quality_loss=0.0)),
                      engine_b)
        mt.arbitrate()                  # initial joint selection
        ...
        mt.run_iteration()              # decode + per-tenant QoS control
        mt.set_budget(smaller)          # exactly one joint re-arbitration
    """

    def __init__(self, budget_bytes: float, *,
                 expert_cache: Optional[ExpertCache] = None,
                 swap_capacity_bytes: int = 64 << 20,
                 arbiter: Optional[ResourceArbiter] = None,
                 controller_config: Optional[QoSControllerConfig] = None,
                 cooldown_iterations: int = 8):
        self.budget_bytes = float(budget_bytes)
        self.cache = expert_cache if expert_cache is not None \
            else ExpertCache(capacity_bytes=swap_capacity_bytes)
        self.arbiter = arbiter or ResourceArbiter()
        self.controller_config = controller_config or QoSControllerConfig()
        #: iterations (summed over tenants) between violation-driven
        #: re-arbitration attempts — the joint analogue of controller dwell
        self.cooldown_iterations = cooldown_iterations
        self._tenants: Dict[str, _Tenant] = {}
        self.reports: List[ReplanReport] = []
        self.metrics: Dict[str, float] = {
            "arbitrations": 0, "arbitrations_noop": 0, "replans": 0,
            "migrated_experts": 0, "migrated_bytes": 0, "downtime_s": 0.0,
            "used_bytes": 0.0,
        }
        self._last_arb_iter = 0.0

    # -- tenant management --------------------------------------------------
    @property
    def tenants(self) -> Dict[str, _Tenant]:
        return dict(self._tenants)

    def add_tenant(self, spec: TenantSpec, engine,
                   frontier: Optional[ParetoFrontier] = None,
                   dynamic=None) -> _Tenant:
        """Register a tenant. ``frontier`` defaults to ``engine.frontier``
        (real engines build one lazily; simulated engines need it passed).
        If the engine already streams through a scoped view of THIS
        shared cache it is reused, otherwise a namespace is opened for
        the tenant. ``dynamic`` (a
        :class:`~repro.core.dynamic_precision.DynamicPrecisionController`,
        DESIGN.md §15) rides the tenant's QoSController: its byte-neutral
        rung swaps step with the per-tenant control loop and its
        placement-only :class:`ReplanReport`\\ s land in the shared
        ``reports`` trace."""
        if spec.name in self._tenants:
            raise ValueError(f"tenant {spec.name!r} already hosted")
        if frontier is None:
            frontier = engine.frontier
        view = getattr(engine, "expert_cache", None)
        if not (isinstance(view, ScopedExpertCache)
                and view.parent is self.cache):
            view = self.cache.scoped(
                spec.name, getattr(engine, "_fetch_expert", None))
        controller = QoSController(
            engine, frontier, self.controller_config,
            on_violation=lambda name=spec.name: self._note_violation(name),
            dynamic=dynamic)
        t = _Tenant(spec, engine, frontier, controller, view)
        if dynamic is not None:
            dynamic.tenant = spec.name
            dynamic.on_report = lambda rr, name=spec.name: \
                self._note_dynamic_report(name, rr)
        t.dynamic = dynamic
        self._tenants[spec.name] = t
        return t

    def _note_dynamic_report(self, name: str, report: ReplanReport):
        """Fold a dynamic-precision swap report into the shared replan
        trace — placement-only by construction (byte-neutral swaps)."""
        t = self._tenants[name]
        t.reports.append(report)
        self.reports.append(report)
        self.metrics["migrated_experts"] += report.migrated_experts
        self.metrics["migrated_bytes"] += report.migrated_bytes

    def _note_violation(self, name: str):
        self._tenants[name].pending_violation = True

    # -- joint arbitration --------------------------------------------------
    def _entries(self) -> List[Tuple[TenantSpec, ParetoFrontier, float]]:
        return [(t.spec, t.frontier, t.derate)
                for t in self._tenants.values()]

    def _select(self) -> Tuple[Dict[str, FrontierPoint], float]:
        if not self._tenants:
            raise RuntimeError("no tenants hosted")
        return self.arbiter.arbitrate(self._entries(), self.budget_bytes)

    def arbitrate(self, _selection: Optional[Tuple[Dict[str, FrontierPoint],
                                                   float]] = None
                  ) -> Dict[str, FrontierPoint]:
        """Joint (re)selection + partial migration + allocation of slack.

        Each tenant's controller target becomes its spec target with
        ``mem_budget_bytes`` = its selected point's footprint plus a
        weight-proportional share of the leftover budget — the headroom
        inside which its own QoSController may keep walking locally."""
        sel, used = self._select() if _selection is None else _selection
        self.metrics["used_bytes"] = used
        slack = max(self.budget_bytes - used, 0.0)
        wsum = sum(t.spec.weight for t in self._tenants.values())
        for name, t in self._tenants.items():
            alloc = float(sel[name].qos.device_bytes) \
                + slack * t.spec.weight / wsum
            if t.spec.target.mem_budget_bytes is not None:
                alloc = min(alloc, t.spec.target.mem_budget_bytes)
            t.allocated_bytes = alloc
            self._apply(t, sel[name], dataclasses.replace(
                t.spec.target, mem_budget_bytes=alloc))
            t.pending_violation = False
        self.metrics["arbitrations"] += 1
        self._last_arb_iter = self._total_iterations()
        return sel

    def _maybe_rearbitrate(self) -> bool:
        """Violation-driven path: re-arbitrate only when the fresh joint
        selection differs from what tenants already run (otherwise the
        miss is a model-error the local controllers keep chasing)."""
        sel, used = self._select()
        if all(sel[name] is t.point for name, t in self._tenants.items()):
            self.metrics["arbitrations_noop"] += 1
            for t in self._tenants.values():
                t.pending_violation = False
            self._last_arb_iter = self._total_iterations()
            return False
        self.arbitrate(_selection=(sel, used))
        return True

    def set_budget(self, budget_bytes: float) -> bool:
        """The job manager resizes the global envelope: one joint
        re-arbitration (shrink AND grow), partial migrations only."""
        if float(budget_bytes) == self.budget_bytes:
            return False
        self.budget_bytes = float(budget_bytes)
        self.arbitrate()
        return True

    # -- partial reconfiguration (DESIGN.md §10.3) --------------------------
    def _apply(self, t: _Tenant, point: FrontierPoint, target: QoSTarget):
        old = t.point
        if old is point:
            # allocation changed but the point did not: refresh the
            # target, no migration, no replan
            t.controller.target = target
            return
        if old is not None:
            delta = reconfig_delta(old.plan, point.plan)
            keys = migrated_expert_keys(delta, point.plan)
            cfg = t.frontier.cfg
            # each migrated expert streams once, in its NEW ladder rung's
            # format (a 4->8 promotion charges the 8-bit size)
            mbytes = sum(cfg.expert_param_bytes(int(point.plan.bits[l, e]))
                         for (l, e) in keys)
            placement_only = (
                old.plan.bank_sizes() == point.plan.bank_sizes()
                and old.plan.seed == point.plan.seed)
            # shared-swap hygiene: migrated experts are stale in THIS
            # tenant's namespace (now device-resident or format-flipped)
            if t.cache_view is not None:
                resident = set(t.cache_view.resident_keys())
                t.cache_view.invalidate(
                    [k for k in keys if k in resident])
            report = ReplanReport(
                tenant=t.spec.name, migrated_experts=len(keys),
                evicted_experts=len(delta["to_evict"]),
                migrated_bytes=int(mbytes),
                downtime_s=mbytes / t.frontier.hw.host_link_bw,
                placement_only=placement_only)
            t.reports.append(report)
            self.reports.append(report)
            self.metrics["replans"] += 1
            self.metrics["migrated_experts"] += report.migrated_experts
            self.metrics["migrated_bytes"] += report.migrated_bytes
            self.metrics["downtime_s"] += report.downtime_s
        t.controller.adopt(target, point)

    # -- runtime loop -------------------------------------------------------
    def _total_iterations(self) -> float:
        return sum(float(t.engine.metrics.get("iterations", 0))
                   for t in self._tenants.values())

    def step(self) -> bool:
        """Per-tenant QoS control + violation-driven joint re-arbitration;
        call between decode iterations (the driver's ``on_iteration``
        slot). Returns True iff a joint re-arbitration was applied."""
        for t in self._tenants.values():
            t.controller.step()
            m = t.controller.metrics["last_measured_tps"]
            if t.point is not None and m > 0:
                t.derate = m / max(t.point.qos.tokens_per_s, 1e-12)
        if any(t.pending_violation for t in self._tenants.values()) \
                and (self._total_iterations() - self._last_arb_iter
                     >= self.cooldown_iterations):
            return self._maybe_rearbitrate()
        return False

    def run_iteration(self, **kw) -> bool:
        """Advance every tenant engine that has work by one decode
        iteration (real engines; the simulator is driven externally),
        then run the joint control step."""
        for t in self._tenants.values():
            if getattr(t.engine, "has_work", lambda: False)():
                t.engine.run_iteration(**kw)
        return self.step()

    def has_work(self) -> bool:
        return any(getattr(t.engine, "has_work", lambda: False)()
                   for t in self._tenants.values())

    def close(self):
        """Release every tenant's transfer pipeline, then close the
        SHARED swap space (joins its async workers when the deployment
        streams through an ``AsyncExpertCache`` — DESIGN.md §12)."""
        for t in self._tenants.values():
            close = getattr(t.engine, "close", None)
            if close is not None:
                close()
        self.cache.close()

    def summary(self) -> str:
        m = self.metrics
        lines = [
            f"multi-tenant: {len(self._tenants)} tenants, budget "
            f"{_fmt_bytes(self.budget_bytes)} "
            f"(used {_fmt_bytes(m['used_bytes'])}), "
            f"{m['arbitrations']:.0f} arbitrations, "
            f"{m['replans']:.0f} replans migrating "
            f"{m['migrated_experts']:.0f} experts "
            f"({m['migrated_bytes'] / 2**20:.1f} MiB, "
            f"~{m['downtime_s'] * 1e3:.1f} ms downtime)"]
        for name, t in self._tenants.items():
            p = t.point.summary() if t.point else "unassigned"
            lines.append(f"  [{name}] w={t.spec.weight:g} "
                         f"alloc={_fmt_bytes(t.allocated_bytes)} "
                         f"derate={t.derate:.2f} @ {p}")
        return "\n".join(lines)
