"""Deterministic serving simulator — the reusable test/benchmark harness
behind the QoS, scheduler and multi-tenant suites (DESIGN.md §10.4).

The QoS controller and the multi-tenant arbiter are CONTROL loops: what
they need from "an engine" is a metrics dict, ``apply_frontier_point``
and (optionally) ``latency_percentiles``. Driving the real jax engine
through every controller scenario would be slow and, worse,
non-deterministic (wall-clock throughput noise would flake the
convergence assertions). This module is the shared stand-in:

* :class:`VirtualClock` — simulated time; nothing here reads
  ``time.perf_counter``, so a scenario replays bit-identically.
* :class:`SimulatedEngine` — engine-shaped object whose *measured*
  throughput is scriptable per frontier point: by default the analytic
  estimate times a constant ``model_error`` (the controller must close
  exactly that gap, as it would close wall-clock drift in production), or
  an arbitrary ``throughput_fn(point, iteration)`` for time-varying
  interference. Per-request latency is scriptable the same way
  (``latency_fn``) for p95-target scenarios.
* :func:`run_scripted` — drives N decode iterations with a controller
  stepping between them, firing scheduled events (budget shocks, target
  renegotiations, interference onsets) at exact iteration indices.
* :func:`budget_shock` — the canonical event: the job manager grows or
  shrinks the active target's memory budget mid-run.

Used by ``tests/test_qos.py``, ``tests/test_multi_tenant.py`` and the
multi-tenant mode of ``benchmarks/fig3_throughput.py``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pareto import FrontierPoint
from repro.serving.metrics import base_metrics

__all__ = ["VirtualClock", "SimulatedEngine", "run_scripted",
           "budget_shock", "zipf_route_fn"]


class VirtualClock:
    """Deterministic simulated time (seconds) plus an event heap.

    Engines sharing one clock advance it cooperatively; tests and the
    control plane (DESIGN.md §14) read/advance it explicitly. Time is
    guarded monotone: a negative ``advance`` delta, an ``advance_to``
    into the past, and NaN deltas all raise instead of silently
    rewinding — a rewound clock would corrupt every accumulated
    ``*_s`` metric downstream.

    The event heap is the trace layer's scheduling surface:
    ``schedule_at(t, event)`` enqueues, ``peek()`` inspects the next due
    time, and ``pop_due()`` drains (deterministically: FIFO among equal
    timestamps) everything scheduled at or before *now*. Events are
    opaque payloads — callables by convention, fired by the caller, so
    the clock stays replay-neutral.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._heap: List[Tuple[float, int, Any]] = []
        self._seq = 0

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if not (dt >= 0):        # rejects negatives AND NaN
            raise ValueError(f"time only moves forward (dt={dt})")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        """Jump to an absolute time >= now (monotonicity guard)."""
        t = float(t)
        if math.isnan(t) or t < self._t:
            raise ValueError(
                f"time only moves forward (now={self._t}, target={t})")
        self._t = t
        return self._t

    # -- event heap ---------------------------------------------------------
    def schedule_at(self, t: float, event: Any) -> int:
        """Enqueue ``event`` to come due at absolute time ``t`` (>= now);
        returns a sequence id (also the FIFO tie-break among events
        scheduled at the same instant)."""
        t = float(t)
        if math.isnan(t) or t < self._t:
            raise ValueError(
                f"cannot schedule into the past (now={self._t}, t={t})")
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, event))
        return self._seq

    def peek(self) -> Optional[float]:
        """Due time of the earliest scheduled event (None when empty)."""
        return self._heap[0][0] if self._heap else None

    def pop_due(self, until: Optional[float] = None) -> List[Any]:
        """Remove and return every event scheduled at or before ``until``
        (default: now), in (time, insertion) order."""
        limit = self._t if until is None else min(float(until), self._t)
        out: List[Any] = []
        while self._heap and self._heap[0][0] <= limit:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def pending(self) -> int:
        return len(self._heap)


ThroughputFn = Callable[[FrontierPoint, int], float]
LatencyFn = Callable[[FrontierPoint, int], float]
TransferFn = Callable[[FrontierPoint, int], float]
#: scripted per-iteration routed-access counts [L, E] (DESIGN.md §15)
RouteFn = Callable[[FrontierPoint, int], np.ndarray]


def zipf_route_fn(num_layers: int, num_experts: int, *,
                  alpha: float = 1.2, tokens_per_iter: int = 64,
                  top_k: int = 2, seed: int = 0,
                  hot_rotation: int = 0) -> RouteFn:
    """Deterministic Zipf-skewed routing schedule: iteration ``it``
    draws ``tokens_per_iter * top_k`` accesses per layer from a Zipf
    law over expert ranks (expert 0 hottest), rng seeded ``seed + it``
    so the whole trace replays bit-identically. ``hot_rotation > 0``
    rotates the hot set by ``num_experts // 2`` every that many
    iterations — the alternating-hotness adversary the hysteresis test
    throws at the dynamic controller."""
    ranks = np.arange(1, num_experts + 1, dtype=np.float64)
    p = ranks ** -float(alpha)
    p /= p.sum()

    def fn(point: FrontierPoint, it: int) -> np.ndarray:
        rng = np.random.default_rng(seed + it)
        probs = p
        if hot_rotation and (it // hot_rotation) % 2:
            probs = np.roll(p, num_experts // 2)
        counts = np.stack([
            rng.multinomial(tokens_per_iter * top_k, probs)
            for _ in range(num_layers)])
        return counts.astype(np.int64)

    return fn


class SimulatedEngine:
    """Engine-shaped deterministic stand-in for control-loop tests.

    Interface (the subset of ``AdaptiveServingEngine`` the QoSController
    and the MultiTenantEngine consume):

    * ``metrics`` — iterations / tokens_generated / decode_s /
      transfer_s / transfer_exposed_s;
    * ``apply_frontier_point(point)`` — records the replan (count +
      full history in ``applied``) and switches the simulated speed;
    * ``latency_percentiles(qs, last_n=None)`` — over scripted latencies.

    Scripting knobs:

    * ``model_error`` — measured tokens/s = analytic estimate × this
      factor (constant miscalibration);
    * ``throughput_fn(point, iteration)`` — overrides ``model_error``
      with an arbitrary schedule (time-varying co-tenant interference);
      with a scripted ``transfer_fn`` this is the COMPUTE-only rate;
    * ``transfer_fn(point, iteration)`` — scripted expert-transfer
      seconds per iteration (DESIGN.md §12). With ``overlap=False`` all
      of it lands on the critical path (serial staging); with
      ``overlap=True`` only ``max(0, transfer - overlap_efficiency *
      decode_dt)`` is exposed — the async pipeline's A/B switch, exactly
      reproducible;
    * ``latency_fn(point, iteration)`` — one completed-request latency
      recorded per iteration (drives p95 targets);
    * ``clock`` — a shared :class:`VirtualClock`; each iteration advances
      it by the simulated decode time plus the exposed transfer time;
    * ``spec_k`` / ``acceptance`` — speculative decode (DESIGN.md §17):
      each iteration proposes ``batch * spec_k`` drafts of which a
      deterministic ``acceptance`` fraction is accepted (extra tokens on
      top of the guaranteed one per slot), while decode time stretches
      by ``spec_k * spec_draft_cost`` (the draft pass's share of a plain
      iteration). Counters land in the SAME schema keys as the real
      engine (``spec_proposed``/``spec_accepted``/``acceptance_rate``)
      so the QoSController's acceptance fallback is testable here;
      ``set_speculation(0)`` is the fallback's entry point, as on the
      real engine.
    """

    def __init__(self, *, model_error: float = 1.0,
                 throughput_fn: Optional[ThroughputFn] = None,
                 latency_fn: Optional[LatencyFn] = None,
                 transfer_fn: Optional[TransferFn] = None,
                 route_fn: Optional[RouteFn] = None,
                 overlap: bool = False,
                 overlap_efficiency: float = 1.0,
                 clock: Optional[VirtualClock] = None,
                 batch: int = 4,
                 spec_k: int = 0,
                 acceptance: float = 0.0,
                 spec_draft_cost: float = 0.25):
        self.model_error = model_error
        self.clock = clock if clock is not None else VirtualClock()
        self.batch = batch
        self._throughput_fn = throughput_fn
        self._latency_fn = latency_fn
        self._transfer_fn = transfer_fn
        self._route_fn = route_fn
        self.overlap = overlap
        self.overlap_efficiency = overlap_efficiency
        self.spec_k = max(0, int(spec_k))
        self.acceptance = min(max(float(acceptance), 0.0), 1.0)
        self.spec_draft_cost = float(spec_draft_cost)
        self.point: Optional[FrontierPoint] = None
        self.replans = 0
        #: full replan history, oldest first (assertable trace)
        self.applied: List[FrontierPoint] = []
        # the FULL shared schema (DESIGN.md §14.2): controllers written
        # against the real engine's dict shape see the same keys here —
        # sim-irrelevant ones simply stay zero.
        self.metrics: Dict[str, float] = base_metrics()
        self._latencies: List[float] = []
        #: accumulated routed-access histogram [L, E] — fed by
        #: ``route_fn`` each iteration; like the real engine's, it
        #: SURVIVES ``apply_frontier_point`` (same plan shape), the
        #: regression the dynamic controller depends on (DESIGN.md §15).
        self.route_counts: Optional[np.ndarray] = None

    # -- engine interface ---------------------------------------------------
    def apply_frontier_point(self, point: FrontierPoint):
        self.point = point
        self.replans += 1
        self.applied.append(point)
        shape = point.plan.bits.shape
        if self.route_counts is None or self.route_counts.shape != shape:
            self.route_counts = np.zeros(shape, np.int64)

    def measured_tps(self) -> float:
        """The tokens/s the NEXT iteration will run at (the COMPUTE-only
        rate when a ``transfer_fn`` is scripted — exposed transfer time
        is added on top per iteration)."""
        if self.point is None:
            raise RuntimeError("no frontier point applied")
        if self._throughput_fn is not None:
            return float(self._throughput_fn(self.point,
                                             int(self.metrics["iterations"])))
        tps = self.point.qos.tokens_per_s * self.model_error
        if self._transfer_fn is not None:
            # the analytic rate already charges exposed transfer; with a
            # scripted transfer_fn that time is added separately per
            # iteration, so strip it back to the compute-only rate (no
            # double count)
            q = self.point.qos
            if q.t_compute_ms > 0:
                tps *= (q.t_compute_ms + q.t_exposed_ms) / q.t_compute_ms
        return tps

    def run_iteration(self, batch: Optional[int] = None) -> None:
        """One decode iteration at the active point's simulated speed.
        Both scripting hooks see the SAME (pre-increment) iteration
        index, so a schedule keyed on one iteration switches throughput
        and latency together."""
        b = self.batch if batch is None else batch
        it = int(self.metrics["iterations"])
        tps = self.measured_tps()
        dt = b / max(tps, 1e-12)
        transfer = float(self._transfer_fn(self.point, it)) \
            if self._transfer_fn is not None else 0.0
        # DESIGN.md §12: serial staging exposes every transferred second;
        # the async pipeline hides up to overlap_efficiency * decode_dt
        exposed = max(0.0, transfer - self.overlap_efficiency * dt) \
            if self.overlap else transfer
        # speculative decode (DESIGN.md §17): per iteration every slot
        # proposes spec_k drafts; a deterministic ``acceptance`` fraction
        # is accepted as extra tokens, while decode time stretches by the
        # draft pass's cost share. spec_k=0 reproduces the plain
        # iteration bit-for-bit.
        proposed = accepted = 0
        if self.spec_k > 0:
            proposed = b * self.spec_k
            accepted = int(round(self.acceptance * proposed))
            dt *= 1.0 + self.spec_k * self.spec_draft_cost
        self.metrics["iterations"] += 1
        self.metrics["tokens_generated"] += b + accepted
        self.metrics["spec_proposed"] += proposed
        self.metrics["spec_accepted"] += accepted
        if self.metrics["spec_proposed"]:
            self.metrics["acceptance_rate"] = \
                self.metrics["spec_accepted"] / self.metrics["spec_proposed"]
        self.metrics["decode_s"] += dt
        self.metrics["transfer_s"] += transfer
        self.metrics["transfer_exposed_s"] += exposed
        self.metrics["transfer_overlapped_s"] += transfer - exposed
        self.clock.advance(dt + exposed)
        if self._route_fn is not None:
            self.route_counts += np.asarray(
                self._route_fn(self.point, it), np.int64)
        if self._latency_fn is not None:
            self._latencies.append(float(self._latency_fn(self.point, it)))

    def set_speculation(self, k: int) -> None:
        """Change the draft depth mid-run — the QoSController's
        acceptance-fallback entry point (``set_speculation(0)`` = plain
        decode from the next iteration on), same contract as the real
        engine's."""
        self.spec_k = max(0, int(k))

    # -- dynamic precision (DESIGN.md §15) ----------------------------------
    @property
    def current_plan(self):
        """The active point's precision plan (None before the first
        ``apply_frontier_point``) — possibly bits-updated in place."""
        return self.point.plan if self.point is not None else None

    def reset_route_counts(self) -> None:
        if self.route_counts is not None:
            self.route_counts[...] = 0

    def apply_bits_update(self, new_bits: np.ndarray) -> Dict[str, Any]:
        """The real engine's in-place rung-flip path, simulated: swaps
        the active point's plan for a bits-replaced copy under the same
        contract (locations and per-layer rung counts preserved). The
        sim has no expert cache, so ``cache_bytes_delta`` is 0 here;
        byte-conservation of the real re-staging path is tested against
        the real ``ExpertCache`` in tests/test_dynamic_precision.py."""
        assert self.point is not None, "no frontier point applied"
        import dataclasses as _dc

        old_plan = self.point.plan
        new_bits = np.asarray(new_bits, old_plan.bits.dtype)
        if new_bits.shape != old_plan.bits.shape:
            raise ValueError(f"bits shape {new_bits.shape} != "
                             f"{old_plan.bits.shape}")
        for li in range(new_bits.shape[0]):
            for b in old_plan.ladder:
                if int((new_bits[li] == b).sum()) \
                        != int((old_plan.bits[li] == b).sum()):
                    raise ValueError(
                        "apply_bits_update must preserve per-layer rung "
                        f"counts (layer {li}, rung {b})")
        flipped = new_bits != old_plan.bits
        new_plan = _dc.replace(old_plan, bits=new_bits)
        self.point = _dc.replace(self.point, plan=new_plan)
        self.metrics["bits_updates"] = \
            self.metrics.get("bits_updates", 0) + 1
        return {"flipped": int(flipped.sum()),
                "promotions": int((new_bits > old_plan.bits).sum()),
                "demotions": int((new_bits < old_plan.bits).sum()),
                "cache_bytes_delta": 0, "restaged": 0}

    def latency_percentiles(self, qs: Sequence[int] = (50, 95),
                            last_n: Optional[int] = None
                            ) -> Dict[str, float]:
        lats = self._latencies if last_n is None else self._latencies[-last_n:]
        if not lats:
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}

    def has_work(self) -> bool:
        """The simulator is driven open-loop (no request queue)."""
        return False

    def summary(self) -> str:
        p = self.point.summary() if self.point else "no point"
        spec = ""
        if self.metrics["spec_proposed"]:
            spec = (f" spec[k={self.spec_k} "
                    f"acc={self.metrics['acceptance_rate']:.0%} "
                    f"{self.metrics['spec_accepted']:.0f}/"
                    f"{self.metrics['spec_proposed']:.0f}]")
        return (f"sim[{p}] it={self.metrics['iterations']:.0f} "
                f"tok={self.metrics['tokens_generated']:.0f} "
                f"t={self.clock.now():.2f}s replans={self.replans}" + spec)


def run_scripted(engine, controller, iterations: int, *,
                 events: Optional[Dict[int, Callable[[], None]]] = None,
                 batch: Optional[int] = None) -> None:
    """Drive ``iterations`` decode iterations, stepping ``controller``
    between them (exactly where the live driver's ``on_iteration`` hook
    runs). ``events[i]`` fires BEFORE iteration ``i`` (0-based) — budget
    shocks, target renegotiations, interference onsets. ``controller``
    may be None (open-loop replay) or anything with a ``step()``."""
    events = events or {}
    for i in range(iterations):
        if i in events:
            events[i]()
        engine.run_iteration(batch)
        if controller is not None:
            controller.step()


def budget_shock(controller, mem_budget_bytes: float) -> Callable[[], None]:
    """Event factory for :func:`run_scripted`: the job manager resizes
    the active target's memory budget mid-run (the canonical shock of
    the paper's Fig. 1 multi-tenant scenario). The controller sees the
    new budget on its next ``step()`` — a shrink below the active point
    is a feasibility violation and bypasses hysteresis (DESIGN.md §9.3)."""
    def fire():
        if controller.target is None:
            raise RuntimeError("controller has no active target to shock")
        controller.target = dataclasses.replace(
            controller.target, mem_budget_bytes=mem_budget_bytes)
    return fire
