"""Iteration-level request scheduler for the continuous-batching engine
(DESIGN.md §3).

The engine owns a FIXED number of decode slots (rows of one slot-based KV
cache); the scheduler owns everything about *requests*: the admission
queue, per-slot request state, and the join/retire decisions taken at
EVERY decode iteration — a short request retires and frees its slot while
its neighbours keep decoding, and the next queued request joins mid-batch
via a prefill-into-slot (no recompile, no re-padding: the decode step is
jitted once for the full slot count).

Admission policy (``SchedulerConfig``):
  * ``max_slots``  — concurrent requests (the decode batch width);
  * ``max_len``    — per-slot KV window: prompt + max_new_tokens must fit;
  * ``max_active_tokens`` — optional cap on the summed token claim
    (prompt + max_new) of all in-flight requests, the knob that trades
    batch occupancy against KV memory under a tight budget.

The scheduler is pure bookkeeping (no jax) and unit-testable on its own.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: Optional[float] = None    # joined a slot (prefill ran)
    t_first: Optional[float] = None    # first output token sampled
    t_done: Optional[float] = None

    @property
    def token_claim(self) -> int:
        """KV-window footprint this request may grow to."""
        return len(self.prompt) + self.max_new_tokens

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (queueing + prefill)."""
        if self.t_first is None:
            return None
        return self.t_first - self.t_submit

    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


@dataclasses.dataclass
class SlotState:
    """Runtime state of one decode slot."""
    req: Request
    position: int          # absolute position of the NEXT token to decode
    last_token: int        # token fed to the next decode step


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_slots: int = 8
    max_len: int = 256                 # prompt + max_new_tokens cap
    # Prompt cap — the KV ring window. For sliding-window models this is
    # smaller than max_len: generation may extend PAST the window (the
    # ring wraps, SWA masking handles it) but a prompt must fit in one
    # prefill write.
    max_prompt_len: Optional[int] = None
    max_queue: Optional[int] = None
    max_active_tokens: Optional[int] = None


class ContinuousScheduler:
    """Admission queue + slot table. The engine calls, per iteration:

        for slot, req in sched.admit(): ...prefill req into slot...
        for slot, st in sched.active(): ...decode one token...
        sched.retire(slot)              # when st.req.done()
    """

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[SlotState]] = [None] * cfg.max_slots
        self.done: Dict[int, Request] = {}
        self._rid = 0

    # -- submission --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               now: Optional[float] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) < 1:
            raise ValueError("prompt must hold at least one token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the prefill "
                             "logit already yields one token)")
        if len(prompt) + max_new_tokens > self.cfg.max_len:
            raise ValueError(
                f"request needs {len(prompt)}+{max_new_tokens} tokens; "
                f"slot window is {self.cfg.max_len}")
        if self.cfg.max_prompt_len is not None \
                and len(prompt) > self.cfg.max_prompt_len:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the prefill "
                f"window {self.cfg.max_prompt_len}")
        if self.cfg.max_queue is not None \
                and len(self.queue) >= self.cfg.max_queue:
            raise RuntimeError("admission queue full")
        self._rid += 1
        self.queue.append(Request(
            rid=self._rid, prompt=prompt, max_new_tokens=max_new_tokens,
            t_submit=time.perf_counter() if now is None else now))
        return self._rid

    # -- introspection -----------------------------------------------------
    def active(self) -> List[Tuple[int, SlotState]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def active_token_claim(self) -> int:
        return sum(s.req.token_claim for s in self.slots if s is not None)

    def has_work(self) -> bool:
        return bool(self.queue) or self.num_active > 0

    # -- join / retire -----------------------------------------------------
    def admit(self, now: Optional[float] = None
              ) -> List[Tuple[int, Request]]:
        """Pop queued requests into free slots (FIFO) subject to the token
        budget; returns [(slot, request)] for the engine to prefill."""
        joined: List[Tuple[int, Request]] = []
        claim = self.active_token_claim
        for slot in self.free_slots():
            if not self.queue:
                break
            nxt = self.queue[0]
            if self.cfg.max_active_tokens is not None and \
                    claim + nxt.token_claim > self.cfg.max_active_tokens \
                    and self.num_active > 0:
                break                      # wait for retirements
            req = self.queue.popleft()
            req.t_admit = time.perf_counter() if now is None else now
            # position of the first decode step = prompt length; the first
            # output token comes from the prefill logits (engine fills it)
            self.slots[slot] = SlotState(req=req,
                                         position=len(req.prompt),
                                         last_token=-1)
            claim += req.token_claim
            joined.append((slot, req))
        return joined

    def retire(self, slot: int, now: Optional[float] = None) -> Request:
        st = self.slots[slot]
        assert st is not None, f"slot {slot} already free"
        st.req.t_done = time.perf_counter() if now is None else now
        self.slots[slot] = None
        self.done[st.req.rid] = st.req
        return st.req

    def drain_queue(self) -> List[Request]:
        """Remove all queued (not yet admitted) requests; returns them."""
        out = list(self.queue)
        self.queue.clear()
        return out

    # -- metrics -----------------------------------------------------------
    def latency_percentiles(self, qs=(50, 95)) -> Dict[str, float]:
        lats = [r.latency_s for r in self.done.values()
                if r.latency_s is not None]
        if not lats:
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}
