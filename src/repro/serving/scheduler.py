"""Iteration-level request scheduler for the continuous-batching engine
(DESIGN.md §3).

The engine owns a FIXED number of decode slots (rows of one slot-based KV
cache); the scheduler owns everything about *requests*: the admission
queue, per-slot request state, and the join/retire decisions taken at
EVERY decode iteration — a short request retires and frees its slot while
its neighbours keep decoding, and the next queued request joins mid-batch
via a prefill-into-slot (no recompile, no re-padding: the decode step is
jitted once for the full slot count).

Admission policy (``SchedulerConfig``):
  * ``max_slots``  — concurrent requests (the decode batch width);
  * ``max_len``    — per-slot KV window: prompt + max_new_tokens must fit;
  * ``max_active_tokens`` — optional cap on the summed token claim
    (prompt + max_new) of all in-flight requests, the knob that trades
    batch occupancy against KV memory under a tight budget.

Variable tokens per iteration (DESIGN.md §17): under speculative decode
an iteration may emit anywhere from 1 to ``speculate + 1`` tokens per
slot, and the engine clamps each slot's draft depth to its remaining
``max_new_tokens`` — so a request never overruns the claim admission
reserved. Because admission charges the FULL ``prompt + max_new`` claim
up front (not per-token), the in-flight claim bound holds for any
tokens-per-iteration schedule; no scheduler change is needed for
speculation, only this contract.

Admission order (DESIGN.md §9): highest :class:`RequestSLO` priority
first; within a priority class, earliest effective deadline first; then
FIFO. Requests without an SLO keep exact FIFO behaviour.

The scheduler is pure bookkeeping (no jax) and unit-testable on its own.
:class:`SamplingParams` and :class:`RequestSLO` are defined here (the
leaf of the serving import graph) and re-exported by the public surface
``repro.serving.api``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters (DESIGN.md §9).

    ``temperature <= 0`` is greedy; ``top_k == 0`` disables the top-k
    filter. A request without SamplingParams inherits the engine-level
    defaults passed to ``run_iteration``/``step``."""
    temperature: float = 0.0
    top_k: int = 0


@dataclasses.dataclass(frozen=True)
class RequestSLO:
    """Per-request service-level objective (DESIGN.md §9).

    ``priority``: larger is more urgent (admitted first). ``deadline_s``
    is RELATIVE to submission; the scheduler admits earliest-deadline
    first within a priority class and ``ServeResult.deadline_met``
    reports the outcome — the scheduler never drops an expired request
    (the paper's QoS is throughput/quality, not load shedding)."""
    priority: int = 0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    sampling: Optional[SamplingParams] = None
    slo: RequestSLO = dataclasses.field(default_factory=RequestSLO)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: Optional[float] = None    # joined a slot (prefill ran)
    t_first: Optional[float] = None    # first output token sampled
    t_done: Optional[float] = None

    @property
    def token_claim(self) -> int:
        """KV-window footprint this request may grow to."""
        return len(self.prompt) + self.max_new_tokens

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (queueing + prefill)."""
        if self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def deadline(self) -> Optional[float]:
        """Absolute deadline on the t_submit clock; None = best effort."""
        if self.slo.deadline_s is None:
            return None
        return self.t_submit + self.slo.deadline_s

    @property
    def deadline_met(self) -> Optional[bool]:
        """None while in flight or when no deadline was declared."""
        if self.slo.deadline_s is None or self.t_done is None:
            return None
        return self.latency_s <= self.slo.deadline_s

    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


@dataclasses.dataclass
class SlotState:
    """Runtime state of one decode slot."""
    req: Request
    position: int          # absolute position of the NEXT token to decode
    last_token: int        # token fed to the next decode step


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_slots: int = 8
    max_len: int = 256                 # prompt + max_new_tokens cap
    # Prompt cap — the KV ring window. For sliding-window models this is
    # smaller than max_len: generation may extend PAST the window (the
    # ring wraps, SWA masking handles it) but a prompt must fit in one
    # prefill write.
    max_prompt_len: Optional[int] = None
    max_queue: Optional[int] = None
    max_active_tokens: Optional[int] = None
    # Starvation control (DESIGN.md §9.2): every ``aging_s`` seconds a
    # request waits in the queue, its EFFECTIVE priority rises one class,
    # so a sustained stream of high-priority arrivals cannot starve
    # low-priority requests forever (deadline-style aging — the wait
    # itself becomes the urgency). None disables aging (strict classes).
    aging_s: Optional[float] = None


class ContinuousScheduler:
    """Admission queue + slot table. The engine calls, per iteration:

        for slot, req in sched.admit(): ...prefill req into slot...
        for slot, st in sched.active(): ...decode one token...
        sched.retire(slot)              # when st.req.done()
    """

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[SlotState]] = [None] * cfg.max_slots
        self.done: Dict[int, Request] = {}
        self._rid = 0

    # -- submission --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               now: Optional[float] = None, *,
               sampling: Optional[SamplingParams] = None,
               slo: Optional[RequestSLO] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) < 1:
            raise ValueError("prompt must hold at least one token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the prefill "
                             "logit already yields one token)")
        if len(prompt) + max_new_tokens > self.cfg.max_len:
            raise ValueError(
                f"request needs {len(prompt)}+{max_new_tokens} tokens; "
                f"slot window is {self.cfg.max_len}")
        if self.cfg.max_prompt_len is not None \
                and len(prompt) > self.cfg.max_prompt_len:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the prefill "
                f"window {self.cfg.max_prompt_len}")
        if self.cfg.max_queue is not None \
                and len(self.queue) >= self.cfg.max_queue:
            raise RuntimeError("admission queue full")
        self._rid += 1
        self.queue.append(Request(
            rid=self._rid, prompt=prompt, max_new_tokens=max_new_tokens,
            sampling=sampling, slo=slo or RequestSLO(),
            t_submit=time.perf_counter() if now is None else now))
        return self._rid

    # -- introspection -----------------------------------------------------
    def active(self) -> List[Tuple[int, SlotState]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def active_token_claim(self) -> int:
        return sum(s.req.token_claim for s in self.slots if s is not None)

    def has_work(self) -> bool:
        return bool(self.queue) or self.num_active > 0

    # -- join / retire -----------------------------------------------------
    def effective_priority(self, req: Request, now: Optional[float]) -> int:
        """SLO priority plus aging: one class per ``aging_s`` of queue
        wait (0 extra when aging is disabled or ``now`` is unknown)."""
        prio = req.slo.priority
        if self.cfg.aging_s is not None and now is not None:
            prio += int(max(0.0, now - req.t_submit) / self.cfg.aging_s)
        return prio

    def _admission_key(self, req: Request, now: Optional[float]):
        """Aged priority classes first, then earliest deadline, then FIFO.
        Deadline-less requests sort after any deadline in their class."""
        dl = req.deadline
        return (-self.effective_priority(req, now),
                dl if dl is not None else float("inf"),
                req.t_submit, req.rid)

    def admit(self, now: Optional[float] = None
              ) -> List[Tuple[int, Request]]:
        """Pop queued requests into free slots subject to the token budget,
        in admission order (aged priority desc, deadline asc, FIFO);
        returns [(slot, request)] for the engine to prefill. When the next
        request in admission order does not fit the token budget, admission
        stops — no skip-ahead, so a large high-priority request is never
        starved by smaller low-priority ones."""
        joined: List[Tuple[int, Request]] = []
        claim = self.active_token_claim
        # aging compares WAITED time, so it needs a consistent "now":
        # the caller's virtual clock when given, wall clock otherwise.
        key_now = now
        if key_now is None and self.cfg.aging_s is not None:
            key_now = time.perf_counter()
        for slot in self.free_slots():
            if not self.queue:
                break
            nxt = min(self.queue,
                      key=lambda r: self._admission_key(r, key_now))
            if self.cfg.max_active_tokens is not None and \
                    claim + nxt.token_claim > self.cfg.max_active_tokens \
                    and self.num_active > 0:
                break                      # wait for retirements
            self.queue.remove(nxt)
            req = nxt
            req.t_admit = time.perf_counter() if now is None else now
            # position of the first decode step = prompt length; the first
            # output token comes from the prefill logits (engine fills it)
            self.slots[slot] = SlotState(req=req,
                                         position=len(req.prompt),
                                         last_token=-1)
            claim += req.token_claim
            joined.append((slot, req))
        return joined

    def retire(self, slot: int, now: Optional[float] = None) -> Request:
        st = self.slots[slot]
        assert st is not None, f"slot {slot} already free"
        st.req.t_done = time.perf_counter() if now is None else now
        self.slots[slot] = None
        self.done[st.req.rid] = st.req
        return st.req

    def drain_queue(self) -> List[Request]:
        """Remove all queued (not yet admitted) requests; returns them."""
        out = list(self.queue)
        self.queue.clear()
        return out

    # -- metrics -----------------------------------------------------------
    def latency_percentiles(self, qs=(50, 95),
                            last_n: Optional[int] = None
                            ) -> Dict[str, float]:
        """Latency percentiles over completed requests; ``last_n``
        restricts to the most recent completions (the QoSController's
        windowed p95 — lifetime tails would let cold-start samples vote
        forever)."""
        done = [r for r in self.done.values() if r.latency_s is not None]
        if last_n is not None:
            done = sorted(done, key=lambda r: r.t_done)[-last_n:]
        lats = [r.latency_s for r in done]
        if not lats:
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}
