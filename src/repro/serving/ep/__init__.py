"""Expert-parallel multi-device serving (DESIGN.md §16).

Two layers on top of the single-device engine:

* :func:`~repro.serving.ep.mesh_engine.build_ep_engine` — ONE engine
  decoding over a (1, ep) jax mesh: the decode FFN dispatches through
  the ``mixed_moe`` shard_map EP path (all2all token routing, per-device
  rung-bank shards) and the planner/frontier gain the PEER placement
  tier. Output is bit-identical to the single-device engine
  (tests/test_token_gather_ep.py pins EP ∈ {1, 2, 4}).
* :class:`~repro.serving.ep.replica.DPReplicaGroup` — N engine replicas
  behind one submit/run/result surface; the raw throughput multiplier
  for heavy traffic, driven by the control plane's
  :class:`~repro.serving.control_plane.autoscale.ReplicaAutoscaler`.

Runnable on CPU via the forced host device count
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` BEFORE
importing jax — the ``launch/dryrun.py`` pattern), so tests and CI need
no multi-accelerator box.
"""
from repro.serving.ep.mesh_engine import (  # noqa: F401
    build_ep_engine, validate_ep_layout,
)
from repro.serving.ep.replica import DPReplicaGroup, make_dp_group  # noqa: F401

__all__ = ["build_ep_engine", "validate_ep_layout", "DPReplicaGroup",
           "make_dp_group"]
