"""EP mesh engine: the single-device serving engine over a (1, ep) mesh.

The engine itself needs no new decode path — ``AdaptiveServingEngine``
already runs every FFN through ``mixed_moe.moe_apply`` under shard_map,
which dispatches tokens over the mesh's "model" axis (all2all routing,
per-device shards of each rung bank, grouped kernels per local bank).
What this module adds is the LAYOUT contract: expert counts and every
rung bank must divide evenly over the EP axis, and the engine's planner
must know ``ep`` so replans keep honouring that (``EngineConfig.ep``).

Bit-identity with the single-device engine (pinned by
tests/test_token_gather_ep.py) rests on the mesh being (1, ep): the
size-1 "data" axis replicates tokens on every rank (no token-gather /
fsdp partial sums), each rank computes exact per-expert contributions
for its local experts, and the closing psum adds exact zeros from ranks
a token was not dispatched to.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.api import EngineConfig, build_engine

__all__ = ["build_ep_engine", "validate_ep_layout"]


def validate_ep_layout(cfg, ep: int) -> None:
    """Raise ``ValueError`` unless ``cfg``'s MoE layout divides over an
    EP axis of size ``ep`` (every per-rung bank is sharded contiguously
    across ranks, so total experts — and, after planner rounding, every
    bank — must be a multiple of ``ep``)."""
    ep = int(ep)
    if ep < 1:
        raise ValueError(f"ep must be >= 1, got {ep}")
    if ep == 1:
        return
    if cfg.moe is None:
        raise ValueError(
            f"--ep {ep} needs an MoE model; {cfg.arch_id} has no experts "
            "to shard")
    e = cfg.moe.num_experts
    if e % ep != 0:
        raise ValueError(
            f"num_experts={e} does not divide over ep={ep} "
            f"({e} % {ep} = {e % ep}); pick ep from the divisors of the "
            "expert count so every rung bank shards evenly")


def build_ep_engine(cfg, params, config: Optional[EngineConfig] = None, *,
                    ep: int = 1, replica: int = 0, expert_cache=None):
    """One serving engine decoding over the (1, ep) mesh of DP replica
    ``replica`` (device slice ``[replica*ep, (replica+1)*ep)``).

    ``ep=1`` builds the plain single-device engine (no mesh) — the
    historical path bit-for-bit. Raises the actionable ``XLA_FLAGS``
    error when the host exposes too few devices, and ``ValueError`` on
    layouts that do not divide over the EP axis.
    """
    validate_ep_layout(cfg, ep)
    config = config or EngineConfig()
    if config.ep not in (1, ep):
        raise ValueError(
            f"EngineConfig.ep={config.ep} conflicts with ep={ep}")
    config = dataclasses.replace(config, ep=int(ep))
    mesh = None
    if ep > 1 or replica > 0:
        from repro.launch.mesh import make_ep_mesh
        mesh = make_ep_mesh(ep, replica=replica)
    return build_engine(cfg, params, config, mesh=mesh,
                        expert_cache=expert_cache)
