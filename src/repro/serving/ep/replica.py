"""DP replica group: N engines behind one serving surface (§16.3).

Data parallelism is deliberately NOT an in-mesh axis here (a size>1
"data" axis would activate the fsdp/token-gather path and break decode
bit-identity): a replica is a WHOLE engine on its own (1, ep) device
slice, and :class:`DPReplicaGroup` fans requests across replicas with
least-loaded routing while presenting the single-engine control surface
— ``submit_request`` / ``run_iteration`` / ``result`` / ``apply_target``
/ ``metrics`` — so existing schedulers and QoS callers work unchanged.

The group is also where the PR 7 control plane's replica decisions land
on real engines: ``autoscale_step`` feeds the group's demand
utilization (active + queued claims over aggregate slot capacity) to a
:class:`~repro.serving.control_plane.autoscale.ReplicaAutoscaler` and
applies the ±1 decision. Scale-down drains: the victim replica stops
receiving new requests and is closed once its in-flight work retires,
so no request is ever dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["DPReplicaGroup", "make_dp_group"]


class DPReplicaGroup:
    """Fan a request stream across N engine replicas.

    ``factory(replica_index)`` builds one engine on the device slice of
    that replica index (see ``make_dp_group``); indices of removed
    replicas are recycled so a later scale-up reuses their devices.
    Request ids returned by the group are GLOBAL: the group keeps the
    global↔(engine, local rid) mapping and harvests every retired
    request's :class:`~repro.serving.api.ServeResult` eagerly, so
    results survive their replica being drained away.
    """

    def __init__(self, factory: Callable[[int], object], *,
                 replicas: int = 1, max_replicas: int = 8):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if max_replicas < replicas:
            raise ValueError(
                f"max_replicas={max_replicas} < initial replicas="
                f"{replicas}")
        self._factory = factory
        self.max_replicas = max_replicas
        self.engines: List[object] = []
        self._slot_of: Dict[int, int] = {}      # id(engine) -> replica idx
        self._free_slots: List[int] = list(range(max_replicas))
        self._draining: set = set()             # id(engine)
        self._rid_map: Dict[int, Tuple[object, int]] = {}
        self._local2g: Dict[int, Dict[int, int]] = {}  # id(eng)->{loc: g}
        self._done: Dict[int, object] = {}      # global rid -> ServeResult
        self._next_rid = 0
        self._target = None
        for _ in range(replicas):
            self._add_replica()

    # -- topology ------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        """Serving replicas (draining ones no longer count as capacity)."""
        return len(self.engines) - len(self._draining)

    def _serving(self) -> List[object]:
        return [e for e in self.engines if id(e) not in self._draining]

    def _add_replica(self):
        if not self._free_slots:
            raise RuntimeError(
                f"replica group is at max_replicas={self.max_replicas}")
        slot = min(self._free_slots)
        engine = self._factory(slot)
        self._free_slots.remove(slot)
        self.engines.append(engine)
        self._slot_of[id(engine)] = slot
        self._local2g[id(engine)] = {}
        if self._target is not None:
            engine.apply_target(self._target)
        return engine

    def _drop_replica(self, engine):
        """Close and forget an IDLE engine."""
        key = id(engine)
        self.engines.remove(engine)
        self._draining.discard(key)
        self._free_slots.append(self._slot_of.pop(key))
        self._local2g.pop(key, None)
        engine.close()

    def scale_to(self, n: int) -> int:
        """Grow/shrink toward ``n`` serving replicas; shrink picks the
        least-loaded replica and drains it (removal completes inside
        ``run_iteration`` once its slots empty). Returns the number of
        serving replicas after the call."""
        if n < 1:
            raise ValueError(f"cannot scale below 1 replica (asked {n})")
        if n > self.max_replicas:
            raise ValueError(
                f"asked {n} replicas, max_replicas={self.max_replicas}")
        while self.n_replicas < n:
            self._add_replica()
        while self.n_replicas > n:
            victim = min(self._serving(), key=self._load)
            if victim.has_work():
                self._draining.add(id(victim))
            else:
                self._drop_replica(victim)
        return self.n_replicas

    # -- routing -------------------------------------------------------
    @staticmethod
    def _load(engine) -> int:
        sched = engine.scheduler
        return len(sched.queue) + sched.num_active

    def submit_request(self, request) -> int:
        """Route to the least-loaded serving replica; returns a GLOBAL
        request id valid for ``result``."""
        engine = min(self._serving(), key=self._load)
        local = engine.submit_request(request)
        rid = self._next_rid
        self._next_rid += 1
        self._rid_map[rid] = (engine, local)
        self._local2g[id(engine)][local] = rid
        return rid

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16, *,
               sampling=None, slo=None) -> int:
        from repro.serving.api import RequestSLO, ServeRequest
        return self.submit_request(ServeRequest(
            prompt=prompt, max_new_tokens=max_new_tokens,
            sampling=sampling, slo=slo or RequestSLO()))

    # -- serving loop --------------------------------------------------
    def run_iteration(self, **kw) -> List[int]:
        """One iteration on EVERY replica (draining ones included — they
        must finish their in-flight work). Returns the GLOBAL rids
        retired this call; drained-empty replicas are closed here."""
        retired: List[int] = []
        for engine in list(self.engines):
            if not engine.has_work():
                continue
            for local in engine.run_iteration(**kw):
                rid = self._local2g[id(engine)].pop(local)
                # re-stamp with the GLOBAL rid: local rids collide
                # across replicas
                self._done[rid] = dataclasses.replace(
                    engine.result(local), rid=rid)
                retired.append(rid)
        for engine in [e for e in self.engines
                       if id(e) in self._draining and not e.has_work()]:
            self._drop_replica(engine)
        return retired

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def result(self, rid: int):
        """ServeResult of a completed request (KeyError in flight —
        same contract as the single engine)."""
        return self._done[rid]

    # -- control surface ----------------------------------------------
    def apply_target(self, target):
        """Apply one QoSTarget to every replica (remembered, so replicas
        added by a later scale-up inherit it)."""
        self._target = target
        return [e.apply_target(target) for e in self.engines]

    @property
    def metrics(self) -> Dict[str, float]:
        """Numeric engine counters summed across replicas, plus the
        group's own ``replicas`` / ``draining`` gauges."""
        agg: Dict[str, float] = {}
        for engine in self.engines:
            for k, v in engine.metrics.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    agg[k] = agg.get(k, 0) + v
        agg["replicas"] = self.n_replicas
        agg["draining"] = len(self._draining)
        return agg

    def throughput_tokens_per_s(self, include_transfer: bool = True
                                ) -> float:
        """Aggregate decode throughput: replicas run concurrently in
        wall-clock, so group throughput is the SUM of per-replica
        rates."""
        return sum(e.throughput_tokens_per_s(include_transfer)
                   for e in self.engines)

    # -- autoscaling (control plane → real engines) --------------------
    def demand_util(self) -> float:
        """Demand over aggregate capacity: active + queued requests per
        decode slot across serving replicas, clamped to [0, 1]."""
        serving = self._serving()
        cap = sum(e.max_slots for e in serving)
        demand = sum(self._load(e) for e in serving)
        return min(1.0, demand / max(cap, 1))

    def autoscale_step(self, now: float, autoscaler=None) -> int:
        """One control-plane tick: feed the group's demand utilization
        to ``autoscaler`` (a fresh §14.3 ReplicaAutoscaler bounded by
        ``max_replicas`` when None) and APPLY its ±1 decision to real
        engines. Returns the decision."""
        if autoscaler is None:
            if not hasattr(self, "_autoscaler"):
                from repro.serving.control_plane.autoscale import \
                    ReplicaAutoscaler
                self._autoscaler = ReplicaAutoscaler(
                    max_replicas=self.max_replicas)
            autoscaler = self._autoscaler
        n = self.n_replicas
        decision = autoscaler.step(
            now, self.demand_util(), n,
            can_add=n < self.max_replicas, can_remove=n > 1)
        if decision:
            self.scale_to(n + decision)
        return decision

    def close(self):
        for engine in list(self.engines):
            self._drop_replica(engine)


def make_dp_group(cfg, params, config=None, *, ep: int = 1, dp: int = 1,
                  max_replicas: Optional[int] = None) -> DPReplicaGroup:
    """A DPReplicaGroup of ``dp`` EP engines: replica ``i`` decodes over
    the (1, ep) mesh on device slice ``[i*ep, (i+1)*ep)``, all sharing
    ``params`` (one host copy; each mesh shards its own device view)."""
    from repro.serving.ep.mesh_engine import build_ep_engine

    def factory(slot: int):
        return build_ep_engine(cfg, params, config, ep=ep, replica=slot)

    return DPReplicaGroup(factory, replicas=dp,
                          max_replicas=max_replicas or max(dp, 1))
