"""Admission control with SLO classes, queue caps and priority
preemption (DESIGN.md §14.3).

A tenant is *active* once its trace ``join`` fires and until it leaves;
it only receives service while *admitted* to an engine replica. The
admission controller closes the gap between the two:

* **admission** — pending tenants (new joiners and previously preempted
  ones) are placed on the replica with the most committed-rate headroom,
  highest SLO priority first; a tenant that does not fit anywhere stays
  pending (its queue keeps accruing, capped by its class's
  ``queue_cap_tokens`` — the overflow is *dropped* and accounted).
* **preemption** — a replica whose measured utilization pins at 1 while
  its backlog grows for ``patience_ticks`` consecutive ticks sheds its
  lowest-priority tenants until its committed rate falls to
  ``drain_to`` × capacity. Preempted tenants drain through the replica
  repoint path (the arbiter re-selects the smaller demand's frontier
  point and the diff emits a §10.3 :class:`~repro.serving.multi.ReplanReport`).
* **aging (no starvation)** — a tenant preempted (or never admitted)
  longer than its class's ``aging_s`` is FORCE-admitted onto the
  least-committed replica, overcommitting it if necessary. Because
  per-replica service is weighted-fair across admitted tenants (never
  strict-priority starvation, §14.3), forced admission guarantees
  progress within one tick; fresh force-admits are shielded from
  immediate re-preemption for one tick.

The controller is deliberately stateless across ticks except for the
per-replica overload streaks — all tenant state lives in the control
plane's arrays, so policies can be swapped per scenario.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

__all__ = ["SLOClass", "DEFAULT_SLO_CLASSES", "AdmissionController"]


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service class: admission priority, throughput floor, backlog
    cap, aging window and weighted-fair share weight."""
    name: str
    priority: int             # higher admits first / preempts last
    min_tokens_per_s: float   # per-tenant SLO floor (violation accounting)
    queue_cap_tokens: float   # backlog cap; arrivals beyond are dropped
    aging_s: float            # max unserved span before forced admission
    weight: float = 1.0       # weighted-fair share within a replica

    def __post_init__(self):
        if self.weight <= 0 or self.aging_s <= 0:
            raise ValueError(f"SLO class {self.name!r}: weight and aging_s "
                             "must be positive")


DEFAULT_SLO_CLASSES: Tuple[SLOClass, ...] = (
    SLOClass("gold", priority=2, min_tokens_per_s=4.0,
             queue_cap_tokens=2400.0, aging_s=240.0, weight=4.0),
    SLOClass("silver", priority=1, min_tokens_per_s=1.0,
             queue_cap_tokens=1200.0, aging_s=600.0, weight=2.0),
    SLOClass("bronze", priority=0, min_tokens_per_s=0.25,
             queue_cap_tokens=600.0, aging_s=1800.0, weight=1.0),
)


class AdmissionController:
    """Admission / preemption / aging over the control plane's tenant
    arrays (the plane is duck-typed — see ControlPlane for the field
    contract)."""

    def __init__(self, classes: Sequence[SLOClass], *,
                 admit_headroom: float = 0.90,
                 preempt_util: float = 0.999,
                 patience_ticks: int = 3,
                 drain_to: float = 0.85):
        self.classes = tuple(classes)
        self.admit_headroom = admit_headroom
        self.preempt_util = preempt_util
        self.patience_ticks = patience_ticks
        self.drain_to = drain_to
        #: replica id -> consecutive overloaded ticks
        self._streak: Dict[int, int] = {}

    # -- helpers ------------------------------------------------------------
    def _headroom(self, plane, r) -> float:
        cap = r.capacity_tps(plane.scn.slots_per_replica)
        return cap * self.admit_headroom - plane.committed_rate(r.id)

    def _place(self, plane, i: int, now: float, force: bool) -> bool:
        """Assign tenant ``i`` to the replica with the most headroom; a
        forced (aged) placement overcommits the least-committed replica
        instead of failing."""
        best, best_h = None, -np.inf
        for r in plane.replicas:
            h = self._headroom(plane, r)
            if h > best_h:
                best, best_h = r, h
        if best is None:
            return False
        if best_h < plane.base_rate[i] and not force:
            return False
        plane.admit(i, best.id, now, forced=force)
        return True

    # -- the per-tick control pass ------------------------------------------
    def step(self, plane, now: float, dt: float) -> int:
        """Aging readmission -> ordinary admission -> overload
        preemption. Returns the number of tenants preempted this tick
        (the plane re-arbitrates when > 0, draining the preempted load
        through the replica repoint path)."""
        self._admit(plane, now)
        return self._preempt(plane, now, dt)

    def _pending_order(self, plane, ids: np.ndarray) -> list:
        """Priority desc, then longest-unserved first, then id — a
        deterministic total order."""
        pr = plane.priority[ids]
        waited = plane.unserved_since[ids]
        order = np.lexsort((ids, waited, -pr))
        return [int(i) for i in ids[order]]

    def _admit(self, plane, now: float) -> None:
        ids = np.nonzero(plane.active & ~plane.admitted)[0]
        if ids.size == 0 or not plane.replicas:
            return
        for i in self._pending_order(plane, ids):
            aged = (now - plane.unserved_since[i]
                    >= self.classes[plane.cls[i]].aging_s)
            self._place(plane, i, now, force=bool(aged))

    def _preempt(self, plane, now: float, dt: float) -> int:
        preempted = 0
        for r in plane.replicas:
            cap = r.capacity_tps(plane.scn.slots_per_replica)
            overloaded = (plane.replica_util.get(r.id, 0.0)
                          >= self.preempt_util
                          and plane.replica_backlog_growth.get(r.id, 0.0)
                          > 1e-9)
            streak = self._streak.get(r.id, 0) + 1 if overloaded else 0
            self._streak[r.id] = streak
            if streak < self.patience_ticks:
                continue
            target = cap * self.drain_to
            ids = np.nonzero(plane.admitted & (plane.replica_of == r.id))[0]
            # victims: lowest priority first, newest-admitted first;
            # skip force-admitted tenants placed within the last tick
            # (the no-starvation shield)
            order = np.lexsort((-ids, -plane.last_admit_t[ids],
                                plane.priority[ids]))
            for i in ids[order]:
                if plane.committed_rate(r.id) <= target:
                    break
                if now - plane.last_admit_t[i] < 1.5 * dt \
                        and plane.forced_admit[i]:
                    continue
                plane.preempt(int(i), now, reason=f"overload r{r.id}")
                preempted += 1
            self._streak[r.id] = 0
        return preempted
