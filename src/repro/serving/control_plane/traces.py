"""Seeded trace layer for the control plane (DESIGN.md §14.1).

Everything the simulator "experiences" — who the tenants are, when they
join and leave, how many tokens they offer each tick, when the job
manager shocks the shared budget — is generated here from ONE seed, so
a scenario replays byte-identically: the control plane draws from a
single ``numpy`` :class:`~numpy.random.Generator` in a fixed order (one
vectorized draw per tick over the FULL tenant population, active or
not, so churn never shifts the stream).

Three arrival processes cover the paper's shifting-resource regimes:

* :class:`PoissonArrivals` — stationary load (the null workload);
* :class:`DiurnalArrivals` — a sinusoidally modulated Poisson process
  with per-tenant phases (the classic day/night swing the autoscaler
  must track);
* :class:`MMPPArrivals` — a two-state Markov-modulated Poisson process
  (bursty on/off sources; the admission controller's queue caps and the
  preemption path earn their keep here).

The replayable :class:`TraceEvent` stream (tenant churn + budget
shocks) is scheduled on the :class:`~repro.serving.simulator.VirtualClock`
event heap; the scenario catalog at the bottom names the reference
experiments (``launch/simulate.py --list``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = [
    "TraceEvent", "TenantPopulation", "Scenario", "ArrivalModel",
    "PoissonArrivals", "DiurnalArrivals", "MMPPArrivals",
    "build_population", "trace_events", "make_arrival_model",
    "SCENARIOS", "get_scenario",
]

GIB = 2**30


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One replayable control-plane stimulus.

    ``kind``: ``"join"``/``"leave"`` (tenant churn, ``tenant`` set) or
    ``"budget"`` (global budget shock, ``value`` = multiple of the
    scenario's initial budget)."""
    t: float
    kind: str
    tenant: int = -1
    value: float = 0.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, fully-parameterized control-plane experiment. Immutable
    and hashable so a report can state exactly what produced it."""
    name: str
    seed: int = 0
    arch: str = "mixtral-8x7b"
    tenants: int = 64
    horizon_s: float = 4000.0
    tick_s: float = 20.0
    #: arrival process: "poisson" | "diurnal" | "bursty"
    arrival: str = "poisson"
    #: per-tenant mean offered load, drawn uniform from this range (tok/s)
    rate_range_tps: Tuple[float, float] = (0.3, 1.3)
    #: (SLO class name, fraction) — fractions should sum to 1
    class_mix: Tuple[Tuple[str, float], ...] = (
        ("gold", 0.2), ("silver", 0.3), ("bronze", 0.5))
    #: fraction of tenants that churn (half join late, half leave early)
    churn_fraction: float = 0.0
    #: (time_s, multiple-of-initial-budget) global budget shocks
    budget_shocks: Tuple[Tuple[float, float], ...] = ()
    budget_bytes: float = 400.0 * GIB
    #: decode slots per engine replica: replica capacity =
    #: point.tokens_per_s * slots (DESIGN.md §14.3)
    slots_per_replica: int = 16
    min_replicas: int = 2
    max_replicas: int = 8
    # diurnal knobs
    diurnal_period_s: float = 20000.0
    diurnal_amplitude: float = 0.7
    # MMPP knobs (per-tick state transition probabilities)
    burst_factor: float = 6.0
    p_on: float = 0.04
    p_off: float = 0.25
    # policy knobs (DESIGN.md §14.4)
    floor_weight: float = 1000.0
    admit_headroom: float = 0.90
    preempt_util: float = 0.999
    preempt_patience_ticks: int = 3
    preempt_drain_to: float = 0.85
    util_band: Tuple[float, float] = (0.40, 0.85)
    scale_patience_ticks: int = 3
    scale_cooldown_s: float = 120.0
    #: --smoke horizon (None: horizon_s / 10)
    smoke_horizon_s: Optional[float] = None
    #: reference-scenario acceptance ceiling on
    #: violation_s / active_tenant_s (asserted in CI)
    violation_ceiling: float = 0.15
    #: control-action event log cap in the report (dropped count kept)
    max_recorded_events: int = 512

    def smoke(self) -> "Scenario":
        h = self.smoke_horizon_s or max(self.horizon_s / 10, 10 * self.tick_s)
        return dataclasses.replace(
            self, name=f"{self.name}-smoke", horizon_s=h,
            budget_shocks=tuple((t, v) for t, v in self.budget_shocks
                                if t < h))


@dataclasses.dataclass(frozen=True)
class TenantPopulation:
    """Per-tenant static attributes, all drawn from the scenario seed."""
    join_t: np.ndarray        # float[n]; <= 0 means present from the start
    leave_t: np.ndarray       # float[n]; inf means never leaves
    base_rate: np.ndarray     # float[n] mean offered tokens/s
    cls: np.ndarray           # int[n] index into the SLO class table
    phase: np.ndarray         # float[n] diurnal phase offset (radians)

    @property
    def n(self) -> int:
        return self.join_t.shape[0]


def build_population(scn: Scenario, num_classes: int,
                     rng: np.random.Generator) -> TenantPopulation:
    """Draw the tenant population (rates, classes, churn times, phases)
    in a FIXED draw order — the first consumer of the scenario stream."""
    n = scn.tenants
    lo, hi = scn.rate_range_tps
    base_rate = rng.uniform(lo, hi, n)
    # class assignment: exact proportions, then a seeded permutation so
    # class membership is uncorrelated with tenant id
    counts = [int(round(f * n)) for _, f in scn.class_mix]
    while sum(counts) > n:
        counts[int(np.argmax(counts))] -= 1
    while sum(counts) < n:
        counts[int(np.argmin(counts))] += 1
    cls = np.repeat(np.arange(len(scn.class_mix)), counts)
    cls = rng.permutation(cls).astype(np.int64)
    if cls.max(initial=0) >= num_classes:
        raise ValueError(f"scenario {scn.name!r} names more classes than "
                         f"the control plane registered ({num_classes})")
    phase = rng.uniform(0.0, 2.0 * math.pi, n)
    join_t = np.zeros(n)
    leave_t = np.full(n, math.inf)
    k = int(round(scn.churn_fraction * n))
    if k:
        churners = rng.choice(n, size=k, replace=False)
        late = churners[: k // 2]
        early = churners[k // 2:]
        join_t[late] = rng.uniform(0.0, 0.5 * scn.horizon_s, late.size)
        leave_t[early] = rng.uniform(0.5 * scn.horizon_s,
                                     scn.horizon_s, early.size)
    return TenantPopulation(join_t=join_t, leave_t=leave_t,
                            base_rate=base_rate, cls=cls, phase=phase)


def trace_events(pop: TenantPopulation, scn: Scenario) -> list:
    """The replayable stimulus stream, time-ascending (ties: joins
    before leaves before budget shocks, then tenant id)."""
    evs = []
    for i in np.nonzero(pop.join_t > 0)[0]:
        evs.append(TraceEvent(float(pop.join_t[i]), "join", int(i)))
    for i in np.nonzero(np.isfinite(pop.leave_t))[0]:
        evs.append(TraceEvent(float(pop.leave_t[i]), "leave", int(i)))
    for t, frac in scn.budget_shocks:
        evs.append(TraceEvent(float(t), "budget", value=float(frac)))
    order = {"join": 0, "leave": 1, "budget": 2}
    evs.sort(key=lambda e: (e.t, order[e.kind], e.tenant))
    return evs


class ArrivalModel:
    """Vectorized seeded arrival process. ``counts`` draws the offered
    token counts for EVERY tenant each tick (inactive tenants get rate
    0 but still occupy the same position in the stream, so replay is
    churn-independent); ``mean_rate`` is the deterministic modulated
    mean the autoscaler smooths on (no sampling noise)."""

    def reset(self, n: int, rng: np.random.Generator) -> None:
        pass

    def mean_rate(self, t: float, base_rate: np.ndarray) -> np.ndarray:
        return base_rate

    def counts(self, t: float, dt: float, base_rate: np.ndarray,
               active: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        # draw at FULL rate for every tenant and only then mask: poisson
        # consumes a lambda-dependent number of underlying draws per
        # element, so zeroing lambdas (rather than results) would shift
        # the stream whenever the active set changes
        lam = self.mean_rate(t, base_rate) * dt
        draws = rng.poisson(lam).astype(np.float64)
        return np.where(active, draws, 0.0)


class PoissonArrivals(ArrivalModel):
    """Stationary Poisson arrivals at each tenant's base rate."""


class DiurnalArrivals(ArrivalModel):
    """Sinusoidally modulated Poisson: ``rate(t) = base * (1 + A *
    sin(2π t / period + phase))``, phases per tenant (a population whose
    peaks partially align — the aggregate still swings by ~A)."""

    def __init__(self, period_s: float, amplitude: float,
                 phase: np.ndarray):
        if not 0.0 <= amplitude < 1.0:
            raise ValueError(f"amplitude must be in [0, 1): {amplitude}")
        self.period_s = float(period_s)
        self.amplitude = float(amplitude)
        # concentrate phases so the population swings together (pure
        # per-tenant uniform phases would cancel in aggregate): keep a
        # third of each tenant's drawn phase
        self.phase = phase / 3.0

    def mean_rate(self, t: float, base_rate: np.ndarray) -> np.ndarray:
        mod = 1.0 + self.amplitude * np.sin(
            2.0 * math.pi * t / self.period_s + self.phase)
        return base_rate * mod


class MMPPArrivals(ArrivalModel):
    """Two-state Markov-modulated Poisson process per tenant: in the ON
    state the rate is ``burst_factor`` × base; state transitions are
    drawn per tick with probabilities ``p_on`` / ``p_off``."""

    def __init__(self, burst_factor: float, p_on: float, p_off: float):
        self.burst_factor = float(burst_factor)
        self.p_on = float(p_on)
        self.p_off = float(p_off)
        self.state: Optional[np.ndarray] = None

    def reset(self, n: int, rng: np.random.Generator) -> None:
        # start at the stationary distribution, seeded
        p_stat = self.p_on / max(self.p_on + self.p_off, 1e-12)
        self.state = rng.random(n) < p_stat

    def mean_rate(self, t: float, base_rate: np.ndarray) -> np.ndarray:
        if self.state is None:
            raise RuntimeError("MMPPArrivals.reset() not called")
        factor = np.where(self.state, self.burst_factor, 1.0)
        return base_rate * factor

    def counts(self, t: float, dt: float, base_rate: np.ndarray,
               active: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        # transition FIRST (one vectorized uniform draw per tick, fixed
        # stream position), then sample arrivals at the new state's rate
        u = rng.random(base_rate.shape[0])
        self.state = np.where(self.state, u >= self.p_off, u < self.p_on)
        return super().counts(t, dt, base_rate, active, rng)


def make_arrival_model(scn: Scenario, pop: TenantPopulation) -> ArrivalModel:
    if scn.arrival == "poisson":
        return PoissonArrivals()
    if scn.arrival == "diurnal":
        return DiurnalArrivals(scn.diurnal_period_s, scn.diurnal_amplitude,
                               pop.phase)
    if scn.arrival == "bursty":
        return MMPPArrivals(scn.burst_factor, scn.p_on, scn.p_off)
    raise ValueError(f"unknown arrival process {scn.arrival!r} "
                     f"(poisson|diurnal|bursty)")


#: The scenario catalog (DESIGN.md §14.6). ``diurnal-1k`` is the CI
#: reference: 1000 tenants over >= 100k virtual seconds with churn, a
#: mid-run budget crunch (forces preemption) and a diurnal swing (forces
#: autoscaling), asserted deterministic and under its violation ceiling.
SCENARIOS: Dict[str, Scenario] = {s.name: s for s in [
    Scenario(
        name="steady-64",
        tenants=64, horizon_s=4000.0, tick_s=20.0, arrival="poisson",
        rate_range_tps=(0.3, 1.3), budget_bytes=400.0 * GIB,
        slots_per_replica=16, min_replicas=2, max_replicas=4,
    ),
    Scenario(
        name="golden-32",
        tenants=32, horizon_s=1500.0, tick_s=25.0, arrival="poisson",
        rate_range_tps=(0.4, 1.6), churn_fraction=0.25,
        budget_shocks=((600.0, 0.08), (1050.0, 1.0)),
        budget_bytes=120.0 * GIB, slots_per_replica=4,
        min_replicas=2, max_replicas=4, scale_cooldown_s=100.0,
        violation_ceiling=0.35,
    ),
    Scenario(
        name="bursty-256",
        tenants=256, horizon_s=20000.0, tick_s=20.0, arrival="bursty",
        rate_range_tps=(0.1, 0.6), churn_fraction=0.1,
        burst_factor=6.0, p_on=0.04, p_off=0.25,
        budget_bytes=400.0 * GIB, slots_per_replica=16,
        min_replicas=2, max_replicas=8,
        violation_ceiling=0.30,
    ),
    Scenario(
        name="diurnal-1k",
        tenants=1000, horizon_s=100_000.0, tick_s=25.0, arrival="diurnal",
        rate_range_tps=(0.3, 1.3), churn_fraction=0.2,
        diurnal_period_s=20000.0, diurnal_amplitude=0.7,
        budget_shocks=((30_000.0, 0.10), (60_000.0, 1.0)),
        budget_bytes=360.0 * GIB, slots_per_replica=24,
        min_replicas=2, max_replicas=8,
        smoke_horizon_s=20_000.0,
        violation_ceiling=0.15,
    ),
]}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; catalog: "
                       f"{', '.join(sorted(SCENARIOS))}") from None
