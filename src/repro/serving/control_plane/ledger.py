"""Per-tenant SLO accounting ledger (DESIGN.md §14.5).

Everything the control plane is judged on accrues here, vectorized over
the tenant population each tick:

* **violation seconds** — a tenant is in violation for a tick when it is
  active, has demand, and its achieved service rate falls short of
  ``min(class floor, demand rate)`` (a gold tenant offering 0.5 tok/s is
  not "violated" up to its 4 tok/s floor — only up to what it asked);
* **latency percentiles** — the per-tick queueing-delay proxy
  ``backlog / service_rate`` is accumulated into a per-tenant
  log-spaced histogram; p95/p99 are read from bin upper edges, so the
  report needs O(bins) memory per tenant instead of every sample, stays
  byte-deterministic, and still resolves sub-second to hour-scale waits;
* **goodput** — served tokens over active seconds;
* **preemption count / max unserved span** — the no-starvation
  evidence: the longest continuous stretch any tenant spent active but
  unserved (pending or preempted);
* **replan downtime** — seconds of replica unavailability attributed to
  each tenant hosted on a repointing replica (§10.3 ReplanReports).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

__all__ = ["SLOLedger", "LATENCY_BIN_EDGES_S"]

#: log-spaced latency histogram bin upper edges (seconds): 10 ms .. 2 h.
LATENCY_BIN_EDGES_S = np.geomspace(1e-2, 7200.0, 48)


class SLOLedger:
    def __init__(self, n: int):
        self.n = n
        self.arrived = np.zeros(n)
        self.served = np.zeros(n)
        self.dropped = np.zeros(n)
        self.violation_s = np.zeros(n)
        self.active_s = np.zeros(n)
        self.admitted_s = np.zeros(n)
        self.downtime_s = np.zeros(n)
        self.preemptions = np.zeros(n, dtype=np.int64)
        self.max_unserved_span_s = np.zeros(n)
        # one overflow bin past the last edge
        self.lat_hist = np.zeros((n, LATENCY_BIN_EDGES_S.size + 1),
                                 dtype=np.int64)

    # -- per-tick accrual ---------------------------------------------------
    def record_tick(self, dt: float, active: np.ndarray,
                    admitted: np.ndarray, demand_rate: np.ndarray,
                    served_rate: np.ndarray, floor: np.ndarray,
                    backlog: np.ndarray) -> None:
        self.active_s[active] += dt
        self.admitted_s[active & admitted] += dt
        required = np.minimum(floor, demand_rate)
        viol = active & (required > 1e-12) \
            & (served_rate < required * (1.0 - 1e-9))
        self.violation_s[viol] += dt
        has_demand = active & ((demand_rate > 1e-12) | (backlog > 1e-9))
        if has_demand.any():
            lat = backlog[has_demand] / np.maximum(served_rate[has_demand],
                                                   1e-9)
            idx = np.searchsorted(LATENCY_BIN_EDGES_S,
                                  np.minimum(lat, 7200.0))
            np.add.at(self.lat_hist, (np.nonzero(has_demand)[0], idx), 1)

    def note_unserved_span(self, ids, span_s: float | np.ndarray) -> None:
        np.maximum.at(self.max_unserved_span_s, ids, span_s)

    def charge_downtime(self, mask: np.ndarray, seconds: float) -> None:
        self.downtime_s[mask] += seconds

    # -- readouts -----------------------------------------------------------
    def percentile(self, q: float, hist: np.ndarray = None) -> np.ndarray:
        """Per-row latency percentile (seconds) from the histogram(s):
        the upper edge of the first bin reaching the q-quantile of the
        row's samples; rows without samples read 0."""
        h = self.lat_hist if hist is None else hist
        h = np.atleast_2d(h)
        total = h.sum(axis=1)
        cum = np.cumsum(h, axis=1)
        # overflow bin reports the top edge
        edges = np.append(LATENCY_BIN_EDGES_S, LATENCY_BIN_EDGES_S[-1])
        idx = np.argmax(cum >= np.ceil(q * total)[:, None], axis=1)
        out = edges[idx]
        out[total == 0] = 0.0
        return out

    def goodput_tps(self) -> np.ndarray:
        return self.served / np.maximum(self.active_s, 1e-9)

    def class_rollup(self, cls: np.ndarray, names: Sequence[str]
                     ) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for c, name in enumerate(names):
            m = cls == c
            hist = self.lat_hist[m].sum(axis=0, keepdims=True)
            out[name] = {
                "tenants": int(m.sum()),
                "arrived_tokens": float(self.arrived[m].sum()),
                "served_tokens": float(self.served[m].sum()),
                "dropped_tokens": float(self.dropped[m].sum()),
                "violation_s": float(self.violation_s[m].sum()),
                "violation_rate": float(
                    self.violation_s[m].sum()
                    / max(self.active_s[m].sum(), 1e-9)),
                "p95_latency_s": float(self.percentile(0.95, hist)[0]),
                "p99_latency_s": float(self.percentile(0.99, hist)[0]),
                "goodput_tps": float(
                    self.served[m].sum()
                    / max(self.active_s[m].sum(), 1e-9)),
                "preemptions": int(self.preemptions[m].sum()),
                "downtime_s": float(self.downtime_s[m].sum()),
                "max_unserved_span_s": float(
                    self.max_unserved_span_s[m].max(initial=0.0)),
            }
        return out

    def tenant_rows(self, cls: np.ndarray) -> List[list]:
        """Compact per-tenant table: [id, class, violation_s, p95_s,
        p99_s, goodput_tps, preemptions, downtime_s, served, dropped]."""
        p95 = self.percentile(0.95)
        p99 = self.percentile(0.99)
        good = self.goodput_tps()
        return [[i, int(cls[i]),
                 round(float(self.violation_s[i]), 6),
                 round(float(p95[i]), 6), round(float(p99[i]), 6),
                 round(float(good[i]), 6), int(self.preemptions[i]),
                 round(float(self.downtime_s[i]), 6),
                 round(float(self.served[i]), 6),
                 round(float(self.dropped[i]), 6)]
                for i in range(self.n)]
