"""Trace-driven control plane over the deterministic simulator
(DESIGN.md §14): seeded traces, SLO-class admission with priority
preemption, replica autoscaling through the PR 3 arbiter, and a
per-tenant SLO ledger. The simulation itself is pure numpy (no jax
compute — jax only rides along through the serving imports)."""
from .admission import AdmissionController, DEFAULT_SLO_CLASSES, SLOClass
from .autoscale import ReplicaAutoscaler
from .ledger import LATENCY_BIN_EDGES_S, SLOLedger
from .plane import ControlPlane, Replica, run_scenario
from .traces import (ArrivalModel, DiurnalArrivals, MMPPArrivals,
                     PoissonArrivals, SCENARIOS, Scenario, TenantPopulation,
                     TraceEvent, build_population, get_scenario,
                     make_arrival_model, trace_events)

__all__ = [
    "AdmissionController", "DEFAULT_SLO_CLASSES", "SLOClass",
    "ReplicaAutoscaler", "LATENCY_BIN_EDGES_S", "SLOLedger",
    "ControlPlane", "Replica", "run_scenario",
    "ArrivalModel", "PoissonArrivals", "DiurnalArrivals", "MMPPArrivals",
    "Scenario", "TenantPopulation", "TraceEvent", "SCENARIOS",
    "build_population", "get_scenario", "make_arrival_model",
    "trace_events",
]
