"""Engine-replica autoscaler with hysteresis (DESIGN.md §14.3).

The autoscaler watches DEMAND utilization — the deterministic modulated
offered load of every active tenant (admitted or pending) over the
fleet's aggregate capacity — rather than the sampled served/capacity
ratio, so Poisson noise cannot flap it. Decisions carry three guards:

* **patience** — the band must be breached for ``patience_ticks``
  consecutive ticks before any action;
* **cooldown** — at least ``cooldown_s`` of virtual time between
  actions (a scale-up's capacity change must be observed before the
  next decision);
* **projection** — scale-down only when the post-removal utilization
  ``util * R / (R - 1)`` would still sit below the high-water mark with
  margin, so an up move can never be immediately forced back.

The plane enforces the budget feasibility side (a replica is only
added when one more cheapest-point footprint fits the global budget).
"""
from __future__ import annotations

import math
from typing import Tuple

__all__ = ["ReplicaAutoscaler"]


class ReplicaAutoscaler:
    def __init__(self, *, band: Tuple[float, float] = (0.40, 0.85),
                 patience_ticks: int = 3, cooldown_s: float = 120.0,
                 min_replicas: int = 1, max_replicas: int = 8,
                 projection_margin: float = 0.95):
        lo, hi = band
        if not 0.0 < lo < hi:
            raise ValueError(f"utilization band must satisfy 0 < lo < hi "
                             f"({band})")
        self.lo, self.hi = float(lo), float(hi)
        self.patience_ticks = patience_ticks
        self.cooldown_s = cooldown_s
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.projection_margin = projection_margin
        self._above = 0
        self._below = 0
        self._last_action_t = -math.inf

    def step(self, now: float, demand_util: float, n_replicas: int, *,
             can_add: bool = True, can_remove: bool = True) -> int:
        """One decision: +1 (scale up), -1 (scale down) or 0 (hold)."""
        self._above = self._above + 1 if demand_util > self.hi else 0
        self._below = self._below + 1 if demand_util < self.lo else 0
        if now - self._last_action_t < self.cooldown_s:
            return 0
        if (self._above >= self.patience_ticks
                and n_replicas < self.max_replicas and can_add):
            self._record(now)
            return 1
        if (self._below >= self.patience_ticks
                and n_replicas > self.min_replicas and can_remove):
            projected = demand_util * n_replicas / max(n_replicas - 1, 1)
            if projected < self.hi * self.projection_margin:
                self._record(now)
                return -1
        return 0

    def _record(self, now: float) -> None:
        self._last_action_t = now
        self._above = 0
        self._below = 0
