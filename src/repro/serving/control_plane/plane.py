"""The trace-driven control plane (DESIGN.md §14).

Ties the seeded trace layer, the admission controller, the replica
autoscaler and the SLO ledger into one deterministic event loop over
the :class:`~repro.serving.simulator.VirtualClock` heap:

* **tenants** are fluid flows (offered tokens/s with a backlog queue),
  vectorized in numpy arrays — a thousand tenants cost a handful of
  array ops per tick, which is what lets 1000 tenants x 100k virtual
  seconds replay in seconds of wall-clock;
* **replicas** are :class:`~repro.serving.simulator.SimulatedEngine`
  instances (replica capacity = frontier-point tokens/s x decode
  slots); each serves its admitted tenants by weighted-fair sharing
  (class weights — work-conserving, so no admitted tenant starves);
* **the arbiter** is the PR 3 :class:`~repro.serving.multi.ResourceArbiter`
  verbatim: each replica is an arbitration entry whose QoS floor is its
  committed + share-of-pending demand, water-filled under the global
  HBM budget. A re-arbitration runs on exactly four triggers — start,
  budget shock, scale event, preemption drain — and every replica
  point change diffs the old/new precision plans into a §10.3
  :class:`~repro.serving.multi.ReplanReport` whose downtime is charged
  to the hosted tenants.

Same seed => byte-identical report (:meth:`ControlPlane.report_bytes`):
all randomness flows through one seeded generator in fixed draw order,
virtual time never touches the wall clock, and every iteration order is
total. ``tests/test_control_plane.py`` pins determinism, no-starvation,
autoscaler hysteresis and the one-arbitration-per-shock invariant.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs import get_config
from repro.core.pareto import FrontierPoint, ParetoFrontier, QoSTarget
from repro.core.precision_plan import migrated_expert_keys, reconfig_delta
from repro.serving.multi import (GlobalBudgetInfeasible, ReplanReport,
                                 ResourceArbiter, TenantSpec)
from repro.serving.simulator import SimulatedEngine, VirtualClock

from .admission import (AdmissionController, DEFAULT_SLO_CLASSES, SLOClass)
from .autoscale import ReplicaAutoscaler
from .ledger import SLOLedger
from .traces import (GIB, Scenario, TraceEvent, build_population,
                     make_arrival_model, trace_events)

__all__ = ["ControlPlane", "Replica", "run_scenario"]


def _r6(x) -> float:
    return round(float(x), 6)


class Replica:
    """One autoscaled engine replica: a SimulatedEngine plus the
    control-plane bookkeeping around it."""

    __slots__ = ("id", "engine", "point", "created_s", "retired_s",
                 "down_until", "replans", "downtime_s", "served_tokens",
                 "prev_backlog")

    def __init__(self, rid: int, engine: SimulatedEngine, created_s: float):
        self.id = rid
        self.engine = engine
        self.point: Optional[FrontierPoint] = None
        self.created_s = created_s
        self.retired_s: Optional[float] = None
        self.down_until = 0.0
        self.replans = 0
        self.downtime_s = 0.0
        self.served_tokens = 0.0
        self.prev_backlog = 0.0

    def capacity_tps(self, slots: int) -> float:
        return 0.0 if self.point is None \
            else self.point.qos.tokens_per_s * slots


def _weighted_fair(queue: np.ndarray, weight: np.ndarray,
                   cap_tokens: float, rounds: int = 4) -> np.ndarray:
    """Work-conserving weighted-fair allocation of ``cap_tokens`` over
    backlogs: iterative filling — every tenant with backlog gets at
    least its weight share per round, surplus from short queues is
    redistributed. Deterministic and O(rounds * n)."""
    served = np.zeros_like(queue)
    rem = queue.copy()
    cap = float(cap_tokens)
    for _ in range(rounds):
        m = rem > 1e-9
        if cap <= 1e-9 or not m.any():
            break
        w = np.where(m, weight, 0.0)
        share = cap * w / w.sum()
        s = np.minimum(rem, share)
        served += s
        rem -= s
        cap -= float(s.sum())
    return served


class ControlPlane:
    """Single-shot deterministic run of one :class:`Scenario`."""

    def __init__(self, scenario: Scenario, *,
                 classes: Sequence[SLOClass] = DEFAULT_SLO_CLASSES,
                 frontier: Optional[ParetoFrontier] = None):
        self.scn = scenario
        self.classes = tuple(classes)
        self.cfg = get_config(scenario.arch)
        self.frontier = frontier if frontier is not None \
            else ParetoFrontier(self.cfg)
        self.cheapest_bytes = min(p.qos.device_bytes
                                  for p in self.frontier.points)
        self.rng = np.random.default_rng(scenario.seed)
        self.pop = build_population(scenario, len(self.classes), self.rng)
        self.arrivals = make_arrival_model(scenario, self.pop)
        self.arrivals.reset(self.pop.n, self.rng)
        self.clock = VirtualClock()
        self._trace: List[TraceEvent] = trace_events(self.pop, scenario)
        self.arbiter = ResourceArbiter(scenario.floor_weight)
        self.admission = AdmissionController(
            self.classes, admit_headroom=scenario.admit_headroom,
            preempt_util=scenario.preempt_util,
            patience_ticks=scenario.preempt_patience_ticks,
            drain_to=scenario.preempt_drain_to)
        self.autoscaler = ReplicaAutoscaler(
            band=scenario.util_band,
            patience_ticks=scenario.scale_patience_ticks,
            cooldown_s=scenario.scale_cooldown_s,
            min_replicas=scenario.min_replicas,
            max_replicas=scenario.max_replicas)
        n = self.pop.n
        self.base_rate = self.pop.base_rate
        self.cls = self.pop.cls
        self.priority = np.array([self.classes[c].priority
                                  for c in self.cls], dtype=np.int64)
        self.weight = np.array([self.classes[c].weight for c in self.cls])
        self.floor = np.array([self.classes[c].min_tokens_per_s
                               for c in self.cls])
        self.queue_cap = np.array([self.classes[c].queue_cap_tokens
                                   for c in self.cls])
        self.active = np.zeros(n, dtype=bool)
        self.admitted = np.zeros(n, dtype=bool)
        self.replica_of = np.full(n, -1, dtype=np.int64)
        self.queue = np.zeros(n)
        #: when the tenant last became active-but-unserved (inf while
        #: served or inactive) — the aging / no-starvation clock
        self.unserved_since = np.full(n, math.inf)
        self.last_admit_t = np.full(n, -math.inf)
        self.forced_admit = np.zeros(n, dtype=bool)
        self.ledger = SLOLedger(n)
        self.replicas: List[Replica] = []
        self._retired: List[Replica] = []
        self._next_rid = 0
        self._committed: Dict[int, float] = {}
        self.replica_util: Dict[int, float] = {}
        self.replica_backlog_growth: Dict[int, float] = {}
        self._budget0 = float(scenario.budget_bytes)
        self.budget_bytes = self._budget0
        self.used_bytes = 0.0
        self.reports: List[ReplanReport] = []
        self.events: List[dict] = []
        self.metrics: Dict[str, float] = {
            "arbitrations": 0, "replans": 0, "migrated_bytes": 0,
            "scale_ups": 0, "scale_downs": 0, "preemptions": 0,
            "forced_admissions": 0, "events_dropped": 0,
            "replicas_peak": 0,
        }
        self._ran = False

    # -- tenant lifecycle (the admission controller's plane contract) -------
    def committed_rate(self, rid: int) -> float:
        return self._committed.get(rid, 0.0)

    def admit(self, i: int, rid: int, now: float, forced: bool = False):
        if math.isfinite(self.unserved_since[i]):
            self.ledger.note_unserved_span(
                i, now - self.unserved_since[i])
        self.admitted[i] = True
        self.replica_of[i] = rid
        self._committed[rid] = self._committed.get(rid, 0.0) \
            + float(self.base_rate[i])
        self.last_admit_t[i] = now
        self.forced_admit[i] = forced
        self.unserved_since[i] = math.inf
        if forced:
            self.metrics["forced_admissions"] += 1

    def preempt(self, i: int, now: float, reason: str = ""):
        self._unassign(i)
        self.ledger.preemptions[i] += 1
        self.metrics["preemptions"] += 1
        self.unserved_since[i] = now

    def _unassign(self, i: int):
        rid = int(self.replica_of[i])
        if rid >= 0:
            self._committed[rid] -= float(self.base_rate[i])
        self.admitted[i] = False
        self.replica_of[i] = -1
        self.forced_admit[i] = False

    def _join(self, i: int, now: float):
        self.active[i] = True
        self.unserved_since[i] = now

    def _leave(self, i: int, now: float):
        if self.admitted[i]:
            self._unassign(i)
        elif math.isfinite(self.unserved_since[i]):
            self.ledger.note_unserved_span(i, now - self.unserved_since[i])
        self.active[i] = False
        # abandoned backlog is accounted as dropped, closing the
        # arrived == served + dropped + backlog balance
        self.ledger.dropped[i] += self.queue[i]
        self.queue[i] = 0.0
        self.unserved_since[i] = math.inf

    # -- replicas / arbitration ---------------------------------------------
    def _can_add_replica(self) -> bool:
        return (len(self.replicas) + 1) * self.cheapest_bytes \
            <= self.budget_bytes

    def _add_replica(self, now: float) -> Replica:
        slots = self.scn.slots_per_replica
        eng = SimulatedEngine(
            throughput_fn=lambda p, it, s=slots: p.qos.tokens_per_s * s)
        r = Replica(self._next_rid, eng, now)
        self._next_rid += 1
        self.replicas.append(r)
        self._committed[r.id] = 0.0
        self.metrics["replicas_peak"] = max(self.metrics["replicas_peak"],
                                            len(self.replicas))
        return r

    def _pick_retire(self) -> Replica:
        return min(self.replicas,
                   key=lambda r: (self._committed[r.id], -r.id))

    def _retire_replica(self, r: Replica, now: float, reason: str):
        self.replicas.remove(r)
        r.retired_s = now
        self._retired.append(r)
        ids = np.nonzero(self.admitted & (self.replica_of == r.id))[0]
        order = np.lexsort((ids, -self.priority[ids]))
        for i in ids[order]:
            i = int(i)
            self._unassign(i)
            # immediate best-effort re-placement; the rest go pending
            if not self.admission._place(self, i, now, force=False):
                self.unserved_since[i] = now
        self._committed.pop(r.id, None)
        self.replica_util.pop(r.id, None)
        self.replica_backlog_growth.pop(r.id, None)

    def _rebalance_to_new(self, now: float):
        """After a scale-up, move low-priority committed load from the
        fullest replicas onto the (empty) newest one until it reaches
        the fleet mean."""
        new = self.replicas[-1]
        mean = sum(self._committed.values()) / len(self.replicas)
        donors = sorted(self.replicas[:-1],
                        key=lambda r: (-self._committed[r.id], r.id))
        for r in donors:
            ids = np.nonzero(self.admitted & (self.replica_of == r.id))[0]
            order = np.lexsort((-ids, self.priority[ids]))
            for i in ids[order]:
                if self._committed[new.id] >= mean \
                        or self._committed[r.id] <= mean:
                    break
                i = int(i)
                self._unassign(i)
                self.admit(i, new.id, now)

    def _arbitrate(self, now: float, reason: str):
        slots = self.scn.slots_per_replica
        pending = float(self.base_rate[self.active & ~self.admitted].sum())
        share = pending / max(len(self.replicas), 1)
        entries = []
        for r in self.replicas:
            req_total = self._committed[r.id] + share
            req_stream = req_total / slots
            tgt = QoSTarget(min_tokens_per_s=req_stream
                            if req_stream > 1e-9 else None)
            entries.append((TenantSpec(f"r{r.id}", tgt,
                                       weight=max(req_total, 1e-3)),
                            self.frontier, 1.0))
        sel, used = self.arbiter.arbitrate(entries, self.budget_bytes)
        self.used_bytes = used
        for r in self.replicas:
            p = sel[f"r{r.id}"]
            if p is not r.point:
                self._repoint(r, p, now)
        self.metrics["arbitrations"] += 1
        self._record_event(now, "arbitrate",
                           f"{reason} R={len(self.replicas)} "
                           f"used={used / GIB:.2f}GiB")

    def _repoint(self, r: Replica, point: FrontierPoint, now: float):
        """Apply a new frontier point to a replica through the partial-
        reconfiguration diff path (DESIGN.md §10.3): only changed
        experts migrate, the transfer downtime stalls the replica and is
        charged to its hosted tenants."""
        old = r.point
        r.engine.apply_frontier_point(point)
        r.point = point
        if old is None:
            return
        delta = reconfig_delta(old.plan, point.plan)
        keys = migrated_expert_keys(delta, point.plan)
        mbytes = sum(self.cfg.expert_param_bytes(int(point.plan.bits[l, e]))
                     for (l, e) in keys)
        downtime = mbytes / self.frontier.hw.host_link_bw
        placement_only = (old.plan.bank_sizes() == point.plan.bank_sizes()
                          and old.plan.seed == point.plan.seed)
        rep = ReplanReport(
            tenant=f"replica-{r.id}", migrated_experts=len(keys),
            evicted_experts=len(delta["to_evict"]),
            migrated_bytes=int(mbytes), downtime_s=downtime,
            placement_only=placement_only)
        self.reports.append(rep)
        r.replans += 1
        r.downtime_s += downtime
        r.down_until = max(r.down_until, now + downtime)
        self.metrics["replans"] += 1
        self.metrics["migrated_bytes"] += rep.migrated_bytes
        self.ledger.charge_downtime(
            self.admitted & (self.replica_of == r.id), downtime)

    # -- events --------------------------------------------------------------
    def _record_event(self, t: float, kind: str, detail: str):
        if len(self.events) < self.scn.max_recorded_events:
            self.events.append({"t": round(float(t), 3), "kind": kind,
                                "detail": detail})
        else:
            self.metrics["events_dropped"] += 1

    def _apply_trace_event(self, ev: TraceEvent, now: float):
        if ev.kind == "join":
            self._join(ev.tenant, now)
        elif ev.kind == "leave":
            self._leave(ev.tenant, now)
        elif ev.kind == "budget":
            self.budget_bytes = ev.value * self._budget0
            self._record_event(now, "budget",
                               f"x{ev.value:g} -> "
                               f"{self.budget_bytes / GIB:.2f}GiB")
            # forced retirement keeps the joint footprint feasible —
            # a deep shock may shrink the fleet below min_replicas
            # (feasibility beats the autoscaler floor); the shock
            # itself re-arbitrates exactly once
            while len(self.replicas) > 1 \
                    and len(self.replicas) * self.cheapest_bytes \
                    > self.budget_bytes:
                self._retire_replica(self._pick_retire(), now, "budget")
                self.metrics["scale_downs"] += 1
            self._arbitrate(now, "budget-shock")
        else:
            raise ValueError(f"unknown trace event kind {ev.kind!r}")

    # -- the tick ------------------------------------------------------------
    def _tick(self, t0: float, t1: float):
        dt = t1 - t0
        scn = self.scn
        slots = scn.slots_per_replica
        act = self.active
        counts = self.arrivals.counts(t0, dt, self.base_rate, act, self.rng)
        self.ledger.arrived += counts
        self.queue += counts
        over = np.maximum(self.queue - self.queue_cap, 0.0)
        self.queue -= over
        self.ledger.dropped += over
        demand_rate = self.queue / dt
        served = np.zeros(self.pop.n)
        for r in self.replicas:
            cap_tps = r.capacity_tps(slots)
            down = min(max(r.down_until - t0, 0.0), dt)
            mask = self.admitted & (self.replica_of == r.id)
            backlog_before = float(self.queue[mask].sum())
            s = _weighted_fair(self.queue[mask], self.weight[mask],
                               cap_tps * (dt - down))
            served[mask] = s
            r_served = float(s.sum())
            r.engine.run_iteration(batch=r_served)
            r.served_tokens += r_served
            denom = cap_tps * dt
            self.replica_util[r.id] = r_served / denom if denom > 0 else 0.0
            end_backlog = backlog_before - r_served
            self.replica_backlog_growth[r.id] = end_backlog - r.prev_backlog
            r.prev_backlog = end_backlog
        self.queue -= served
        self.ledger.served += served
        self.ledger.record_tick(dt, act, self.admitted, demand_rate,
                                served / dt, self.floor, self.queue)
        # control pass: admission/preemption -> autoscaling
        npre = self.admission.step(self, t1, dt)
        if npre:
            self._record_event(t1, "preempt", f"{npre} tenants drained")
            self._arbitrate(t1, "preempt-drain")
        mean_rate = self.arrivals.mean_rate(t1, self.base_rate)
        demand = float(mean_rate[act].sum())
        cap_total = sum(r.capacity_tps(slots) for r in self.replicas)
        demand_util = demand / max(cap_total, 1e-9)
        delta = self.autoscaler.step(
            t1, demand_util, len(self.replicas),
            can_add=self._can_add_replica(),
            can_remove=len(self.replicas) > scn.min_replicas)
        if delta > 0:
            self._add_replica(t1)
            self._rebalance_to_new(t1)
            self.metrics["scale_ups"] += 1
            self._record_event(t1, "scale-up",
                               f"R={len(self.replicas)} "
                               f"util_d={demand_util:.3f}")
            self._arbitrate(t1, "scale-up")
        elif delta < 0:
            r = self._pick_retire()
            self._retire_replica(r, t1, "scale-down")
            self.metrics["scale_downs"] += 1
            self._record_event(t1, "scale-down",
                               f"R={len(self.replicas)} "
                               f"util_d={demand_util:.3f}")
            self._arbitrate(t1, "scale-down")

    # -- the run -------------------------------------------------------------
    def run(self) -> dict:
        if self._ran:
            raise RuntimeError("ControlPlane.run() is single-shot — build "
                               "a fresh plane to replay the scenario")
        self._ran = True
        scn = self.scn
        if scn.min_replicas * self.cheapest_bytes > self.budget_bytes:
            raise GlobalBudgetInfeasible(
                f"{scn.min_replicas} replicas x cheapest point "
                f"{self.cheapest_bytes / GIB:.2f}GiB exceeds the budget "
                f"{self.budget_bytes / GIB:.2f}GiB")
        for i in np.nonzero(self.pop.join_t <= 0)[0]:
            self._join(int(i), 0.0)
        for ev in self._trace:
            self.clock.schedule_at(ev.t, ev)
        for _ in range(scn.min_replicas):
            self._add_replica(0.0)
        self._arbitrate(0.0, "initial")
        t = 0.0
        while t < scn.horizon_s - 1e-9:
            t1 = min(t + scn.tick_s, scn.horizon_s)
            self.clock.advance_to(t1)
            for ev in self.clock.pop_due():
                self._apply_trace_event(ev, t1)
            self._tick(t, t1)
            t = t1
        # close the unserved spans still open at the horizon
        open_ids = np.nonzero(np.isfinite(self.unserved_since)
                              & self.active)[0]
        if open_ids.size:
            self.ledger.note_unserved_span(
                open_ids, scn.horizon_s - self.unserved_since[open_ids])
        return self.report()

    # -- reporting -----------------------------------------------------------
    def report(self) -> dict:
        led = self.ledger
        scn = self.scn
        m = self.metrics
        active_s = float(led.active_s.sum())
        viol = float(led.violation_s.sum())
        all_hist = led.lat_hist.sum(axis=0, keepdims=True)
        reps = sorted(self.replicas + self._retired, key=lambda r: r.id)
        return {
            "schema": 1,
            "scenario": {
                "name": scn.name, "seed": scn.seed, "arch": scn.arch,
                "tenants": scn.tenants, "horizon_s": _r6(scn.horizon_s),
                "tick_s": _r6(scn.tick_s), "arrival": scn.arrival,
                "budget_gib": _r6(self._budget0 / GIB),
                "slots_per_replica": scn.slots_per_replica,
            },
            "totals": {
                "arrived_tokens": _r6(led.arrived.sum()),
                "served_tokens": _r6(led.served.sum()),
                "dropped_tokens": _r6(led.dropped.sum()),
                "goodput_tps": _r6(led.served.sum() / scn.horizon_s),
                "violation_s": _r6(viol),
                "active_tenant_s": _r6(active_s),
                "violation_rate": _r6(viol / max(active_s, 1e-9)),
                "p95_latency_s": _r6(led.percentile(0.95, all_hist)[0]),
                "p99_latency_s": _r6(led.percentile(0.99, all_hist)[0]),
                "max_unserved_span_s": _r6(
                    led.max_unserved_span_s.max(initial=0.0)),
                "preemptions": int(m["preemptions"]),
                "forced_admissions": int(m["forced_admissions"]),
                "arbitrations": int(m["arbitrations"]),
                "replans": int(m["replans"]),
                "migrated_bytes": int(m["migrated_bytes"]),
                "downtime_s": _r6(sum(r.downtime_s for r in reps)),
                "scale_ups": int(m["scale_ups"]),
                "scale_downs": int(m["scale_downs"]),
                "replicas_final": len(self.replicas),
                "replicas_peak": int(m["replicas_peak"]),
                "used_bytes_final": int(self.used_bytes),
                "events_recorded": len(self.events),
                "events_dropped": int(m["events_dropped"]),
            },
            "classes": {
                name: {k: (_r6(v) if isinstance(v, float) else v)
                       for k, v in row.items()}
                for name, row in led.class_rollup(
                    self.cls, [c.name for c in self.classes]).items()
            },
            "replicas": [{
                "id": r.id,
                "created_s": _r6(r.created_s),
                "retired_s": None if r.retired_s is None
                else _r6(r.retired_s),
                "replans": r.replans,
                "downtime_s": _r6(r.downtime_s),
                "served_tokens": _r6(r.served_tokens),
                "iterations": int(r.engine.metrics["iterations"]),
                "point": None if r.point is None else {
                    "tokens_per_s": _r6(r.point.qos.tokens_per_s),
                    "device_gib": _r6(r.point.qos.device_bytes / GIB),
                    "quality_proxy": _r6(r.point.qos.quality_proxy),
                },
            } for r in reps],
            "events": self.events,
            "tenants": led.tenant_rows(self.cls),
        }

    def report_bytes(self) -> bytes:
        """The canonical serialization — byte-identical across replays
        of the same scenario+seed (sorted keys, fixed separators, 6-dp
        rounding, trailing newline)."""
        return (json.dumps(self.report(), sort_keys=True,
                           separators=(",", ":")) + "\n").encode()


def run_scenario(scenario: Scenario, *,
                 frontier: Optional[ParetoFrontier] = None,
                 classes: Sequence[SLOClass] = DEFAULT_SLO_CLASSES
                 ) -> ControlPlane:
    """Build, run and return the (finished) plane for a scenario."""
    plane = ControlPlane(scenario, classes=classes, frontier=frontier)
    plane.run()
    return plane
