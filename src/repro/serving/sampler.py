"""Token samplers (greedy / temperature / top-k) for the serving engine,
plus the speculative-decode verify primitives (DESIGN.md §17)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _mask_vocab_pad(logits: jax.Array, vocab_size: int) -> jax.Array:
    if vocab_size and logits.shape[-1] > vocab_size:
        mask = jnp.arange(logits.shape[-1]) >= vocab_size
        logits = jnp.where(mask, -1e30, logits)
    return logits


def sample(logits: jax.Array, *, key: jax.Array, temperature: float = 0.0,
           top_k: int = 0, vocab_size: int = 0) -> jax.Array:
    """logits: (B, V_padded) -> (B,) int32."""
    logits = _mask_vocab_pad(logits, vocab_size)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        thresh = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def greedy(logits: jax.Array, *, vocab_size: int = 0) -> jax.Array:
    """Argmax over the last axis with vocab-pad masking — EXACTLY the
    ``temperature <= 0`` branch of :func:`sample`, shape-polymorphic in
    the leading axes so the verify forward can score (B, S, V) logits in
    one call. Greedy speculative acceptance compares these targets
    against the drafted tokens; equality over a prefix means verify and
    plain decode chose identical tokens (DESIGN.md §17)."""
    return jnp.argmax(_mask_vocab_pad(logits, vocab_size),
                      axis=-1).astype(jnp.int32)


def sample_probs(logits: jax.Array, *, temperature: float,
                 top_k: int = 0, vocab_size: int = 0) -> jax.Array:
    """The categorical distribution :func:`sample` draws from at
    ``temperature > 0`` (same masking, same scaling, f32 simplex over the
    last axis). The rejection-sampled verify path needs the explicit
    draft (q) and target (p) probabilities, not just a draw."""
    if temperature <= 0.0:
        raise ValueError("sample_probs is the temperature>0 distribution; "
                         "greedy verify compares argmax targets instead")
    logits = _mask_vocab_pad(logits, vocab_size) / temperature
    if top_k:
        thresh = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)


def speculative_verify(draft_tokens: np.ndarray, q_probs: np.ndarray,
                       p_probs: np.ndarray, accept_uniforms: np.ndarray,
                       residual_uniforms: np.ndarray
                       ) -> Tuple[int, int]:
    """Chain rejection sampling for one slot (Leviathan et al.; host-side
    numpy — k is tiny and the engine drives one slot row at a time).

    draft_tokens: (k,) tokens proposed by the draft model;
    q_probs: (k, V) draft distribution each was drawn from;
    p_probs: (k+1, V) target distributions from the verify forward
    (row j conditions on the prefix through draft j);
    accept_uniforms / residual_uniforms: (k,) / (k+1,) U(0,1) draws.

    Returns ``(accepted, final_token)``: draft j is accepted with
    probability ``min(1, p[d_j]/q[d_j])``; the first rejection resamples
    from the normalized residual ``max(p - q, 0)``; full acceptance draws
    the bonus token from ``p[k]``. The emitted stream is
    ``draft_tokens[:accepted] + [final_token]`` — distributed EXACTLY as
    k+1 sequential target samples, at any acceptance rate."""
    k = len(draft_tokens)
    for j in range(k):
        d = int(draft_tokens[j])
        p_d = float(p_probs[j, d])
        q_d = float(q_probs[j, d])
        if q_d <= 0.0 or accept_uniforms[j] * q_d > p_d:
            residual = np.maximum(
                p_probs[j].astype(np.float64)
                - q_probs[j].astype(np.float64), 0.0)
            z = residual.sum()
            if z <= 0.0:        # p == q: any p-sample is exact
                residual, z = p_probs[j].astype(np.float64), \
                    float(p_probs[j].sum())
            cdf = np.cumsum(residual / z)
            tok = int(np.searchsorted(cdf, float(residual_uniforms[j]),
                                      side="right"))
            return j, min(tok, len(cdf) - 1)
    p_last = p_probs[k].astype(np.float64)
    cdf = np.cumsum(p_last / p_last.sum())
    tok = int(np.searchsorted(cdf, float(residual_uniforms[k]),
                              side="right"))
    return k, min(tok, len(cdf) - 1)
