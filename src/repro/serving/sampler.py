"""Token samplers (greedy / temperature / top-k) for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, *, key: jax.Array, temperature: float = 0.0,
           top_k: int = 0, vocab_size: int = 0) -> jax.Array:
    """logits: (B, V_padded) -> (B,) int32."""
    if vocab_size and logits.shape[-1] > vocab_size:
        mask = jnp.arange(logits.shape[-1]) >= vocab_size
        logits = jnp.where(mask, -1e30, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        thresh = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
