"""Host-side page allocator for the paged KV cache (DESIGN.md §13).

The device pool and its gather/scatter live in ``models/model.py``; this
module owns the bookkeeping the engine drives every iteration: the
per-slot page table (chunk index -> physical page, 0 = the reserved null
page), the free list, and the byte accounting that makes the paged win
measurable (``engine.summary()``'s kv columns) and feeds reclaimed HBM
back into the frontier's residency axis (``EngineConfig.kv_reserve``).

Allocation never dead-ends mid-flight: the engine derives an admission
cap (``max_active_tokens``) from the pool size whenever the pool is
smaller than worst case, so ``ensure()`` failing is a logic error, not an
operational state.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List

import numpy as np

__all__ = ["PageAllocator"]


class PageAllocator:
    """Per-slot page table + free list over ``num_pages`` physical pages.

    Page 0 is the reserved null page: it marks unmapped chunks in the
    table and is never handed out. The table is the exact array the
    engine ships to the jitted paged decode step each iteration.
    """

    def __init__(self, num_slots: int, chunks_per_slot: int,
                 num_pages: int, page_size: int):
        self.num_slots = num_slots
        self.chunks_per_slot = chunks_per_slot
        self.num_pages = num_pages
        self.page_size = page_size
        #: chunk -> physical page; 0 = unmapped (the null page)
        self.table = np.zeros((num_slots, chunks_per_slot), np.int32)
        self._free: Deque[int] = deque(range(1, num_pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def ensure(self, slot: int, chunk: int) -> int:
        """Map ``chunk`` of ``slot`` (no-op if already mapped); returns
        the physical page."""
        page = int(self.table[slot, chunk])
        if page:
            return page
        if not self._free:
            raise RuntimeError(
                f"KV page pool exhausted ({self.num_pages - 1} pages); "
                "the admission cap should have prevented this")
        page = self._free.popleft()
        self.table[slot, chunk] = page
        return page

    def ensure_prefix(self, slot: int, tokens: int) -> List[int]:
        """Map every chunk a ``tokens``-long prefill writes (ring indices
        0..tokens-1; the scheduler already validated tokens <= window);
        returns the pages touched."""
        chunks = min(-(-tokens // self.page_size), self.chunks_per_slot)
        return [self.ensure(slot, c) for c in range(chunks)]

    def ensure_index(self, slot: int, ring_index: int) -> int:
        """Map the chunk containing ``ring_index`` (the decode write
        target ``position % window``)."""
        return self.ensure(slot, ring_index // self.page_size)

    def truncate(self, slot: int, new_len: int) -> List[int]:
        """Unmap every chunk past a ``new_len``-token ring prefix (chunk
        ``ceil(new_len / page_size)`` onward); returns the freed pages.

        This is the page-residency analog of the device-side rollback:
        rejected speculative tokens and early-stopped requests would
        otherwise hold their tail pages until retire (DESIGN.md §17).
        The caller must already have invalidated the freed pages'
        position tags on device (the speculative rollback bounds tags
        BEFORE truncation; retire uses ``free_slot`` + reset instead).
        No-op (returns []) when the prefix already covers every mapped
        chunk. NOTE: only meaningful while the slot's live ring span is
        the prefix 0..new_len-1 (pre-wraparound) — after the ring wraps,
        every chunk is live and truncate must not be called."""
        keep = min(-(-max(new_len, 0) // self.page_size),
                   self.chunks_per_slot)
        freed = [int(p) for p in self.table[slot, keep:] if p]
        self.table[slot, keep:] = 0
        self._free.extend(freed)
        return freed

    def free_slot(self, slot: int) -> List[int]:
        """Unmap the slot's pages back to the free list; returns the
        freed page ids (the engine invalidates their position tags on
        device before they can be re-handed out)."""
        pages = [int(p) for p in self.table[slot] if p]
        self.table[slot] = 0
        self._free.extend(pages)
        return pages

    def slot_pages(self, slot: int) -> List[int]:
        return [int(p) for p in self.table[slot] if p]
