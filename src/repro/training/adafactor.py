"""Adafactor (factored second moment, no first moment) — the memory-lean
optimizer option for the 1T-param Kimi-K2 cell (DESIGN.md / EXPERIMENTS.md
§Dry-run memory notes)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.training.optimizer import OptConfig, global_norm, schedule


def init_adafactor_state(params) -> Dict[str, Any]:
    def factors(x):
        if x.ndim < 2:
            return {"v": jnp.zeros(x.shape, jnp.float32)}
        return {"vr": jnp.zeros(x.shape[:-1], jnp.float32),
                "vc": jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32)}
    return {"f": jax.tree_util.tree_map(factors, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    b2 = 1.0 - (step.astype(jnp.float32)) ** -0.8

    def upd(p, g, f):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + 1e-30
        if p.ndim < 2:
            v = b2 * f["v"] + (1 - b2) * g2
            u = g * jax.lax.rsqrt(v + 1e-30)
            newf = {"v": v}
        else:
            vr = b2 * f["vr"] + (1 - b2) * g2.mean(-1)
            vc = b2 * f["vc"] + (1 - b2) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1)[..., None, None], 1e-30))
            u = g * jax.lax.rsqrt(denom + 1e-30)
            newf = {"vr": vr, "vc": vc}
        # update clipping (Adafactor RMS rule)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        newp = (p.astype(jnp.float32) - lr * u
                - lr * cfg.weight_decay * p.astype(jnp.float32) * (p.ndim >= 2)
                ).astype(p.dtype)
        return newp, newf

    # params is the structure tree: each param leaf pairs with the whole
    # factor sub-dict of state["f"]
    pairs = jax.tree_util.tree_map(upd, params, grads, state["f"])
    is_pair = lambda x: isinstance(x, tuple)
    new_p = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_f = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is_pair)
    return new_p, {"f": new_f, "step": step}, {"grad_norm": gnorm, "lr": lr}
