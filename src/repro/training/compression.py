"""int8 gradient compression with error feedback (1-bit-Adam-style).

Large-scale data parallelism ships gradients across pods every step; at
(2, 16, 16) the pod-axis all-reduce moves the full gradient set over the
slow inter-pod links. Compressing to int8 (per-tensor absmax scale)
quarters the wire bytes; the quantization error is fed back into the
next step's gradient (error feedback), which provably preserves SGD/Adam
convergence rates for smooth objectives.

Semantics implemented here:

    g_corrected = g + ef                     (apply residual)
    q, scale    = quantize_int8(g_corrected) (what crosses the wire)
    g_hat       = q * scale                  (all ranks decode identically)
    ef'         = g_corrected - g_hat        (residual stays local)

``g_hat`` feeds the optimizer. Under pjit the data/pod-axis reduction is
inserted by GSPMD, so the int8 *representation* is validated numerically
here (tests/test_compression.py: convergence + bounded residual), and
the wire-level int8 all-reduce is a runtime substitution on the reduced
tensor — the math above is exactly what each rank computes either way.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_grad(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (codes, scale)."""
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def compress_grads(grads, ef):
    """(decoded grads, new error feedback). Apply between accumulation
    and the optimizer update."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_grad(corrected)
        g_hat = q.astype(jnp.float32) * scale
        return g_hat.astype(g.dtype), corrected - g_hat

    flat = jax.tree_util.tree_map(one, grads, ef)
    g_hat = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, new_ef


def wire_bytes(params, compressed: bool) -> int:
    """Gradient all-reduce payload per step (reporting helper)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        total += leaf.size * (1 if compressed else 4) + \
            (4 if compressed else 0)
    return total
