"""pjit train step: microbatched grad accumulation + optimizer update.

Microbatching (grad accumulation over a lax.scan) bounds activation memory
to one microbatch and overlaps the per-microbatch gradient all-reduce with
the next microbatch's compute (XLA schedules the accumulation psum while the
scan body runs — the standard compute/comm overlap trick at this layer).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.training import optimizer as O
from repro.training import adafactor as AF


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: O.OptConfig = O.OptConfig()
    optimizer: str = "adamw"          # adamw | adafactor
    num_microbatches: int = 1
    grad_dtype: Any = jnp.bfloat16    # accumulation dtype
    # int8 + error-feedback gradient compression for the (inter-pod)
    # gradient all-reduce (training/compression.py); None disables.
    grad_compression: Any = None      # None | "int8"


def init_train_state(params, tcfg: TrainConfig):
    state = AF.init_adafactor_state(params) if tcfg.optimizer == "adafactor" \
        else O.init_opt_state(params)
    if tcfg.grad_compression == "int8":
        from repro.training import compression as C
        state = dict(state)
        state["ef"] = C.init_error_feedback(params)
    return state


def _opt_update(params, grads, opt_state, tcfg: TrainConfig):
    if tcfg.optimizer == "adafactor":
        return AF.adafactor_update(params, grads, opt_state, tcfg.opt)
    return O.adamw_update(params, grads, opt_state, tcfg.opt)


def make_train_step(loss_fn: Callable, tcfg: TrainConfig):
    """loss_fn(params, batch) -> (loss, metrics). Returns
    train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        n = tcfg.num_microbatches
        if n == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                batch)

            def body(acc, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(tcfg.grad_dtype), acc, g)
                return g, (l, m)

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, tcfg.grad_dtype), params)
            grads, (losses, ms) = jax.lax.scan(body, zeros, micro)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = losses.mean()
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        if tcfg.grad_compression == "int8":
            from repro.training import compression as C
            ef = opt_state["ef"]
            grads, new_ef = C.compress_grads(grads, ef)
            opt_state = {k: v for k, v in opt_state.items() if k != "ef"}
            params, opt_state, om = _opt_update(params, grads, opt_state,
                                                tcfg)
            opt_state = dict(opt_state)
            opt_state["ef"] = new_ef
        else:
            params, opt_state, om = _opt_update(params, grads, opt_state,
                                                tcfg)
        return params, opt_state, {**metrics, **om}

    return train_step


def opt_state_specs(param_spec_tree, tcfg: TrainConfig, params_struct):
    """PartitionSpec tree for the optimizer state, derived from the param
    specs (moments shard like their params; factored states drop the
    reduced dim's partition)."""
    from jax.sharding import PartitionSpec as P

    extra = {}
    if tcfg.grad_compression == "int8":
        extra["ef"] = param_spec_tree    # residual shards like its param
    if tcfg.optimizer == "adamw":
        return {"m": param_spec_tree, "v": param_spec_tree, "step": P(),
                **extra}

    def factor_specs(spec, p):
        if p.ndim < 2:
            return {"v": spec}
        parts = tuple(spec) + (None,) * (p.ndim - len(tuple(spec)))
        return {"vr": P(*parts[:-1]), "vc": P(*(parts[:-2] + parts[-1:]))}

    return {"f": jax.tree_util.tree_map(
        factor_specs, param_spec_tree, params_struct,
        is_leaf=lambda x: isinstance(x, P)), "step": P(), **extra}
