"""AdamW + cosine schedule + global-norm clipping (no optax offline).

Optimizer moments are f32 (params stay bf16); state shards exactly like the
params (the sharding rules map over the same tree structure), giving
ZeRO-ish behavior for tensor-sharded weights for free.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm,
                              0.1 + 0.9 * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def _is_matrix(path: tuple) -> bool:
    last = str(path[-1])
    return not any(s in last for s in ("scale", "norm", "bias", "ln_x",
                                       "A_log", "D", "mix", "bonus"))


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_map_with_path(
        upd, params, grads, state["m"], state["v"])
    new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
