"""Control-plane scenario runner (DESIGN.md §14.6).

Replays a named scenario from the catalog through the trace-driven
control plane and writes the deterministic report — same scenario +
seed, byte-identical file, which is exactly what CI asserts by running
the reference scenario twice and ``cmp``-ing the outputs.

Usage:
  python -m repro.launch.simulate --list
  python -m repro.launch.simulate --scenario diurnal-1k --smoke
  python -m repro.launch.simulate --scenario golden-32 --out results/x.json
  python -m repro.launch.simulate --scenario steady-64 --perf

``--smoke`` shortens the horizon (scenario-declared smoke horizon,
budget shocks past it dropped); ``--perf`` appends a wall-clock scaling
section to the written file AFTER the deterministic body is produced
(perf numbers are machine-dependent by nature, so determinism checks
must compare reports produced without ``--perf``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

from repro.serving.control_plane import (ControlPlane, SCENARIOS,
                                         get_scenario)

DEFAULT_OUT = Path("results") / "sim_control_plane.json"


def run(scenario_name: str, *, seed: int = None, smoke: bool = False,
        perf: bool = False) -> tuple:
    """Returns (report_bytes, plane, wall_s)."""
    scn = get_scenario(scenario_name)
    if smoke:
        scn = scn.smoke()
    if seed is not None:
        scn = dataclasses.replace(scn, seed=seed)
    t0 = time.perf_counter()
    plane = ControlPlane(scn)
    plane.run()
    wall = time.perf_counter() - t0
    body = plane.report_bytes()
    if perf:
        report = json.loads(body)
        virt = scn.horizon_s
        report["perf"] = {
            "wall_s": round(wall, 3),
            "virtual_s": virt,
            "speedup_x": round(virt / max(wall, 1e-9), 1),
            "tenant_virtual_s_per_wall_s": round(
                scn.tenants * virt / max(wall, 1e-9), 1),
        }
        body = (json.dumps(report, sort_keys=True,
                           separators=(",", ":")) + "\n").encode()
    return body, plane, wall


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="trace-driven control-plane simulator")
    ap.add_argument("--scenario", default="steady-64",
                    help="catalog name (see --list)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the scenario's seed")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--smoke", action="store_true",
                    help="shortened horizon for CI")
    ap.add_argument("--perf", action="store_true",
                    help="append machine-dependent wall-clock section")
    ap.add_argument("--list", action="store_true",
                    help="print the scenario catalog and exit")
    ap.add_argument("--check-ceiling", action="store_true",
                    help="exit 1 if violation_rate exceeds the "
                         "scenario's declared ceiling")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(SCENARIOS):
            s = SCENARIOS[name]
            print(f"{name:12s} tenants={s.tenants:<5d} "
                  f"horizon={s.horizon_s:>9.0f}s arrival={s.arrival:8s} "
                  f"shocks={len(s.budget_shocks)} "
                  f"replicas={s.min_replicas}..{s.max_replicas} "
                  f"ceiling={s.violation_ceiling}")
        return 0

    body, plane, wall = run(args.scenario, seed=args.seed,
                            smoke=args.smoke, perf=args.perf)
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_bytes(body)

    t = plane.report()["totals"]
    scn = plane.scn
    print(f"[{scn.name}] seed={scn.seed} tenants={scn.tenants} "
          f"horizon={scn.horizon_s:.0f}s wall={wall:.2f}s "
          f"({scn.horizon_s / max(wall, 1e-9):.0f}x realtime)")
    print(f"  goodput={t['goodput_tps']:.1f} tok/s "
          f"violation_rate={t['violation_rate']:.4f} "
          f"preemptions={t['preemptions']} "
          f"scale={t['scale_ups']}up/{t['scale_downs']}down "
          f"arbitrations={t['arbitrations']} replans={t['replans']}")
    print(f"  wrote {args.out}")
    if args.check_ceiling and t["violation_rate"] > scn.violation_ceiling:
        print(f"FAIL: violation_rate {t['violation_rate']:.4f} > "
              f"ceiling {scn.violation_ceiling}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
