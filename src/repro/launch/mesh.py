"""Production mesh builders (spec-mandated shapes).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.
"""
from __future__ import annotations

import contextlib

import jax


def _make_mesh(shape, axes, devices):
    """jax.make_mesh across versions (axis_types only where supported)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh: ``jax.set_mesh``
    on new jax, the legacy global-mesh context on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh if hasattr(mesh, "__enter__") else contextlib.nullcontext()


def _require_devices(ndev: int, shape) -> list:
    """The first ``ndev`` jax devices, or the actionable XLA_FLAGS error
    every mesh builder raises (a short device list would otherwise build
    a silently wrong-shaped mesh)."""
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}; got {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(ndev, 8)} BEFORE importing jax (launch/dryrun.py "
            "does this)")
    return devices[:ndev]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    ndev = int(np.prod(shape))
    return _make_mesh(shape, axes, _require_devices(ndev, shape))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    import numpy as np
    ndev = int(np.prod(shape))
    return _make_mesh(shape, axes, _require_devices(ndev, shape))


def make_ep_mesh(ep: int, *, replica: int = 0):
    """The (1, ep) serving mesh of DP replica ``replica`` (DESIGN.md
    §16): axes ("data", "model") with the experts sharded over "model"
    (mixed_moe's EP axis) and a size-1 data axis — data parallelism is
    N whole engine REPLICAS (serving/ep.DPReplicaGroup), not an in-mesh
    axis, so each replica's mesh owns the disjoint device slice
    ``[replica*ep, (replica+1)*ep)``. Raises the actionable XLA_FLAGS
    error when the host does not expose enough devices."""
    ep = int(ep)
    if ep < 1:
        raise ValueError(f"ep must be >= 1, got {ep}")
    if replica < 0:
        raise ValueError(f"replica must be >= 0, got {replica}")
    ndev = (replica + 1) * ep
    devices = _require_devices(ndev, (1, ep))[replica * ep:]
    return _make_mesh((1, ep), ("data", "model"), devices)
