"""Production mesh builders (spec-mandated shapes).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}; got {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices[:ndev],
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    import numpy as np
    ndev = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:ndev],
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
