"""Production mesh builders (spec-mandated shapes).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.
"""
from __future__ import annotations

import contextlib

import jax


def _make_mesh(shape, axes, devices):
    """jax.make_mesh across versions (axis_types only where supported)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh: ``jax.set_mesh``
    on new jax, the legacy global-mesh context on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh if hasattr(mesh, "__enter__") else contextlib.nullcontext()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}; got {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return _make_mesh(shape, axes, devices[:ndev])


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    import numpy as np
    ndev = int(np.prod(shape))
    return _make_mesh(shape, axes, jax.devices()[:ndev])
