"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 128 [--smoke] [--ckpt-dir DIR] \
        [--resume] [--microbatches 2]

On this CPU container ``--smoke`` (default) reduces the config to the
same-family smoke scale. On a TPU slice, drop ``--smoke`` and pass
``--mesh data,model`` sizes that match the slice; the step function,
shardings, checkpointing and data pipeline are the production ones either
way — tests/test_dryrun_small.py and the multi-pod dry-run prove the full
configs compile for the production meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.data.pipeline import (DataPipeline, SyntheticCorpus,
                                 SyntheticCorpusConfig)
from repro.dist import sharding as SH
from repro.ft.checkpoint import CheckpointManager
from repro.models.model import build_model
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduce config for CPU (default on)")
    ap.add_argument("--mesh", default=None,
                    help="comma data,model sizes, e.g. 16,16 (TPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--optimizer", default="adamw",
                    choices=("adamw", "adafactor"))
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    model = build_model(cfg, mesh)
    print(f"[train] {cfg.arch_id} ({cfg.param_count()/1e6:.1f}M params) "
          f"steps={args.steps} batch={args.batch}x{args.seq} "
          f"mesh={mesh.shape if mesh else '1x1'}")

    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps),
        optimizer=args.optimizer, num_microbatches=args.microbatches)
    corpus = SyntheticCorpus(SyntheticCorpusConfig(
        vocab_size=cfg.vocab_size))
    pipe = DataPipeline(corpus, batch=args.batch, seq=args.seq)

    params = model.init(jax.random.key(0))
    state = init_train_state(params, tcfg)
    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume and mgr.latest_step() is not None:
            tree, manifest = mgr.restore()
            params = jax.tree_util.tree_map(jnp.asarray, tree["params"])
            state = jax.tree_util.tree_map(jnp.asarray, tree["opt"])
            pipe.restore(manifest["extra"]["pipe"])
            start = manifest["extra"]["step"]
            print(f"[train] resumed from step {start}")

    if mesh is not None:
        p_sh = SH.param_shardings(cfg, mesh, params)
        params = jax.tree_util.tree_map(jax.device_put, params, p_sh)
        step_fn = jax.jit(make_train_step(model.loss_fn, tcfg))
    else:
        step_fn = jax.jit(make_train_step(model.loss_fn, tcfg))

    t0 = time.perf_counter()
    tokens = 0
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, state, metrics = step_fn(params, state, batch)
        tokens += args.batch * args.seq
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"  step {step:5d} nll={float(metrics['nll']):.4f} "
                  f"gnorm={float(metrics.get('grad_norm', 0)):.2f} "
                  f"tok/s={tokens/max(dt, 1e-9):,.0f}")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": state},
                     extra={"pipe": pipe.state(), "step": step + 1})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": state},
                 extra={"pipe": pipe.state(), "step": args.steps},
                 block=True)
        print(f"[train] final checkpoint at step {args.steps}")


if __name__ == "__main__":
    main()
