import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

MUST be imported before anything that initializes jax — the two lines above
create 512 host placeholder devices so the production meshes (16,16) and
(2,16,16) can be built. Do NOT set this flag globally (smoke tests and
benches run on 1 device).

Per cell this driver:
  1. builds the step function (train_step / prefill / decode_step),
  2. lowers + compiles it AOT against ShapeDtypeStruct inputs with the
     production shardings (no allocation),
  3. records memory_analysis / cost_analysis / collective bytes parsed from
     the compiled HLO (roofline inputs) into results/dryrun/*.json.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_cells, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding as SH
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models.model import (abstract_params, apply_precision_plan,
                                build_model, init_cache)
from repro.training.train_loop import (TrainConfig, make_train_step,
                                       opt_state_specs, init_train_state)
from repro.training.optimizer import OptConfig

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Serving cells for MoE archs lower the paper's mixed-precision banks:
# half the experts 4-bit (per-layer balanced; EP needs multiples of 16).
MOP_FRACTION = 0.5


def _serve_params_struct(cfg: ModelConfig):
    """Abstract serve-layout params (mixed banks) via eval_shape."""
    if cfg.moe is None or not cfg.mop.enabled:
        return abstract_params(cfg)
    from repro.core.precision_plan import balanced_random_plan
    e = cfg.moe.num_experts
    per_layer = int(e * MOP_FRACTION)
    per_layer -= per_layer % 16 if e >= 16 else 0
    plan = balanced_random_plan(cfg.num_layers, e,
                                per_layer * cfg.num_layers,
                                bits=cfg.mop.bits,
                                group_size=cfg.mop.group_size)
    fn = functools.partial(apply_precision_plan, cfg=cfg, plan=plan)
    return jax.eval_shape(fn, abstract_params(cfg))


def pick_train_cfg(cfg: ModelConfig, shape: ShapeConfig, mesh) -> TrainConfig:
    dp = SH.batch_axes(mesh, shape.global_batch)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    b_loc = max(shape.global_batch // n_dp, 1)
    # one sequence per device per microstep bounds activation memory
    n_micro = b_loc
    opt = "adafactor" if cfg.param_count() > 2e11 else "adamw"
    return TrainConfig(opt=OptConfig(), optimizer=opt,
                       num_microbatches=n_micro)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (fn, example_args (SDS), in_shardings, out_shardings|None,
    donate)."""
    dp = SH.batch_axes(mesh, shape.global_batch)
    model = build_model(cfg, mesh, dp_axes=dp)
    ns = lambda tree: SH.param_shardings(cfg, mesh, tree)

    if shape.kind == "train":
        cfg_t = cfg.replace(remat="full")
        model_t = build_model(cfg_t, mesh, dp_axes=dp)
        tcfg = pick_train_cfg(cfg, shape, mesh)
        step = make_train_step(model_t.loss_fn, tcfg)
        params = abstract_params(cfg)
        opt_state = jax.eval_shape(
            functools.partial(init_train_state, tcfg=tcfg), params)
        batch, batch_sh = SH.input_specs(cfg, shape, mesh)
        p_spec = SH.param_specs(cfg, mesh, params)
        o_spec = opt_state_specs(p_spec, tcfg, params)
        to_ns = lambda t: jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        in_sh = (to_ns(p_spec), to_ns(o_spec), batch_sh)
        out_sh = (to_ns(p_spec), to_ns(o_spec), None)
        return step, (params, opt_state, batch), in_sh, out_sh, (0, 1)

    serve_params = _serve_params_struct(cfg)
    p_sh = ns(serve_params)
    cache, cache_sh = SH.cache_specs(cfg, shape, mesh)
    if shape.kind == "prefill":
        batch, batch_sh = SH.input_specs(cfg, shape, mesh)
        fn = lambda params, batch, cache: model.prefill(params, batch, cache)
        return fn, (serve_params, batch, cache), \
            (p_sh, batch_sh, cache_sh), None, (2,)
    # decode
    inp, inp_sh = SH.input_specs(cfg, shape, mesh)
    fn = lambda params, cache, tokens, positions: model.decode_step(
        params, cache, tokens, positions)
    return fn, (serve_params, cache, inp["tokens"], inp["positions"]), \
        (p_sh, cache_sh, inp_sh["tokens"], inp_sh["positions"]), None, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, extra_tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = "pod2x16x16" if multi_pod else "pod16x16"
    out = {"arch": arch, "shape": shape_name, "mesh": tag,
           "params_b": cfg.param_count() / 1e9,
           "active_params_b": cfg.active_param_count() / 1e9}
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
        jit_kw = dict(in_shardings=in_sh)
        if out_sh is not None:
            jit_kw["out_shardings"] = out_sh
        with use_mesh(mesh):
            jfn = jax.jit(fn, **jit_kw)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        out.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "code_bytes": int(ma.generated_code_size_in_bytes),
                "peak_per_device_gib": round(
                    (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes) / 2**30, 3),
            },
            "cost_analysis": {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed",
                                        "transcendentals")},
        })
        # roofline inputs: collective bytes + trip-count-corrected
        # FLOPs/traffic from compiled HLO (cost_analysis counts a scanned
        # layer body once — see roofline/hlo_parse.py)
        from repro.roofline.hlo_parse import collective_summary, cost_summary
        hlo = compiled.as_text()
        out["collectives"] = collective_summary(hlo)
        out["hlo_cost"] = cost_summary(hlo)
        # TPU-target view: CPU-backend f32-promotion artifacts removed
        # (the roofline's memory term uses this; raw kept for reference)
        out["hlo_cost_tpu"] = cost_summary(hlo, tpu_adjusted=True)
        out["hlo_bytes"] = len(hlo)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweep
        out.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    out["total_s"] = round(time.time() - t0, 2)
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape_name}__{tag}{extra_tag}.json"
        (RESULTS / name).write_text(json.dumps(out, indent=1))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_fail = 0
    for arch, shape_name in cells:
        for mp in meshes:
            tag = "pod2x16x16" if mp else "pod16x16"
            path = RESULTS / f"{arch}__{shape_name}__{tag}.json"
            if path.exists() and not args.force:
                prev = json.loads(path.read_text())
                if prev.get("ok"):
                    print(f"[skip] {arch} {shape_name} {tag} (cached ok)")
                    continue
            r = run_cell(arch, shape_name, mp)
            status = "OK " if r["ok"] else "FAIL"
            mem = r.get("memory", {}).get("peak_per_device_gib", "-")
            print(f"[{status}] {arch:22s} {shape_name:12s} {tag:10s} "
                  f"peak/dev={mem}GiB t={r['total_s']}s"
                  + ("" if r["ok"] else f"  {r['error'][:120]}"))
            n_fail += 0 if r["ok"] else 1
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
