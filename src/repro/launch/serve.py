"""Serving launcher — the paper's adaptive MoE deployment as a CLI, on
the declarative QoS surface (DESIGN.md §9).

Declare TARGETS, not knobs: the engine resolves them on its Pareto
frontier and the QoSController keeps the deployment on target while
requests stream:

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --min-tps 8 --max-ppl-x 1.05 --budget-gb 40 --requests 8

    # quality-capped only: cheapest config within +2% perplexity
    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --max-ppl-x 1.02 --budget-gb 30

``--ladder 16,8,4`` opens the per-expert mixed-precision configuration
space (DESIGN.md §11): the frontier then enumerates counts per ladder
rung and the controller may promote/demote expert rungs at runtime.

``--speculate K`` turns on ladder-draft self-speculative decoding
(DESIGN.md §17): each iteration drafts K tokens per slot with every
expert forced to the lowest ladder rung (no extra weights — the rung
banks are already resident), then one batched verify forward at the
serving plan accepts the longest matching prefix. Greedy output is
token-identical to plain decode; temperature>0 stays exactly
distributed via rejection sampling. The trace gains
``spec[...]`` columns (proposed / accepted / acceptance rate) and the
QoSController falls back to plain decode when measured acceptance
collapses.

``--overlap on`` switches expert staging to the async transfer pipeline
(DESIGN.md §12): transfers run on AsyncExpertCache workers, decode runs
the per-layer lookahead pipeline, and throughput charges only the
EXPOSED transfer time; ``off`` (default) keeps the paper's serial
staging so the two modes A/B against each other.

``--calibrate`` runs the offline sensitivity pass (DESIGN.md §15) and
writes a byte-deterministic per-(layer, expert) profile — same seed,
same bytes — then exits:

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --calibrate --calibrate-out results/sensitivity_profile.json

    # serve with data-driven quality pricing + online rung swaps that
    # chase the measured routing histogram (hysteresis-guarded):
    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --profile results/sensitivity_profile.json --dynamic-precision \
        --max-ppl-x 1.05 --requests 8

The imperative spelling (``--preference throughput|quality --num-q N``)
is kept as a deprecated compatibility path over ``engine.configure``.

``--trace`` replays a CSV of budget points — a single tenant under the
changing allocations of the paper's Fig. 1 scenario. Rows are
``budget_gb,preference[,num_q[,min_tps]]``; the optional 4th SLO column
switches that phase onto the declarative path with
``QoSTarget(mem_budget_bytes, min_tokens_per_s)``:

    # budget_gb, preference, num_q, min_tps (SLO)
    1.2, throughput
    0.8, quality, 0, 5.0

``--tenants spec.json`` hosts N tenants under ONE shared budget through
the :class:`~repro.serving.multi.MultiTenantEngine` (DESIGN.md §10).
The spec carries per-tenant SLO columns (min_tps / max_ppl_x /
deadline_s / priority) plus arbitration weight, and an optional
``budget_fracs`` schedule replaying global budget shifts (each one a
single joint re-arbitration). Budget fractions are of the SUMMED full
bf16 footprint of all tenants:

    {"budget_frac": 1.1, "budget_fracs": [1.1, 0.6],
     "tenants": [
       {"name": "chat",  "min_tps": null, "weight": 2.0,
        "priority": 1, "deadline_s": 30.0, "requests": 3},
       {"name": "batch", "max_ppl_x": 1.0, "requests": 3}]}

``--ep N --dp M`` serves over an expert-parallel mesh (DESIGN.md §16):
each of the M DP replicas is a whole engine decoding over its own
(1, N) device slice, experts sharded over the mesh's "model" axis with
all2all token routing, and the frontier gains the peer-device placement
tier. Runs on CPU with a forced host device count::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --ep 2 --dp 2 --requests 4

Smoke-reduced on CPU (same-family config); the planner/engine logic and
the plan signatures are identical at full scale.
"""
from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.core.dynamic_precision import DynamicPrecisionController
from repro.core.expert_cache import AsyncExpertCache, ExpertCache
from repro.core.sensitivity import SensitivityProfile, calibrate_sensitivity
from repro.ft.checkpoint import CheckpointManager
from repro.models.model import build_model
from repro.serving.api import (EngineConfig, MultiTenantEngine, QoSTarget,
                               RequestSLO, ServeRequest, TenantSpec,
                               build_engine)
from repro.serving.qos import QoSController, QoSControllerConfig


def _parse_trace(path: str):
    """budget_gb,preference[,num_q[,min_tps]] rows; '#' comments; empty
    cells allowed (e.g. ``0.8,quality,,5.0``)."""
    points = []
    for ln in Path(path).read_text().splitlines():
        parts = [p.strip() for p in ln.split(",")]
        if not parts or not parts[0] or parts[0].startswith("#"):
            continue
        points.append((
            float(parts[0]) * 1e9,
            parts[1] if len(parts) > 1 and parts[1] else "throughput",
            int(parts[2]) if len(parts) > 2 and parts[2] else None,
            float(parts[3]) if len(parts) > 3 and parts[3] else None,
        ))
    return points


def _tenant_target(t: dict, full16: float) -> QoSTarget:
    """Per-tenant SLO columns -> QoSTarget. ``min_tps`` null/absent means
    best-effort-fast (inf) unless a quality cap pins the tenant."""
    max_loss = (t["max_ppl_x"] - 1.0) if t.get("max_ppl_x") else None
    min_tps = t.get("min_tps")
    if min_tps is None and max_loss is None:
        min_tps = math.inf
    cap = t.get("budget_frac")
    return QoSTarget(
        min_tokens_per_s=min_tps, max_quality_loss=max_loss,
        mem_budget_bytes=cap * full16 if cap else None)


def _serve_tenants(args, cfg, model, params0, profile=None):
    """--tenants mode: N engines, one budget, one arbiter (DESIGN.md §10)."""
    spec = json.loads(Path(args.tenants).read_text())
    total = cfg.num_layers * cfg.moe.num_experts
    full16 = cfg.non_expert_bytes() + total * cfg.expert_param_bytes(16)
    # budget fractions are of the SUMMED full bf16 footprint of all
    # tenants (1.0 = every tenant could be fully resident in bf16)
    n_tenants = len(spec["tenants"])
    fracs = spec.get("budget_fracs") \
        or [spec.get("budget_frac", 1.1)]
    overlap = args.overlap == "on"
    # the shared swap space is async when overlap serving is on — every
    # tenant's scoped view then streams through its workers (§12)
    cache_cls = AsyncExpertCache if overlap else ExpertCache
    shared = cache_cls(capacity_bytes=max(
        8 * cfg.expert_param_bytes(16), 1 << 20))
    mt = MultiTenantEngine(
        budget_bytes=fracs[0] * full16 * n_tenants, expert_cache=shared,
        controller_config=QoSControllerConfig(
            min_dwell_iterations=4, window_iterations=2))
    for i, t in enumerate(spec["tenants"]):
        params = params0 if i == 0 else model.init(jax.random.key(i))
        engine = build_engine(
            cfg, params,
            EngineConfig(max_slots=2, max_len=16 + args.max_new_tokens,
                         overlap=overlap),
            expert_cache=shared.scoped(t["name"]))
        if profile is not None:
            engine.planner.set_profile(profile)
        dyn = None
        if args.dynamic_precision:
            # per-tenant controller: each engine's own routing histogram
            # drives its swaps; reports fan into the arbiter's ledger
            dyn = DynamicPrecisionController(
                engine, profile if profile is not None
                else SensitivityProfile.uniform(cfg))
        mt.add_tenant(TenantSpec(t["name"], _tenant_target(t, full16),
                                 weight=float(t.get("weight", 1.0))),
                      engine, dynamic=dyn)
    rng = np.random.default_rng(0)
    for phase, frac in enumerate(fracs):
        reports0 = len(mt.reports)
        if phase == 0:
            sel = mt.arbitrate()
        else:
            mt.set_budget(frac * full16 * n_tenants)
            sel = {n: t.point for n, t in mt.tenants.items()}
        print(f"[serve] phase {phase}: budget {frac:.2f}x summed bf16 "
              f"({mt.budget_bytes / 1e6:.1f} MB), "
              f"{mt.metrics['arbitrations']:.0f} arbitrations")
        for t in spec["tenants"]:
            name = t["name"]
            tn = mt.tenants[name]
            print(f"[serve]   {name}: slo[{tn.spec.target.describe()}] "
                  f"w={tn.spec.weight:g} "
                  f"alloc={tn.allocated_bytes / 1e6:.2f}MB "
                  f"-> {sel[name].summary()}")
            for _ in range(int(t.get("requests", args.requests))):
                tn.engine.submit_request(ServeRequest(
                    prompt=rng.integers(1, cfg.vocab_size, 8),
                    max_new_tokens=args.max_new_tokens,
                    slo=RequestSLO(priority=int(t.get("priority", 0)),
                                   deadline_s=t.get("deadline_s"))))
        for r in mt.reports[reports0:]:     # this phase's migrations only
            print(f"[serve]   {r.summary()}")
        while mt.has_work():
            mt.run_iteration(temperature=args.temperature)
        for name, tn in mt.tenants.items():
            lat = tn.engine.latency_percentiles()
            print(f"[serve]   {name}: {len(tn.engine.done)} done, "
                  f"{tn.engine.metrics['tokens_generated']} tokens, "
                  f"p50 {lat['p50'] * 1e3:.0f} ms "
                  f"p95 {lat['p95'] * 1e3:.0f} ms "
                  f"kv_waste={tn.engine.kv_waste_fraction():.0%}")
    if args.dynamic_precision:
        for name, tn in mt.tenants.items():
            dm = tn.dynamic.metrics
            print(f"[serve]   {name}: dynamic precision "
                  f"{dm['swaps']:.0f} swaps "
                  f"({dm['rung_promotions']:.0f}p/"
                  f"{dm['rung_demotions']:.0f}d)")
    print("[serve] " + mt.summary().replace("\n", "\n[serve] "))
    mt.close()                  # joins the shared async transfer workers


def _serve_dp(args, cfg, params, profile=None):
    """--dp N: a DPReplicaGroup of EP engines behind one declarative
    surface (DESIGN.md §16.3). Each replica decodes over its own (1, ep)
    device slice; the §14.3 autoscaler watches the group's demand
    utilization between iterations and its replica decisions land on
    real engines (scale-down drains, no request is dropped)."""
    from repro.serving.ep import make_dp_group
    group = make_dp_group(
        cfg, params,
        EngineConfig(max_slots=4, max_len=32 + args.max_new_tokens,
                     overlap=args.overlap == "on"),
        ep=args.ep, dp=args.dp, max_replicas=args.dp)
    if profile is not None:
        for e in group.engines:
            e.planner.set_profile(profile)
    planner = group.engines[0].planner
    full = planner.size_ne + planner.num_experts_total * planner.size_e16
    budget = args.budget_gb * 1e9 if args.budget_gb else full * 0.6
    max_loss = args.max_ppl_x - 1.0 if args.max_ppl_x else None
    target = QoSTarget(
        min_tokens_per_s=(args.min_tps if args.min_tps is not None
                          else float("inf")),
        max_quality_loss=max_loss, mem_budget_bytes=budget)
    points = group.apply_target(target)
    print(f"[serve] ep={args.ep} dp={group.n_replicas} "
          f"target[{target.describe()}] -> {points[0].summary()}")
    rng = np.random.default_rng(0)
    for k in range(args.requests):
        slo = RequestSLO()
        if args.priority_split and k % 2:
            slo = RequestSLO(priority=1, deadline_s=30.0)
        group.submit_request(ServeRequest(
            prompt=rng.integers(1, cfg.vocab_size, 16),
            max_new_tokens=args.max_new_tokens, slo=slo))
    tick = 0.0
    while group.has_work():
        group.run_iteration(temperature=args.temperature)
        decision = group.autoscale_step(tick)
        if decision:
            print(f"[serve] autoscale {decision:+d} -> "
                  f"{group.n_replicas} replicas")
        tick += 1.0
    m = group.metrics
    print(f"[serve] ep={args.ep} dp={group.n_replicas} "
          f"{m['tokens_generated']:.0f} tokens across "
          f"{m['replicas']:.0f} replicas, "
          f"{group.throughput_tokens_per_s():.1f} tok/s aggregate, "
          f"{m['iterations']:.0f} engine iterations")
    for rid in range(min(2, args.requests)):
        r = group.result(rid)
        print(f"  {r.summary()} tokens={r.tokens[:12]}...")
    group.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=list(ARCH_IDS))
    # -- declarative QoS targets (DESIGN.md §9) -------------------------
    ap.add_argument("--min-tps", type=float, default=None,
                    help="SLO: minimum tokens/s; the QoSController walks "
                         "the Pareto frontier to hold it")
    ap.add_argument("--max-ppl-x", type=float, default=None,
                    help="SLO: quality ceiling as a perplexity multiplier "
                         "vs all-16-bit, e.g. 1.05 = at most +5%%")
    ap.add_argument("--budget-gb", type=float, default=None,
                    help="HBM budget; default = full bf16 size * 0.6")
    ap.add_argument("--speculate", type=int, default=0,
                    help="ladder-draft self-speculative decoding "
                         "(DESIGN.md §17): draft depth K per iteration "
                         "(0 = plain decode, byte-identical engine "
                         "path); greedy output is token-identical, "
                         "temperature>0 uses rejection sampling")
    ap.add_argument("--overlap", default="off", choices=("on", "off"),
                    help="async overlapped expert streaming (DESIGN.md "
                         "§12): transfers stage on a worker pool and "
                         "decode runs the per-layer lookahead pipeline; "
                         "'off' keeps the paper's serial staging for A/B")
    ap.add_argument("--ladder", default=None,
                    help="precision ladder as descending CSV rungs, e.g. "
                         "'16,8,4' (DESIGN.md §11); default = the arch's "
                         "binary ladder (16,<bits>) reproducing boolean "
                         "plans bit-identically")
    # -- sensitivity calibration + dynamic precision (DESIGN.md §15) ----
    ap.add_argument("--calibrate", action="store_true",
                    help="run the offline sensitivity calibration pass "
                         "(activation-weighted per-expert quantization "
                         "error), write the profile and exit; "
                         "byte-deterministic per --calibrate-seed")
    ap.add_argument("--calibrate-out",
                    default="results/sensitivity_profile.json",
                    help="where --calibrate writes the profile")
    ap.add_argument("--calibrate-seed", type=int, default=0,
                    help="seed for the calibration batch (same seed => "
                         "byte-identical profile)")
    ap.add_argument("--profile", default=None,
                    help="serve with a calibrated sensitivity profile: "
                         "the frontier prices quality per (layer, "
                         "expert) instead of the flat rung table")
    ap.add_argument("--dynamic-precision", action="store_true",
                    help="online controller (DESIGN.md §15): folds the "
                         "measured routing histogram into the profile "
                         "and issues hysteresis-guarded byte-neutral "
                         "rung swaps between decode iterations")
    # -- deprecated imperative knobs ------------------------------------
    ap.add_argument("--preference", default=None,
                    choices=("throughput", "quality"),
                    help="DEPRECATED: use --min-tps/--max-ppl-x")
    ap.add_argument("--num-q", type=int, default=None,
                    help="DEPRECATED: Num_E4 for quality preference")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--priority-split", action="store_true",
                    help="submit every other request at priority 1 with a "
                         "deadline, exercising SLO-aware admission")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained params instead of random init")
    ap.add_argument("--trace", default=None,
                    help="CSV of budget_gb,preference[,num_q[,min_tps]] "
                         "to replay (4th column = SLO)")
    ap.add_argument("--tenants", default=None,
                    help="JSON spec of N tenants served under ONE budget "
                         "via the multi-tenant arbiter (DESIGN.md §10); "
                         "see the module docstring for the schema")
    # -- expert-parallel mesh serving (DESIGN.md §16) -------------------
    ap.add_argument("--ep", type=int, default=1,
                    help="expert-parallel shard count: decode over a "
                         "(1, ep) mesh with experts sharded across the "
                         "'model' axis (all2all token routing); expert "
                         "count must divide by ep. Needs ep*dp devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8 on CPU)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replica count: dp whole engines "
                         "on disjoint (1, ep) device slices behind one "
                         "submit surface, autoscaler-driven (§16.3)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.moe is None:
        raise SystemExit(f"{args.arch} has no routed experts — the MoP "
                         "engine serves MoE archs (DESIGN.md §5); dense "
                         "archs serve via the plain prefill/decode path "
                         "(see examples/quickstart.py)")
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if args.ladder:
        import dataclasses
        ladder = tuple(int(b) for b in args.ladder.split(","))
        cfg = cfg.replace(mop=dataclasses.replace(cfg.mop, ladder=ladder))
        print(f"[serve] precision ladder {ladder}")
    if args.ep < 1 or args.dp < 1:
        raise SystemExit(f"--ep/--dp must be >= 1 (got ep={args.ep} "
                         f"dp={args.dp})")
    if args.ep > 1 or args.dp > 1:
        from repro.serving.ep import validate_ep_layout
        try:
            # reject up front — a ladder/expert-count combo that does not
            # divide over the EP axis must fail before building the model
            validate_ep_layout(cfg, args.ep)
        except ValueError as e:
            raise SystemExit(f"[serve] {e}")
        if args.tenants:
            raise SystemExit("--ep/--dp and --tenants are mutually "
                             "exclusive (one mesh per tenant engine is "
                             "not implemented; see DESIGN.md §16)")
        if args.speculate:
            raise SystemExit("--speculate over an EP/DP mesh is not "
                             "implemented (the draft/verify steps are "
                             "single-device jits; see DESIGN.md §17)")
    model = build_model(cfg)
    if args.ckpt_dir and CheckpointManager(args.ckpt_dir).latest_step():
        tree, _ = CheckpointManager(args.ckpt_dir).restore()
        params = jax.tree_util.tree_map(
            jnp.asarray, tree.get("params", tree))
        print(f"[serve] restored params from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.key(0))

    if args.calibrate:
        prof = calibrate_sensitivity(cfg, params,
                                     seed=args.calibrate_seed)
        out = Path(args.calibrate_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        prof.save(out)
        print(f"[serve] sensitivity profile -> {out} "
              f"(seed {args.calibrate_seed}, "
              f"{prof.shape[0]}x{prof.shape[1]} experts, "
              f"rungs {sorted(prof.sens)})")
        return

    profile = None
    if args.profile:
        profile = SensitivityProfile.load(args.profile)
        print(f"[serve] sensitivity profile {args.profile} "
              f"({'uniform' if profile.is_uniform() else 'calibrated'})")

    if args.tenants:
        _serve_tenants(args, cfg, model, params, profile)
        return

    if args.dp > 1:
        _serve_dp(args, cfg, params, profile)
        return

    if args.ep > 1:
        from repro.serving.ep import build_ep_engine
        engine = build_ep_engine(cfg, params, EngineConfig(
            max_slots=4, max_len=32 + args.max_new_tokens,
            overlap=args.overlap == "on"), ep=args.ep)
        print(f"[serve] expert parallelism ep={args.ep}: (1, {args.ep}) "
              f"mesh, experts all2all-sharded (DESIGN.md §16)")
    else:
        engine = build_engine(cfg, params, EngineConfig(
            max_slots=4, max_len=32 + args.max_new_tokens,
            overlap=args.overlap == "on",
            speculate=max(0, args.speculate)))
    if args.overlap == "on":
        print("[serve] async overlapped expert streaming ON "
              "(DESIGN.md §12)")
    if args.speculate > 0:
        print(f"[serve] speculative decoding ON, draft depth "
              f"K={args.speculate} at the lowest ladder rung "
              "(DESIGN.md §17)")
    if profile is not None:
        engine.planner.set_profile(profile)
    dynamic = None
    if args.dynamic_precision:
        dynamic = DynamicPrecisionController(
            engine, profile if profile is not None
            else SensitivityProfile.uniform(cfg))
        print("[serve] dynamic precision ON (DESIGN.md §15): "
              "hysteresis-guarded rung swaps chase measured hotness")
    controller = QoSController(engine, dynamic=dynamic)
    full = engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16
    budget = args.budget_gb * 1e9 if args.budget_gb else full * 0.6

    if args.trace:
        points = _parse_trace(args.trace)
    elif args.preference is not None:
        points = [(budget, args.preference, args.num_q, args.min_tps)]
    else:
        # declarative default path: one QoSTarget phase. With no explicit
        # tokens/s floor the server still wants speed: inf = "as fast as
        # possible inside the budget/quality constraints" (best effort).
        points = [(budget, None, None,
                   args.min_tps if args.min_tps is not None
                   else float("inf"))]

    max_loss = args.max_ppl_x - 1.0 if args.max_ppl_x else None
    rng = np.random.default_rng(0)
    par = f"ep={args.ep} dp={args.dp} "   # parallelism columns (§16)
    for budget, pref, nq, min_tps in points:
        if pref is None or min_tps is not None:
            target = QoSTarget(min_tokens_per_s=min_tps,
                               max_quality_loss=max_loss,
                               mem_budget_bytes=budget)
            point = controller.set_target(target)
            print(f"[serve] {par}target[{target.describe()}] "
                  f"-> {point.summary()}")
        else:
            res = engine.configure(budget, pref, nq)
            # imperative phase: the controller must not keep walking the
            # previous phase's target over this plan
            controller.target = None
            controller.point = None
            print(f"[serve] {par}{res.summary()}")
        for k in range(args.requests):
            slo = RequestSLO()
            if args.priority_split and k % 2:
                slo = RequestSLO(priority=1, deadline_s=30.0)
            engine.submit_request(ServeRequest(
                prompt=rng.integers(1, cfg.vocab_size, 16),
                max_new_tokens=args.max_new_tokens,
                slo=slo))
        while engine.has_work():
            # one shared temperature -> engine-level default keeps the
            # batched sampling path (per-request SamplingParams would
            # force the row-wise loop)
            engine.run_iteration(temperature=args.temperature)
            controller.step()          # QoS loop between iterations
        print(f"[serve] {engine.summary()}")
        # KV padding accounting (DESIGN.md §13): last-iteration snapshot
        # of allocated vs used bytes + run-averaged padding waste — the
        # column a --trace replay watches shrink when paged_kv is on.
        m = engine.metrics
        print(f"[serve]   kv[{'paged' if engine.paged else 'slots'}] "
              f"alloc={m['kv_allocated_bytes'] / 2**20:.2f}MiB "
              f"used={m['kv_used_bytes'] / 2**20:.2f}MiB "
              f"cap={m['kv_capacity_bytes'] / 2**20:.2f}MiB "
              f"waste={engine.kv_waste_fraction():.0%}")
        # speculative decode columns (DESIGN.md §17): shown whenever
        # drafts ran this phase (speculate_k may be 0 already if the
        # QoSController's acceptance fallback fired mid-phase).
        if m["spec_proposed"] or engine.speculate_k:
            print(f"[serve]   spec[k={engine.speculate_k}] "
                  f"proposed={m['spec_proposed']} "
                  f"accepted={m['spec_accepted']} "
                  f"acceptance={m['acceptance_rate']:.2%} "
                  f"fallbacks={controller.metrics['spec_fallbacks']:.0f}")
        if controller.target is not None:
            print(f"[serve] {controller.summary()}")
    if dynamic is not None:
        dm = dynamic.metrics
        print(f"[serve] dynamic precision: {dm['swaps']:.0f} swaps "
              f"({dm['rung_promotions']:.0f} promotions / "
              f"{dm['rung_demotions']:.0f} demotions) over "
              f"{dm['steps']:.0f} steps, measured quality cost "
              f"{dynamic.quality_cost_measured():.5f}")
    for rid in list(engine.done)[:2]:
        r = engine.result(rid)
        print(f"  {r.summary()} tokens={r.tokens[:12]}...")
    engine.close()              # joins the async transfer workers (§12)


if __name__ == "__main__":
    main()
