"""Serving launcher — the paper's adaptive MoE deployment as a CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        [--budget-gb 40] [--preference throughput|quality] [--num-q 128] \
        [--requests 8] [--ckpt-dir DIR] [--trace budgets.csv]

Smoke-reduced on CPU (same-family config); the planner/engine logic and
the plan signatures are identical at full scale. ``--trace`` replays a
CSV of ``budget_gb,preference[,num_q]`` lines — the multi-tenant scenario
of the paper's Fig. 1.
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.ft.checkpoint import CheckpointManager
from repro.models.model import build_model
from repro.serving.engine import AdaptiveServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=list(ARCH_IDS))
    ap.add_argument("--budget-gb", type=float, default=None,
                    help="HBM budget; default = full bf16 size * 0.6")
    ap.add_argument("--preference", default="throughput",
                    choices=("throughput", "quality"))
    ap.add_argument("--num-q", type=int, default=None,
                    help="Num_E4 for quality preference")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained params instead of random init")
    ap.add_argument("--trace", default=None,
                    help="CSV of budget_gb,preference[,num_q] to replay")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.moe is None:
        raise SystemExit(f"{args.arch} has no routed experts — the MoP "
                         "engine serves MoE archs (DESIGN.md §5); dense "
                         "archs serve via the plain prefill/decode path "
                         "(see examples/quickstart.py)")
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)
    if args.ckpt_dir and CheckpointManager(args.ckpt_dir).latest_step():
        tree, _ = CheckpointManager(args.ckpt_dir).restore()
        params = jax.tree_util.tree_map(
            jnp.asarray, tree.get("params", tree))
        print(f"[serve] restored params from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.key(0))

    engine = AdaptiveServingEngine(cfg, params, max_batch=4,
                                   max_len=32 + args.max_new_tokens)
    full = engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16

    if args.trace:
        points = []
        for ln in Path(args.trace).read_text().splitlines():
            parts = [p.strip() for p in ln.split(",")]
            if not parts or parts[0].startswith("#"):
                continue
            points.append((float(parts[0]) * 1e9, parts[1],
                           int(parts[2]) if len(parts) > 2 else None))
    else:
        budget = args.budget_gb * 1e9 if args.budget_gb else full * 0.6
        points = [(budget, args.preference, args.num_q)]

    rng = np.random.default_rng(0)
    for budget, pref, nq in points:
        res = engine.configure(budget, pref, nq)
        print(f"[serve] {res.summary()}")
        for _ in range(args.requests):
            engine.submit(rng.integers(1, cfg.vocab_size, 16),
                          max_new_tokens=args.max_new_tokens)
        while engine.step(temperature=args.temperature):
            pass
        print(f"[serve] {engine.summary()}")
    done = list(engine.done.values())[:2]
    for r in done:
        print(f"  req {r.rid}: {r.out_tokens[:12]}...")


if __name__ == "__main__":
    main()
