"""Deterministic, resumable, DP-sharded synthetic-text data pipeline.

No external datasets are available offline, so the corpus is a synthetic
language with learnable structure: a zipfian-vocabulary order-2 Markov
chain with embedded "phrase" templates. A trained model's perplexity on a
held-out stream is a real generalization measure (used by the paper-
protocol quality benchmarks, benchmarks/fig2_quality.py).

Properties a production pipeline needs and this one has:
  * determinism: stream(seed, dp_rank) is a pure function;
  * resumability: ``state()`` returns an O(1) cursor; ``restore()`` resumes
    bit-exactly (checkpointed with the model, see ft/checkpoint.py);
  * DP sharding: rank r of R sees disjoint documents (leapfrog);
  * packing: documents are packed into fixed (batch, seq+1) token blocks
    with -1 label masking across boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticCorpusConfig:
    vocab_size: int = 512
    order: int = 2
    branching: int = 24        # plausible successors per context
    zipf_a: float = 1.2
    doc_len_mean: int = 512
    seed: int = 1234


class SyntheticCorpus:
    """Order-2 Markov chain over a zipfian vocab; contexts hash to a small
    successor table so the transition structure is learnable."""

    def __init__(self, cfg: SyntheticCorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, b = cfg.vocab_size, cfg.branching
        # per-hash successor candidates + unnormalized zipf weights
        self.n_ctx = 4096
        # zipf-skewed successor candidates: global unigram distribution is
        # heavy-tailed (like text), not uniform
        u = rng.random((self.n_ctx, b))
        self.succ = np.minimum((v * u ** 3).astype(np.int32), v - 1)
        w = 1.0 / np.arange(1, b + 1) ** cfg.zipf_a
        self.cum = np.cumsum(w / w.sum())

    def _ctx_hash(self, a: int, b: int) -> int:
        return (a * 1000003 + b * 7919) % self.n_ctx

    def document(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, doc_id))
        n = max(8, int(rng.exponential(self.cfg.doc_len_mean)))
        out = np.empty(n, np.int32)
        a, b = rng.integers(0, self.cfg.vocab_size, 2)
        for i in range(n):
            h = self._ctx_hash(int(a), int(b))
            j = int(np.searchsorted(self.cum, rng.random()))
            tok = self.succ[h, min(j, self.succ.shape[1] - 1)]
            out[i] = tok
            a, b = b, tok
        return out


@dataclasses.dataclass
class PipelineState:
    doc_cursor: int
    buf: np.ndarray            # leftover tokens from the current doc
    step: int

    def to_dict(self) -> Dict:
        return {"doc_cursor": int(self.doc_cursor),
                "buf": self.buf.tolist(), "step": int(self.step)}

    @classmethod
    def from_dict(cls, d: Dict) -> "PipelineState":
        return cls(doc_cursor=d["doc_cursor"],
                   buf=np.asarray(d["buf"], np.int32), step=d["step"])


class DataPipeline:
    """Packed LM batches for one data-parallel rank."""

    def __init__(self, corpus: SyntheticCorpus, *, batch: int, seq: int,
                 dp_rank: int = 0, dp_size: int = 1, eod: int = 0,
                 start_doc: int = 0):
        self.corpus = corpus
        self.batch, self.seq = batch, seq
        self.dp_rank, self.dp_size = dp_rank, dp_size
        self.eod = eod
        self._state = PipelineState(
            doc_cursor=start_doc, buf=np.empty(0, np.int32), step=0)

    # -- resumability -----------------------------------------------------
    def state(self) -> Dict:
        return self._state.to_dict()

    def restore(self, d: Dict):
        self._state = PipelineState.from_dict(d)

    # -- iteration --------------------------------------------------------
    def _next_tokens(self, n: int) -> np.ndarray:
        st = self._state
        chunks = [st.buf]
        have = len(st.buf)
        cursor = st.doc_cursor
        while have < n:
            doc_id = cursor * self.dp_size + self.dp_rank    # leapfrog
            doc = self.corpus.document(doc_id)
            chunks.append(np.append(doc, self.eod).astype(np.int32))
            have += len(doc) + 1
            cursor += 1
        flat = np.concatenate(chunks)
        st.buf = flat[n:]
        st.doc_cursor = cursor
        return flat[:n]

    def next_batch(self) -> Dict[str, np.ndarray]:
        n = self.batch * (self.seq + 1)
        flat = self._next_tokens(n).reshape(self.batch, self.seq + 1)
        self._state.step += 1
        labels = flat[:, 1:].astype(np.int32)
        # mask the token right after an EOD (cross-document boundary)
        labels = np.where(flat[:, :-1] == self.eod, -1, labels)
        return {"tokens": np.ascontiguousarray(flat[:, :-1]),
                "labels": np.ascontiguousarray(labels)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def make_eval_stream(corpus: SyntheticCorpus, *, batch: int, seq: int,
                     n_batches: int, offset: int = 10_000_000):
    """Held-out stream: documents from a disjoint id range."""
    pipe = DataPipeline(corpus, batch=batch, seq=seq, start_doc=offset)
    return [pipe.next_batch() for _ in range(n_batches)]
