"""SeamlessM4T-medium — enc-dec multimodal. [arXiv:2308.11596; hf]

12L (encoder) + 12L (decoder) d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206. The speech frontend (w2v-BERT conformer) is a STUB per spec:
input_specs() provides precomputed frame embeddings for the encoder.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium",
    family="encdec",
    num_layers=12,
    num_encoder_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=256206,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64,
                              rope_theta=1e4),
    frontend="audio",
    frontend_len=1024,
    act="gelu",
)
