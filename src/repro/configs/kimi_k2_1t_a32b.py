"""Kimi K2 — trillion-param MoE (paper-table config). [arXiv:2501.kimi2; unverified]

61L d_model=7168 64H (GQA kv=8) d_ff_expert=2048 vocab=163840, 384 experts
top-8. ~1.03T total / ~32B active params.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, MoPConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    d_ff=2048,
    vocab_size=163840,
    attention=AttentionConfig(
        num_heads=64, num_kv_heads=8, head_dim=112, rope_theta=5e6),
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25),
    mop=MoPConfig(enabled=True, bits=4, group_size=64, num_q_experts=0),
    act="swiglu",
)
