"""Minitron-4B — pruned Nemotron. [arXiv:2407.14679; hf]

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    d_ff=9216,
    vocab_size=256000,
    attention=AttentionConfig(num_heads=24, num_kv_heads=8, head_dim=128,
                              rope_theta=1e4),
    act="swiglu",
)
