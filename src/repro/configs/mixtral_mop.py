"""The paper's serving configuration: Mixtral 8x7B + Mixture-of-Precisions.

Defaults match the paper's mid-range operating point: half the experts
4-bit (128/256), planner enabled with a 40 GB HBM budget.
"""
import dataclasses

from repro.configs.base import MoPConfig
from repro.configs.mixtral_8x7b import CONFIG as _BASE

CONFIG = _BASE.replace(
    arch_id="mixtral-mop",
    mop=MoPConfig(enabled=True, bits=4, group_size=64, num_q_experts=128,
                  hbm_budget_gb=40.0),
)
