"""RWKV6 (Finch) 3B — attention-free, data-dependent decay. [arXiv:2404.05892; hf]

32L d_model=2560 d_ff=8960 vocab=65536, head size 64 (40 heads).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", state_dim=64, head_dim=64, chunk_size=128),
    act="relu_sq",
)
