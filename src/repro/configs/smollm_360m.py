"""SmolLM-360M — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    d_ff=2560,
    vocab_size=49152,
    attention=AttentionConfig(num_heads=15, num_kv_heads=5, head_dim=64,
                              rope_theta=1e4),
    act="swiglu",
)
