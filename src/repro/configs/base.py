"""Config dataclasses for every architecture family in the zoo.

Pure-python dataclasses (no flax) — a ModelConfig fully determines parameter
shapes, sharding rules and the step functions built in ``repro.models.model``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """Routed mixture-of-experts FFN (the paper's substrate)."""
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # Router jitter / z-loss are training-time details.
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class MoPConfig:
    """Mixture-of-Precisions serving plan defaults (the paper's contribution).

    ``num_q_experts`` counts 4-bit experts across the whole model (paper's
    Num_E4 knob, 0..num_layers*num_experts). Assignment is balanced-random:
    the same count per layer (see DESIGN.md §2).

    ``ladder`` declares the precision rungs a serving deployment may
    assign per expert (descending, must contain 16; DESIGN.md §11).
    ``None`` resolves to the binary ladder ``(16, bits)`` — bit-identical
    to the historical boolean plans. Set ``(16, 8, 4)`` to open the
    per-expert mixed-precision configuration space.
    """
    enabled: bool = False
    bits: int = 4                  # legacy single quantized rung (4 or 8)
    group_size: int = 64           # quantization group along the reduction dim
    num_q_experts: int = 0         # global Num_E4 (paper eq. 1 output)
    ladder: Optional[Tuple[int, ...]] = None
    # Serving-time placement knobs (host vs HBM residency).
    hbm_budget_gb: Optional[float] = None

    @property
    def precision_ladder(self) -> Tuple[int, ...]:
        """The resolved ladder: declared ``ladder`` or ``(16, bits)``."""
        return tuple(self.ladder) if self.ladder else (16, self.bits)


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"           # "mamba2" | "rwkv6"
    state_dim: int = 64            # N (mamba2) / head_dim (rwkv6 K==V dim)
    head_dim: int = 64             # P per SSM head
    expand: int = 2                # d_inner = expand * d_model (mamba2)
    chunk_size: int = 128          # chunked-scan block length


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # SWA width (Mixtral: 4096)
    rope_theta: float = 1e6
    causal: bool = True


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense|moe|ssm|hybrid|encdec|vlm|audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mop: MoPConfig = field(default_factory=MoPConfig)

    # Encoder-decoder (seamless): encoder depth; num_layers == decoder depth.
    num_encoder_layers: int = 0
    # Hybrid (zamba2): one shared attention block applied every k layers.
    attn_every: int = 0
    # Modality frontend stub: "none"|"audio"|"vision"; frontend emits
    # precomputed embeddings of length frontend_len (per spec).
    frontend: str = "none"
    frontend_len: int = 0

    act: str = "swiglu"            # swiglu|gelu|relu_sq
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Pad the embedding/logits vocab so it shards evenly on the model axis
    # and tiles the MXU; logits beyond vocab_size are masked in the loss.
    vocab_pad_multiple: int = 2048
    scan_layers: bool = True       # scan over stacked layer params (O(1) HLO)
    remat: str = "none"            # none|full|dots — activation checkpointing

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def attn_dim(self) -> int:
        a = self.attention
        return a.num_heads * a.head_dim if a else 0

    # ----- parameter counting (used by planner + roofline) -----
    def param_count(self) -> int:
        return sum(math.prod(s) for _, s in self.param_shapes())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        e = self.moe
        per_expert = 3 * self.d_model * e.d_ff_expert
        experts_total = self.num_layers * e.num_experts * per_expert
        experts_active = self.num_layers * e.top_k * per_expert
        return total - experts_total + experts_active

    def expert_param_bytes(self, bits: int = 16) -> int:
        """Size of ONE expert in bytes at the given precision (paper Size_E*)."""
        if self.moe is None:
            return 0
        n = 3 * self.d_model * self.moe.d_ff_expert
        if bits == 16:
            return n * 2
        # packed weights + bf16 group scales
        g = self.mop.group_size
        return n * bits // 8 + (n // g) * 2

    def non_expert_bytes(self) -> int:
        if self.moe is None:
            return self.param_count() * 2
        per_expert = 3 * self.d_model * self.moe.d_ff_expert
        return (self.param_count()
                - self.num_layers * self.moe.num_experts * per_expert) * 2

    def param_shapes(self):
        """(name, shape) for every parameter — single source of truth used by
        init, sharding rules, and the analytic roofline."""
        out = []
        d, v = self.d_model, self.padded_vocab
        out.append(("embed/table", (v, d)))
        out.append(("final_norm/scale", (d,)))
        if not self.tie_embeddings:
            out.append(("lm_head/table", (v, d)))
        if self.num_encoder_layers:
            for nm, sh in self._block_shapes(kind="encoder"):
                out.append((f"encoder/{nm}", (self.num_encoder_layers,) + sh))
            out.append(("encoder_norm/scale", (d,)))
        kind = {"ssm": self.ssm.kind if self.ssm else "mamba2"}.get(
            self.family, "decoder")
        if self.family == "ssm":
            kind = self.ssm.kind
        elif self.family == "hybrid":
            kind = "mamba2"
        for nm, sh in self._block_shapes(kind=kind):
            out.append((f"layers/{nm}", (self.num_layers,) + sh))
        if self.family == "hybrid" and self.attn_every:
            for nm, sh in self._block_shapes(kind="shared_attn"):
                out.append((f"shared/{nm}", sh))
        return out

    def _attn_shapes(self, cross: bool = False):
        a = self.attention
        d, hd = self.d_model, a.head_dim
        pre = "cross_" if cross else ""
        sh = [
            (f"{pre}attn/wq", (d, a.num_heads * hd)),
            (f"{pre}attn/wk", (d, a.num_kv_heads * hd)),
            (f"{pre}attn/wv", (d, a.num_kv_heads * hd)),
            (f"{pre}attn/wo", (a.num_heads * hd, d)),
            (f"{pre}attn_norm/scale", (d,)),
        ]
        if a.qk_norm:
            sh += [(f"{pre}attn/q_norm", (hd,)), (f"{pre}attn/k_norm", (hd,))]
        return sh

    def _ffn_shapes(self):
        d = self.d_model
        if self.moe is not None:
            e = self.moe
            return [
                ("moe/router", (d, e.num_experts)),
                ("moe/w_gate", (e.num_experts, d, e.d_ff_expert)),
                ("moe/w_up", (e.num_experts, d, e.d_ff_expert)),
                ("moe/w_down", (e.num_experts, e.d_ff_expert, d)),
                ("ffn_norm/scale", (d,)),
            ]
        f = self.d_ff
        sh = [("mlp/w_up", (d, f)), ("mlp/w_down", (f, d)),
              ("ffn_norm/scale", (d,))]
        if self.act == "swiglu":
            sh.insert(0, ("mlp/w_gate", (d, f)))
        return sh

    def _ssm_shapes(self):
        s = self.ssm
        d = self.d_model
        if s.kind == "rwkv6":
            hd = s.head_dim
            h = d // hd
            lora = 64
            return [
                ("rwkv/w_r", (d, d)), ("rwkv/w_k", (d, d)),
                ("rwkv/w_v", (d, d)), ("rwkv/w_g", (d, d)),
                ("rwkv/w_o", (d, d)),
                ("rwkv/decay_lora_a", (d, lora)),
                ("rwkv/decay_lora_b", (lora, d)),
                ("rwkv/decay_base", (d,)),
                ("rwkv/bonus", (h, hd)),
                ("rwkv/ln_x", (d,)),
                ("rwkv/mix", (5, d)),            # token-shift mixing coeffs
                ("attn_norm/scale", (d,)),        # pre-norm of time-mix
                ("rwkv/ffn_k", (d, self.d_ff)),
                ("rwkv/ffn_v", (self.d_ff, d)),
                ("rwkv/ffn_r", (d, d)),
                ("rwkv/ffn_mix", (2, d)),
                ("ffn_norm/scale", (d,)),
            ]
        # mamba2
        di = s.expand * d
        h = di // s.head_dim
        return [
            ("mamba/w_in", (d, 2 * di + 2 * s.state_dim + h)),  # x,z,B,C,dt
            ("mamba/w_out", (di, d)),
            ("mamba/A_log", (h,)),
            ("mamba/D", (h,)),
            ("mamba/dt_bias", (h,)),
            ("mamba/conv", (4, di + 2 * s.state_dim)),
            ("mamba/norm", (di,)),
            ("attn_norm/scale", (d,)),
        ]

    def _block_shapes(self, kind: str):
        if kind in ("decoder", "encoder"):
            sh = list(self._attn_shapes())
            if kind == "decoder" and self.num_encoder_layers:
                sh += self._attn_shapes(cross=True)
            return sh + self._ffn_shapes()
        if kind == "mamba2":
            return self._ssm_shapes()
        if kind == "rwkv6":
            return self._ssm_shapes()
        if kind == "shared_attn":
            # zamba2: one attention+MLP block shared across depths
            sh = list(self._attn_shapes())
            d, f = self.d_model, self.d_ff
            sh += [("mlp/w_gate", (d, f)), ("mlp/w_up", (d, f)),
                   ("mlp/w_down", (f, d)), ("ffn_norm/scale", (d,))]
            return sh
        raise ValueError(kind)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Input shapes assigned to the LM family (spec: 4 shapes, per-arch skips).
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic decode state — DESIGN.md §6).
LONG_CONTEXT_ARCHS = ("zamba2-7b", "rwkv6-3b", "mixtral-8x7b")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.arch_id in LONG_CONTEXT_ARCHS
    return True


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        num_layers=2, d_model=64, d_ff=128, vocab_size=512,
        vocab_pad_multiple=64, scan_layers=True,
    )
    if cfg.attention:
        a = cfg.attention
        kw["attention"] = dataclasses.replace(
            a, num_heads=4, num_kv_heads=max(1, min(a.num_kv_heads, 2)),
            head_dim=16,
            sliding_window=64 if a.sliding_window else None)
    if cfg.moe:
        # capacity_factor=8 -> no token dropping at smoke scale, so the
        # decode==prefill invariant holds exactly
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            capacity_factor=8.0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk_size=16)
    if cfg.num_encoder_layers:
        kw["num_encoder_layers"] = 2
    if cfg.attn_every:
        kw["attn_every"] = 2
    if cfg.frontend != "none":
        kw["frontend_len"] = 8
    if cfg.mop.enabled:
        kw["mop"] = dataclasses.replace(cfg.mop, group_size=16)
    return cfg.replace(**kw)
