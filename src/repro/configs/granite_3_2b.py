"""Granite-3.0-2B — dense GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    d_ff=8192,
    vocab_size=49155,
    attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=64,
                              rope_theta=1e4),
    act="swiglu",
)
