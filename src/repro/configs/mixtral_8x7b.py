"""Mixtral 8x7B — the paper's evaluation model. [arXiv:2401.04088; hf]

32L d_model=4096 32H (GQA kv=8) d_ff_expert=14336 vocab=32000, 8 experts
top-2, sliding-window attention (4096).
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, MoPConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=32000,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=8, head_dim=128,
        sliding_window=4096, rope_theta=1e6),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
    mop=MoPConfig(enabled=True, bits=4, group_size=64, num_q_experts=0),
    act="swiglu",
)
