"""PaliGemma-3B — SigLIP + Gemma backbone. [arXiv:2407.07726; hf]

Text backbone: 18L d_model=2048 8H (MQA kv=1, head_dim 256) d_ff=16384
vocab=257216. The SigLIP vision tower is a STUB per spec: input_specs()
provides 256 precomputed patch embeddings prepended to the text sequence.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=257216,
    attention=AttentionConfig(num_heads=8, num_kv_heads=1, head_dim=256,
                              rope_theta=1e4),
    frontend="vision",
    frontend_len=256,
    act="gelu",
)
