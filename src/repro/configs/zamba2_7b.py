"""Zamba2-7B — hybrid Mamba2 + shared attention blocks. [arXiv:2411.15242; unverified]

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Every 6th layer applies ONE shared attention+MLP block (Zamba's
parameter-sharing trick); the rest are Mamba2 blocks.
"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32000,
    attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=112,
                              rope_theta=1e4),
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2,
                  chunk_size=128),
    attn_every=6,
    act="swiglu",
)
