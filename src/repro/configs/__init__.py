"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    AttentionConfig, LONG_CONTEXT_ARCHS, ModelConfig, MoEConfig, MoPConfig,
    SHAPES, ShapeConfig, SSMConfig, reduce_for_smoke, shape_applicable,
)

_MODULES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen3-8b": "qwen3_8b",
    "minitron-4b": "minitron_4b",
    "granite-3-2b": "granite_3_2b",
    "smollm-360m": "smollm_360m",
    "zamba2-7b": "zamba2_7b",
    "rwkv6-3b": "rwkv6_3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mixtral-8x7b": "mixtral_8x7b",
    "paligemma-3b": "paligemma_3b",
    "mixtral-mop": "mixtral_mop",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "mixtral-mop")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_cells():
    """Every runnable (arch, shape) dry-run cell — DESIGN.md §6."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape_applicable(cfg, shape):
                yield arch, shape.name
