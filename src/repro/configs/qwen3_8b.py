"""Qwen3-8B — dense, qk_norm + GQA. [hf:Qwen/Qwen3-8B; hf]

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    d_ff=12288,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=8, head_dim=128, qk_norm=True,
        rope_theta=1e6),
    act="swiglu",
)
