"""Precision-ladder plan API (DESIGN.md §11).

Two contracts are pinned here:

* **compat** — with the binary ladder ``(16, 4)`` the redesigned
  ``bits[L, E]`` encoding reproduces the pre-redesign boolean plans
  bit-identically: frontier records match the checked-in golden fixture
  byte-for-byte, ``balanced_ladder_plan`` consumes the rng exactly like
  the legacy ``balanced_random_plan``, and the derived ``quant``/
  ``num_q_experts``/``bank_sizes()`` views keep their historical values.
* **dominance** — the 3-rung ladder ``(16, 8, 4)`` opens configurations
  the binary space cannot express; its frontier must contain at least
  one point STRICTLY dominating a binary-frontier point on the
  (device bytes ↓, quality ↑, tokens/s ↑) axes.
"""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import HardwareModel, estimate_qos
from repro.core.pareto import ParetoFrontier, QoSTarget
from repro.core.planner import AdaptivePlanner
from repro.core.precision_plan import (
    DEVICE, balanced_ladder_plan, balanced_random_plan, delta_cost_bytes,
    migrated_expert_keys, quantized_rungs, reconfig_delta, validate_ladder,
)

MIXTRAL = get_config("mixtral-8x7b")
LADDER3 = MIXTRAL.replace(
    mop=dataclasses.replace(MIXTRAL.mop, ladder=(16, 8, 4)))
FIXTURE = Path(__file__).parent / "fixtures" \
    / "frontier_mixtral-8x7b_hw-default_b1_s0.json"


@pytest.fixture(scope="module")
def binary_frontier():
    return ParetoFrontier(MIXTRAL)


@pytest.fixture(scope="module")
def ladder_frontier():
    return ParetoFrontier(LADDER3)


def _strictly_dominates(a, b) -> bool:
    ge = (a.qos.tokens_per_s >= b.qos.tokens_per_s
          and a.qos.quality_proxy <= b.qos.quality_proxy
          and a.qos.device_bytes <= b.qos.device_bytes)
    gt = (a.qos.tokens_per_s > b.qos.tokens_per_s
          or a.qos.quality_proxy < b.qos.quality_proxy
          or a.qos.device_bytes < b.qos.device_bytes)
    return ge and gt


class TestLadderValidation:
    def test_accepts_supported_ladders(self):
        assert validate_ladder((16, 4)) == (16, 4)
        assert validate_ladder((16, 8, 4)) == (16, 8, 4)
        assert validate_ladder((16, 8)) == (16, 8)

    @pytest.mark.parametrize("bad", [
        (4, 16), (16, 16, 4), (8, 4), (16, 2), (16,), (16, 12, 4),
    ])
    def test_rejects_malformed(self, bad):
        with pytest.raises(ValueError):
            validate_ladder(bad)

    def test_quantized_rungs_ascending(self):
        assert quantized_rungs((16, 8, 4)) == (4, 8)
        assert quantized_rungs((16, 4)) == (4,)


class TestBinaryCompat:
    """Ladder (16, 4) must reproduce today's binary plans bit-identically
    — the API contract of the redesign (ISSUE 4 acceptance)."""

    def test_frontier_records_match_checked_in_golden_fixture(
            self, binary_frontier):
        """The strongest compat statement: the enumerated dominant set of
        the DEFAULT (binary-ladder) config equals the fixture generated
        BEFORE the redesign, byte for byte (hex floats + plan sha256 over
        the boolean view)."""
        assert FIXTURE.exists()
        golden = json.loads(FIXTURE.read_text())
        assert binary_frontier.records() == golden

    @pytest.mark.parametrize("nq,res,seed", [
        (0, None, 0), (64, 100, 0), (128, 64, 3), (256, 256, 7),
    ])
    def test_ladder_plan_rng_identical_to_legacy(self, nq, res, seed):
        """balanced_ladder_plan({4: nq}, ladder=(16,4)) consumes the rng
        exactly like the legacy boolean assignment."""
        legacy = balanced_random_plan(32, 8, nq, seed=seed,
                                      resident_experts=res)
        ladder = balanced_ladder_plan(32, 8, {4: nq}, ladder=(16, 4),
                                      seed=seed, resident_experts=res)
        assert (legacy.bits == ladder.bits).all()
        assert (legacy.location == ladder.location).all()

    def test_derived_boolean_views(self):
        p = balanced_random_plan(4, 8, 16, seed=1)
        assert p.quant.dtype == bool
        assert (p.quant == (p.bits == 4)).all()
        assert p.num_q_experts == 16
        assert p.num_q_per_layer == 4
        assert p.bank_sizes() == (4, 4)          # (E4, E16)
        assert p.q_bits == 4

    def test_planner_counts_spelling_matches_num_q(self):
        pl = AdaptivePlanner(MIXTRAL)
        a = pl.plan(40 * 2**30, "quality", num_q_experts=128)
        b = pl.plan(40 * 2**30, "quality", counts={4: 128})
        assert (a.plan.bits == b.plan.bits).all()
        assert (a.plan.location == b.plan.location).all()


class TestThreeRungLadder:
    def test_enumeration_covers_mixed_counts(self, ladder_frontier):
        combos = {p.counts_per_rung for p in ladder_frontier.all_points}
        # pure corners present ...
        total = MIXTRAL.num_layers * MIXTRAL.moe.num_experts
        assert (total, 0, 0) in combos
        assert (0, total, 0) in combos
        assert (0, 0, total) in combos
        # ... and genuinely mixed rung assignments
        assert any(c[1] > 0 and c[2] > 0 for c in combos)

    def test_per_layer_counts_balanced_and_banks_static(self):
        plan = balanced_ladder_plan(8, 8, {4: 16, 8: 24}, ladder=(16, 8, 4),
                                    seed=2)
        for l in range(8):
            assert int((plan.bits[l] == 4).sum()) == 2
            assert int((plan.bits[l] == 8).sum()) == 3
        assert plan.bank_sizes() == (2, 3, 3)    # ascending bits
        order = plan.expert_order()
        for l in range(8):
            assert sorted(order[l]) == list(range(8))
            assert (plan.bits[l, order[l][:2]] == 4).all()
            assert (plan.bits[l, order[l][2:5]] == 8).all()
            assert (plan.bits[l, order[l][5:]] == 16).all()

    def test_quality_proxy_orders_rungs(self):
        """Same count at a higher rung must cost less quality."""
        qos = {}
        for rung in (4, 8):
            plan = balanced_ladder_plan(
                32, 8, {rung: 128}, ladder=(16, 8, 4), seed=0,
                resident_experts=256)
            qos[rung] = estimate_qos(LADDER3, plan)
        assert qos[8].quality_proxy < qos[4].quality_proxy
        assert qos[8].device_bytes > qos[4].device_bytes
        assert qos[8].tokens_per_s < qos[4].tokens_per_s

    def test_frontier_point_plans_bit_identical_to_planner(
            self, ladder_frontier):
        """The engine apply path: planner.plan(point bytes, 'quality',
        counts=point.quantized_counts()) must reproduce a mixed-rung
        frontier point's plan exactly."""
        pl = AdaptivePlanner(LADDER3)
        mixed = [p for p in ladder_frontier.points
                 if p.quantized_counts().get(4, 0)
                 and p.quantized_counts().get(8, 0)]
        assert mixed, "ladder frontier lost all mixed-rung points"
        for p in mixed[:: max(1, len(mixed) // 5)]:
            r = pl.plan(float(p.qos.device_bytes), "quality",
                        counts=p.quantized_counts())
            assert (r.plan.bits == p.plan.bits).all()
            assert (r.plan.location == p.plan.location).all()
            assert r.qos.device_bytes == p.qos.device_bytes

    def test_ladder_frontier_strictly_dominates_a_binary_point(
            self, binary_frontier, ladder_frontier):
        """ISSUE 4 acceptance: the 3-rung frontier contains >= 1 point
        strictly dominating some binary-frontier point on the
        (bytes, quality, tokens/s) axes."""
        assert any(
            _strictly_dominates(p, b)
            for p in ladder_frontier.points for b in binary_frontier.points)

    def test_select_can_land_on_a_mid_rung(self, ladder_frontier):
        """A tight quality ceiling that only int8 can meet under a small
        budget: the declarative surface reaches the new rung."""
        t = QoSTarget(max_quality_loss=0.025, min_tokens_per_s=5.0,
                      mem_budget_bytes=40 * 2**30)
        p = ladder_frontier.select(t)
        assert p.quantized_counts().get(8, 0) > 0

    def test_records_carry_rung_counts(self, ladder_frontier):
        recs = ladder_frontier.records()
        assert all(r["ladder"] == [16, 8, 4] for r in recs)
        assert all(sum(r["counts_per_rung"])
                   == MIXTRAL.num_layers * MIXTRAL.moe.num_experts
                   for r in recs)


class TestLadderReconfig:
    def test_promote_4_to_8_charges_delta(self):
        """A rung promotion in place (same residency) migrates exactly
        the flipped experts, each at its NEW size — the
        delta_cost_bytes contract for ladder moves."""
        a = balanced_ladder_plan(4, 8, {4: 8}, ladder=(16, 8, 4), seed=0,
                                 resident_experts=32)
        b = balanced_ladder_plan(4, 8, {8: 8}, ladder=(16, 8, 4), seed=0,
                                 resident_experts=32)
        delta = reconfig_delta(a, b)
        flipped = np.argwhere(a.bits != b.bits)
        keys = migrated_expert_keys(delta, b)
        assert len(keys) == len(flipped)
        cost = delta_cost_bytes(delta, MIXTRAL.expert_param_bytes, b)
        s8 = MIXTRAL.expert_param_bytes(8)
        s4 = MIXTRAL.expert_param_bytes(4)
        # same seed -> the same experts flip 4->8 AND 8->4 is empty:
        # every migrated expert streams at the 8-bit size
        n_promoted = int((b.bits[tuple(flipped.T)] == 8).sum())
        n_demoted = len(flipped) - n_promoted
        assert cost == n_promoted * s8 + n_demoted * s4

    def test_pruned_enumeration_stays_tractable_at_scale(self):
        """kimi-scale (61 layers x 384 experts) with a 3-rung ladder:
        the §11 pruning rule keeps the enumerated space bounded while
        preserving the pure corners."""
        cfg = get_config("kimi-k2-1t-a32b")
        cfg = cfg.replace(mop=dataclasses.replace(cfg.mop, ladder=(16, 8, 4)))
        f = ParetoFrontier(cfg, HardwareModel(), residency_step=None,
                           max_enum_points=4096)
        assert len(f.all_points) <= 4096
        e = cfg.moe.num_experts
        for levels in f.count_levels.values():
            assert levels[0] == 0 and levels[-1] == e
