"""VirtualClock guards + event heap, and the sim/real engine metric-key
parity contract (DESIGN.md §14.2).

The control plane schedules its replayable trace on the clock's event
heap and charges every accumulated ``*_s`` metric off clock deltas, so
monotonicity violations must raise instead of silently rewinding; and
controllers written against the real engine's ``metrics`` dict must see
the same key set on the simulated one (the set drifted twice before the
shared schema in ``repro.serving.metrics`` pinned it).
"""
import math

import pytest

from repro.serving.metrics import ENGINE_METRIC_SCHEMA, base_metrics
from repro.serving.simulator import SimulatedEngine, VirtualClock


class TestVirtualClock:
    def test_advance_and_now(self):
        c = VirtualClock()
        assert c.now() == 0.0
        assert c.advance(2.5) == 2.5
        assert c.now() == 2.5

    def test_negative_advance_raises(self):
        c = VirtualClock(10.0)
        with pytest.raises(ValueError, match="forward"):
            c.advance(-1e-9)
        assert c.now() == 10.0

    def test_nan_advance_raises(self):
        c = VirtualClock()
        with pytest.raises(ValueError):
            c.advance(math.nan)

    def test_advance_to_backwards_raises(self):
        c = VirtualClock(5.0)
        with pytest.raises(ValueError, match="forward"):
            c.advance_to(4.999)
        assert c.advance_to(5.0) == 5.0   # no-op jump is legal
        with pytest.raises(ValueError):
            c.advance_to(math.nan)

    def test_schedule_into_past_raises(self):
        c = VirtualClock(100.0)
        with pytest.raises(ValueError, match="past"):
            c.schedule_at(99.0, "late")

    def test_heap_orders_by_time_then_insertion(self):
        c = VirtualClock()
        c.schedule_at(30.0, "c")
        c.schedule_at(10.0, "a1")
        c.schedule_at(10.0, "a2")    # same instant: FIFO
        c.schedule_at(20.0, "b")
        assert c.peek() == 10.0
        assert c.pending() == 4
        c.advance_to(20.0)
        assert c.pop_due() == ["a1", "a2", "b"]
        assert c.pending() == 1
        assert c.pop_due() == []     # nothing else due yet
        c.advance_to(50.0)
        assert c.pop_due() == ["c"]
        assert c.peek() is None

    def test_pop_due_until_clamped_to_now(self):
        c = VirtualClock()
        c.schedule_at(10.0, "x")
        # an `until` beyond now must not release future events
        assert c.pop_due(until=99.0) == []
        c.advance_to(10.0)
        assert c.pop_due(until=5.0) == []
        assert c.pop_due(until=10.0) == ["x"]


class TestMetricParity:
    def test_simulated_engine_has_full_schema(self):
        eng = SimulatedEngine()
        assert set(eng.metrics) == set(ENGINE_METRIC_SCHEMA)

    def test_base_metrics_returns_fresh_typed_zeros(self):
        a, b = base_metrics(), base_metrics()
        assert a == b and a is not b
        for k, v in a.items():
            assert v == 0
            assert type(v) is type(ENGINE_METRIC_SCHEMA[k])

    def test_parity_keys_cover_transfer_and_kv_accounting(self):
        # the two historic drift points: PR 5 transfer split, PR 6 kv
        for k in ("transfer_exposed_s", "transfer_overlapped_s",
                  "kv_allocated_bytes", "kv_used_bytes",
                  "kv_alloc_byte_iters", "kv_used_byte_iters",
                  "kv_capacity_bytes"):
            assert k in ENGINE_METRIC_SCHEMA

    def test_real_engine_matches_schema(self):
        jax = pytest.importorskip("jax")
        from repro.configs import get_config, reduce_for_smoke
        from repro.models.model import build_model
        from repro.serving.engine import AdaptiveServingEngine
        cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = AdaptiveServingEngine(cfg, params, max_batch=2, max_len=24)
        # construction-time key set IS the contract (keys added lazily
        # after a reconfig — last_migrated_* — are excluded, see
        # repro/serving/metrics.py)
        assert set(eng.metrics) == set(ENGINE_METRIC_SCHEMA)
        for k, v in ENGINE_METRIC_SCHEMA.items():
            assert type(eng.metrics[k]) is type(v), k
