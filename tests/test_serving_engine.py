"""Integration tests for the adaptive serving engine (the paper's Fig. 1
system): plan -> serve -> replan with minimal downtime, plus the async
overlap pipeline (DESIGN.md §12) and the temporal-locality prefetch
path."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.serving.api import EngineConfig
from repro.serving.engine import AdaptiveServingEngine


@pytest.fixture(scope="module")
def smoke():
    cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def engine(smoke):
    cfg, params = smoke
    return AdaptiveServingEngine(cfg, params, max_batch=2, max_len=24)


def _full_size(engine):
    return engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16


class TestEngine:
    def test_requires_configure(self, engine):
        engine.submit(np.array([1, 2, 3]), max_new_tokens=2)
        with pytest.raises(RuntimeError):
            engine.step()
        engine.queue.clear()

    def test_serve_roundtrip(self, engine):
        engine.configure(_full_size(engine) * 1.1, "throughput")
        rid = engine.submit(np.array([5, 6, 7, 8]), max_new_tokens=4)
        assert engine.step() == 1
        req = engine.done[rid]
        assert len(req.out_tokens) == 4
        assert all(0 <= t < engine.cfg.vocab_size for t in req.out_tokens)

    def test_generation_plan_invariant(self, engine):
        """Greedy tokens must be identical for (all-16bit resident) vs
        (all-16bit partially offloaded): placement NEVER changes outputs."""
        prompt = np.array([3, 1, 4, 1, 5])
        outs = []
        for frac in (1.2, 0.4):
            engine.configure(_full_size(engine) * frac, "quality",
                             num_q_experts=0)
            rid = engine.submit(prompt, max_new_tokens=4)
            engine.step()
            outs.append(engine.done[rid].out_tokens)
        assert outs[0] == outs[1]

    def test_infeasible_budget_raises(self, engine):
        with pytest.raises(ValueError, match="infeasible"):
            engine.configure(engine.planner.size_ne * 0.5, "throughput")

    def test_quantized_plan_changes_outputs_slightly(self, engine):
        """4-bit experts perturb logits; the engine must still produce
        valid tokens and track the miss-rate estimate."""
        ne = engine.planner.size_ne
        expert_bytes = _full_size(engine) - ne
        engine.configure(ne + expert_bytes * 0.25, "throughput")
        assert engine.planner.current.plan.num_q_experts > 0
        rid = engine.submit(np.array([2, 7, 1]), max_new_tokens=3)
        engine.step()
        assert len(engine.done[rid].out_tokens) == 3
        assert 0.0 <= engine.metrics["miss_rate"] < 1.0

    def test_reconfig_is_cached_per_signature(self, engine):
        engine.configure(_full_size(engine) * 1.1, "quality",
                         num_q_experts=0)
        n0 = engine.metrics["reconfigs"]
        params_before = engine._serve_params
        engine.configure(_full_size(engine) * 1.05, "quality",
                         num_q_experts=0)   # same bank split -> no rebuild
        assert engine.metrics["reconfigs"] == n0 + 1
        # placement-only change: serve-layout params were NOT rebuilt
        assert engine._serve_params is params_before

    def test_throughput_accounting(self, engine):
        engine.configure(_full_size(engine) * 1.1, "throughput")
        engine.submit(np.arange(1, 5), max_new_tokens=2)
        engine.step()
        assert engine.throughput_tokens_per_s() > 0
        assert engine.metrics["tokens_generated"] > 0
        # serial staging: every transferred second was exposed
        assert engine.metrics["transfer_exposed_s"] == pytest.approx(
            engine.metrics["transfer_s"] + engine.metrics["prefetch_s"])
        assert engine.metrics["transfer_overlapped_s"] == 0.0


class TestTemporalLocalityPrefetch:
    """The engine's gate-ahead path (DESIGN.md §2/§12): each iteration
    hints the PREVIOUS iteration's demanded experts, re-staging anything
    the LRU evicted since, so the follow-up demand hits."""

    @pytest.fixture(scope="class")
    def prefetch_engine(self, smoke):
        cfg, params = smoke
        # tiny swap (2 experts) -> heavy churn -> evicted prev-demanded
        # keys exist for the hint path to re-stage
        eng = AdaptiveServingEngine(
            cfg, params, config=EngineConfig(
                max_slots=2, max_len=24, prefetch=True,
                swap_bytes=2 * cfg.expert_param_bytes(16)))
        full = eng.planner.size_ne + \
            eng.planner.num_experts_total * eng.planner.size_e16
        with pytest.warns(DeprecationWarning):
            eng.configure(full * 0.4, "quality", num_q_experts=0)
        eng.submit(np.array([3, 1, 4, 1, 5]), max_new_tokens=10)
        eng.step()
        return eng

    def test_prev_demanded_restaged_after_eviction(self, prefetch_engine):
        st = prefetch_engine.expert_cache.stats
        # the hint path actually re-staged evicted prev-demanded experts
        assert st.prefetch_bytes > 0
        assert st.evictions > 0
        # and tracks the working set between iterations
        assert prefetch_engine._prev_demanded

    def test_speculative_traffic_split_in_metrics(self, prefetch_engine):
        m = prefetch_engine.metrics
        st = prefetch_engine.expert_cache.stats
        assert m["prefetch_s"] == st.prefetch_s
        assert m["transfer_s"] == st.transfer_s       # demand only
        # measured miss rate counts DEMAND fetches only
        assert m["expert_fetches"] <= m["expert_accesses"]
        assert 0.0 <= m["miss_rate_measured"] <= 1.0
        # sync staging: hint + demand transfers all block -> all exposed
        assert m["transfer_exposed_s"] == pytest.approx(
            st.transfer_s + st.prefetch_s)
        assert "prefetch" in prefetch_engine.summary()

    def test_hints_reset_on_replan(self, prefetch_engine, smoke):
        cfg, _ = smoke
        full = prefetch_engine.planner.size_ne + \
            prefetch_engine.planner.num_experts_total * \
            prefetch_engine.planner.size_e16
        with pytest.warns(DeprecationWarning):
            prefetch_engine.configure(full * 0.5, "quality",
                                      num_q_experts=cfg.num_layers
                                      * cfg.moe.num_experts)
        assert prefetch_engine._prev_demanded == []
        assert prefetch_engine._prev_layer_keys is None


class TestOverlapPipeline:
    """Async overlapped streaming through the REAL engine (DESIGN.md
    §12): the per-layer pipeline must not change outputs, must account
    exposed vs hidden transfer time, and close() must join workers."""

    @pytest.fixture(scope="class")
    def overlap_engine(self, smoke):
        cfg, params = smoke
        return AdaptiveServingEngine(
            cfg, params,
            config=EngineConfig(max_slots=2, max_len=24, overlap=True))

    def test_pipeline_active_and_async_cache(self, overlap_engine):
        assert overlap_engine._pipeline
        assert overlap_engine.expert_cache.is_async
        assert overlap_engine.hw.overlap_efficiency > 0

    def test_generation_identical_to_sync_engine(self, engine,
                                                 overlap_engine):
        """The per-layer pipeline is the same primitive sequence as the
        scanned step — greedy generations must MATCH the sync engine
        under an identical partially-offloaded plan."""
        prompt = np.array([3, 1, 4, 1, 5])
        outs = []
        for eng in (engine, overlap_engine):
            eng.configure(_full_size(eng) * 0.4, "quality",
                          num_q_experts=0)
            rid = eng.submit(prompt, max_new_tokens=4)
            eng.step()
            outs.append(eng.done[rid].out_tokens)
        assert outs[0] == outs[1]

    def test_overlap_metrics_and_streaming(self, overlap_engine):
        m = overlap_engine.metrics
        assert m["expert_accesses"] > 0
        assert m["transfer_exposed_s"] >= 0.0
        assert m["transfer_overlapped_s"] >= 0.0
        assert m["miss_rate_measured"] > 0     # partially offloaded plan
        assert "exposed" in overlap_engine.summary()

    def test_calibrate_overlap_updates_hw_and_frontier(self,
                                                       overlap_engine):
        f0 = overlap_engine.frontier
        eff = overlap_engine.calibrate_overlap()
        assert eff is not None and 0.0 <= eff <= 1.0
        assert overlap_engine.hw.overlap_efficiency == eff
        assert overlap_engine.frontier is not f0   # re-ranked lazily

    def test_close_joins_transfer_workers(self, overlap_engine):
        overlap_engine.close()
        leaked = [t for t in threading.enumerate()
                  if t.name.startswith("expert-xfer") and t.is_alive()]
        assert not leaked
        overlap_engine.close()                      # idempotent
