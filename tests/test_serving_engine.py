"""Integration tests for the adaptive serving engine (the paper's Fig. 1
system): plan -> serve -> replan with minimal downtime."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.serving.engine import AdaptiveServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return AdaptiveServingEngine(cfg, params, max_batch=2, max_len=24)


def _full_size(engine):
    return engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16


class TestEngine:
    def test_requires_configure(self, engine):
        engine.submit(np.array([1, 2, 3]), max_new_tokens=2)
        with pytest.raises(RuntimeError):
            engine.step()
        engine.queue.clear()

    def test_serve_roundtrip(self, engine):
        engine.configure(_full_size(engine) * 1.1, "throughput")
        rid = engine.submit(np.array([5, 6, 7, 8]), max_new_tokens=4)
        assert engine.step() == 1
        req = engine.done[rid]
        assert len(req.out_tokens) == 4
        assert all(0 <= t < engine.cfg.vocab_size for t in req.out_tokens)

    def test_generation_plan_invariant(self, engine):
        """Greedy tokens must be identical for (all-16bit resident) vs
        (all-16bit partially offloaded): placement NEVER changes outputs."""
        prompt = np.array([3, 1, 4, 1, 5])
        outs = []
        for frac in (1.2, 0.4):
            engine.configure(_full_size(engine) * frac, "quality",
                             num_q_experts=0)
            rid = engine.submit(prompt, max_new_tokens=4)
            engine.step()
            outs.append(engine.done[rid].out_tokens)
        assert outs[0] == outs[1]

    def test_infeasible_budget_raises(self, engine):
        with pytest.raises(ValueError, match="infeasible"):
            engine.configure(engine.planner.size_ne * 0.5, "throughput")

    def test_quantized_plan_changes_outputs_slightly(self, engine):
        """4-bit experts perturb logits; the engine must still produce
        valid tokens and track the miss-rate estimate."""
        ne = engine.planner.size_ne
        expert_bytes = _full_size(engine) - ne
        engine.configure(ne + expert_bytes * 0.25, "throughput")
        assert engine.planner.current.plan.num_q_experts > 0
        rid = engine.submit(np.array([2, 7, 1]), max_new_tokens=3)
        engine.step()
        assert len(engine.done[rid].out_tokens) == 3
        assert 0.0 <= engine.metrics["miss_rate"] < 1.0

    def test_reconfig_is_cached_per_signature(self, engine):
        engine.configure(_full_size(engine) * 1.1, "quality",
                         num_q_experts=0)
        n0 = engine.metrics["reconfigs"]
        params_before = engine._serve_params
        engine.configure(_full_size(engine) * 1.05, "quality",
                         num_q_experts=0)   # same bank split -> no rebuild
        assert engine.metrics["reconfigs"] == n0 + 1
        # placement-only change: serve-layout params were NOT rebuilt
        assert engine._serve_params is params_before

    def test_throughput_accounting(self, engine):
        engine.configure(_full_size(engine) * 1.1, "throughput")
        engine.submit(np.arange(1, 5), max_new_tokens=2)
        engine.step()
        assert engine.throughput_tokens_per_s() > 0
        assert engine.metrics["tokens_generated"] > 0
