"""Grouped GQA (§Perf kimi/smollm iterations) must be numerically
identical to the flat expand-K/V path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import _sdpa


def make_qkv(seed, b, sq, sk, h, hkv, hd):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, hkv, hd), jnp.float32)
    mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)[None, None]
    return q, k, v, jnp.broadcast_to(mask, (b, 1, sq, sk))


class TestGroupedEqualsFlat:
    @pytest.mark.parametrize("h,hkv", [(15, 5), (32, 8), (8, 1), (64, 8)])
    def test_equivalence(self, h, hkv):
        q, k, v, mask = make_qkv(0, 2, 8, 16, h, hkv, 16)
        flat = _sdpa(q, k, v, mask, "attn_scores_full", grouped=False)
        grp = _sdpa(q, k, v, mask, "attn_scores_full", grouped=True)
        np.testing.assert_allclose(np.asarray(flat), np.asarray(grp),
                                   rtol=2e-5, atol=2e-5)

    def test_chunked_long_query(self):
        # sq > 2 * _Q_CHUNK triggers the scan path in both modes
        from repro.models import layers as L
        old = L._Q_CHUNK
        L._Q_CHUNK = 8
        try:
            q, k, v, mask = make_qkv(1, 1, 32, 32, 6, 2, 8)
            flat = _sdpa(q, k, v, mask, "attn_scores_full", grouped=False)
            grp = _sdpa(q, k, v, mask, "attn_scores_full", grouped=True)
            np.testing.assert_allclose(np.asarray(flat), np.asarray(grp),
                                       rtol=2e-5, atol=2e-5)
        finally:
            L._Q_CHUNK = old

    @settings(deadline=None, max_examples=15)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([(4, 2), (6, 3),
                                                       (8, 2)]))
    def test_equivalence_property(self, seed, heads):
        h, hkv = heads
        q, k, v, mask = make_qkv(seed, 1, 4, 8, h, hkv, 8)
        flat = _sdpa(q, k, v, mask, "attn_scores_full", grouped=False)
        grp = _sdpa(q, k, v, mask, "attn_scores_full", grouped=True)
        np.testing.assert_allclose(np.asarray(flat), np.asarray(grp),
                                   rtol=3e-5, atol=3e-5)

    def test_gradients_match(self):
        q, k, v, mask = make_qkv(2, 1, 4, 8, 6, 2, 8)

        def loss(mode, q, k, v):
            out = _sdpa(q, k, v, mask, "attn_scores_full", grouped=mode)
            return jnp.sum(out.astype(jnp.float32) ** 2)

        gf = jax.grad(lambda *a: loss(False, *a), argnums=(0, 1, 2))(q, k, v)
        gg = jax.grad(lambda *a: loss(True, *a), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gf, gg):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)
