"""Async overlapped expert streaming (DESIGN.md §12), end to end over
the analytic stack: the overlap-aware token time, frontier re-ranking
(a point dominated under the additive model becomes dominant), the
deterministic simulator's sync/async A/B, and the control loops charging
EXPOSED — not total — transfer time."""
import dataclasses

import numpy as np
import pytest

from helpers import make_route_fn
from repro.configs import get_config
from repro.core.cost_model import HardwareModel, estimate_qos
from repro.core.pareto import ParetoFrontier, QoSTarget
from repro.core.planner import AdaptivePlanner
from repro.serving.qos import QoSController, QoSControllerConfig
from repro.serving.simulator import SimulatedEngine, run_scripted

MIXTRAL = get_config("mixtral-8x7b")

#: A100-class constants on a fast (NVLink-C2C-ish) host link with a slow
#: bnb-style 4-bit matmul: the regime where the additive model produces
#: genuinely DOMINATED configurations (fast link keeps transfer-heavy
#: points competitive; the q4 compute penalty lets cheaper-byte points
#: outrun more-quantized ones), so overlap can re-rank membership.
OVERLAP_HW = HardwareModel(
    peak_flops=312e12, hbm_bw=2.0e12, host_link_bw=100e9,
    hbm_bytes=80e9, mbu=0.17,
    q4_speedup_decode=0.3, q8_speedup_decode=0.9)


def _key(p):
    return (p.num_q_experts, p.resident_experts)


@pytest.fixture(scope="module")
def additive():
    return ParetoFrontier(MIXTRAL, OVERLAP_HW)


@pytest.fixture(scope="module")
def overlapped(additive):
    return additive.overlap_variant(1.0)


class TestOverlapCostModel:
    def test_zero_efficiency_is_bitwise_additive(self):
        """overlap_efficiency=0 must reproduce the serial additive token
        time BIT-FOR-BIT (the frontier golden fixture depends on it)."""
        plan = AdaptivePlanner(MIXTRAL).plan(40e9, "throughput").plan
        base = estimate_qos(MIXTRAL, plan, HardwareModel())
        explicit = estimate_qos(MIXTRAL, plan,
                                HardwareModel(overlap_efficiency=0.0))
        assert base.tokens_per_s == explicit.tokens_per_s
        assert base.t_exposed_ms == base.t_transfer_ms
        # and the additive identity itself holds
        t_token = (base.t_compute_ms + base.t_transfer_ms) / 1e3
        assert base.tokens_per_s == pytest.approx(1.0 / t_token, rel=1e-12)

    def test_full_overlap_hides_transfer_up_to_compute(self):
        planner = AdaptivePlanner(MIXTRAL, hw=OVERLAP_HW)
        plan = planner.plan(10e9, "throughput").plan   # offloading region
        add = estimate_qos(MIXTRAL, plan, OVERLAP_HW)
        ov = estimate_qos(
            MIXTRAL, plan,
            dataclasses.replace(OVERLAP_HW, overlap_efficiency=1.0))
        assert add.t_transfer_ms > 0          # actually transfer-bound
        assert ov.t_exposed_ms == pytest.approx(
            max(0.0, ov.t_transfer_ms - ov.t_compute_ms))
        assert ov.tokens_per_s > add.tokens_per_s
        # quality/footprint axes are untouched by overlap
        assert ov.device_bytes == add.device_bytes
        assert ov.quality_proxy == add.quality_proxy

    def test_planner_recalibrate_clears_frontiers(self):
        planner = AdaptivePlanner(MIXTRAL, hw=OVERLAP_HW)
        f0 = planner.frontier()
        planner.recalibrate(
            dataclasses.replace(OVERLAP_HW, overlap_efficiency=0.9))
        f1 = planner.frontier()
        assert f1 is not f0
        assert f1.hw.overlap_efficiency == 0.9


class TestOverlapFrontier:
    def test_dominated_point_becomes_dominant(self, additive, overlapped):
        """The acceptance criterion: a configuration DOMINATED under the
        additive token time (its exposed transfer made it strictly worse
        than some cheaper/faster point) enters the dominant set once
        transfers hide under compute."""
        dominant_add = {_key(p) for p in additive.points}
        dominated_add = [p for p in additive.all_points
                        if _key(p) not in dominant_add]
        assert dominated_add, "hw regime must produce dominated points"
        dominant_ov = {_key(p) for p in overlapped.points}
        flipped = [p for p in dominated_add if _key(p) in dominant_ov]
        assert flipped, ("no additive-dominated point became dominant "
                        "under the overlap-aware model")
        # the flip is explained by transfer hiding: the flipped point is
        # transfer-bound, and its overlap estimate strictly improves
        p = flipped[0]
        assert p.qos.t_transfer_ms > 0
        ov_p = next(q for q in overlapped.all_points if _key(q) == _key(p))
        assert ov_p.qos.tokens_per_s > p.qos.tokens_per_s

    def test_overlap_variant_zero_is_identity_ranking(self, additive):
        same = additive.overlap_variant(0.0)
        assert [_key(p) for p in same.points] == \
            [_key(p) for p in additive.points]
        assert [p.qos.tokens_per_s for p in same.points] == \
            [p.qos.tokens_per_s for p in additive.points]

    def test_select_prefers_newly_viable_point_under_tight_budget(
            self, additive, overlapped):
        """Overlap lets a smaller-footprint point meet a tokens/s floor
        that the additive model needed more resident bytes for."""
        floor = min(p.qos.tokens_per_s for p in additive.points
                    if p.qos.t_transfer_ms > 0) * 1.5
        target = QoSTarget(min_tokens_per_s=floor)
        add_pick = additive.select(target)
        ov_pick = overlapped.select(target)
        assert ov_pick.qos.tokens_per_s >= floor
        assert ov_pick.qos.device_bytes <= add_pick.qos.device_bytes


def transfer_bound_point(frontier):
    """A frontier point whose transfer exceeds its compute (the paper's
    offloading region)."""
    return next(p for p in frontier.points
                if p.qos.t_transfer_ms > p.qos.t_compute_ms)


def make_ab_engines(point, iterations=32):
    """Identical scripted compute+transfer timings, overlap off vs on.
    Both engines replay the SAME deterministic routed trace (the shared
    tests/helpers.py builder), so the A/B also pins that overlap moves
    time, not traffic."""
    num_layers, num_experts = point.plan.bits.shape
    out = {}
    for mode in ("sync", "async"):
        eng = SimulatedEngine(
            batch=1,
            throughput_fn=lambda p, i: 1e3 / p.qos.t_compute_ms,
            transfer_fn=lambda p, i: p.qos.t_transfer_ms / 1e3,
            route_fn=make_route_fn(num_layers, num_experts,
                                   MIXTRAL.moe.top_k, alpha=1.2,
                                   tokens_per_iter=4, seed=11),
            overlap=(mode == "async"), overlap_efficiency=1.0)
        eng.apply_frontier_point(point)
        for _ in range(iterations):
            eng.run_iteration()
        out[mode] = eng
    return out["sync"], out["async"]


class TestSimulatedOverlapAB:
    def test_async_strictly_faster_on_transfer_bound_config(self, additive):
        """Acceptance criterion: with the simulator's scriptable timings
        a transfer-bound config shows async tokens/s strictly greater
        than sync, and transfer_exposed_s < transfer_s."""
        point = transfer_bound_point(additive)
        sync, async_ = make_ab_engines(point)
        def tps(e):
            m = e.metrics
            return m["tokens_generated"] / (m["decode_s"]
                                            + m["transfer_exposed_s"])
        assert tps(async_) > tps(sync)
        assert async_.metrics["transfer_exposed_s"] \
            < async_.metrics["transfer_s"]
        # serial staging exposes everything
        assert sync.metrics["transfer_exposed_s"] == \
            pytest.approx(sync.metrics["transfer_s"])
        # both moved the same bytes — overlap hides time, not traffic
        assert async_.metrics["transfer_s"] == \
            pytest.approx(sync.metrics["transfer_s"])
        # the virtual clock agrees: async wall-clock is strictly shorter
        assert async_.clock.now() < sync.clock.now()
        # same deterministic routed trace on both sides: overlap hides
        # transfer time, it never changes WHICH experts were accessed
        assert sync.route_counts.sum() > 0
        np.testing.assert_array_equal(sync.route_counts,
                                      async_.route_counts)

    def test_fully_hidden_transfer_reaches_compute_bound_rate(self, additive):
        point = next(p for p in additive.points
                     if 0 < p.qos.t_transfer_ms <= p.qos.t_compute_ms)
        _, async_ = make_ab_engines(point, iterations=8)
        m = async_.metrics
        assert m["transfer_exposed_s"] == 0.0
        assert m["tokens_generated"] / m["decode_s"] == pytest.approx(
            1e3 / point.qos.t_compute_ms)


class TestControlLoopsUseExposedTime:
    def test_controller_measures_exposed_not_total(self, additive):
        """The same scripted timings read as ON-target through the async
        pipeline and BELOW-target through serial staging — the
        controller must charge only exposed transfer time. The point's
        transfer hides completely (t_transfer <= t_compute), so the
        async measurement is exactly the compute-bound rate."""
        point = next(p for p in additive.points
                     if 0 < p.qos.t_transfer_ms <= p.qos.t_compute_ms)
        compute_tps = 1e3 / point.qos.t_compute_ms
        # dwell > run length: measure only, never walk (a walk would
        # switch the scripted point mid-run)
        cfg = QoSControllerConfig(tolerance=0.05, min_dwell_iterations=100,
                                  window_iterations=2)
        target = QoSTarget(min_tokens_per_s=compute_tps * 0.95)
        measured = {}
        for mode in ("sync", "async"):
            eng = SimulatedEngine(
                batch=1,
                throughput_fn=lambda p, i: 1e3 / p.qos.t_compute_ms,
                transfer_fn=lambda p, i: p.qos.t_transfer_ms / 1e3,
                overlap=(mode == "async"), overlap_efficiency=1.0)
            ctl = QoSController(eng, additive, cfg)
            ctl.target = target
            ctl.point = point
            eng.apply_frontier_point(point)
            run_scripted(eng, ctl, 8)
            measured[mode] = ctl.metrics["last_measured_tps"]
        assert measured["async"] == pytest.approx(compute_tps, rel=1e-6)
        assert measured["sync"] < measured["async"]

    def test_arbiter_derate_follows_exposed_time(self, additive):
        """MultiTenantEngine.step derives each tenant's derate from the
        controller's exposed-time measurement: an overlap tenant with
        fully hidden transfers derates toward compute-bound truth, not
        toward the additive model's pessimism."""
        from repro.serving.multi import MultiTenantEngine, TenantSpec
        point = transfer_bound_point(additive)
        mt = MultiTenantEngine(
            200e9, controller_config=QoSControllerConfig(
                min_dwell_iterations=4, window_iterations=2))
        eng = SimulatedEngine(
            batch=1,
            throughput_fn=lambda p, i: 1e3 / p.qos.t_compute_ms,
            transfer_fn=lambda p, i: p.qos.t_transfer_ms / 1e3,
            overlap=True, overlap_efficiency=1.0)
        # unconstrained target: the controller measures but never walks
        # (a walk would change the scripted point mid-run)
        t = mt.add_tenant(TenantSpec("a", QoSTarget()), eng, additive)
        t.controller.adopt(t.spec.target, point)
        for _ in range(8):
            eng.run_iteration()
            mt.step()
        # transfer-bound + full overlap: per-token wall time collapses
        # from (t_compute + t_transfer) to t_transfer alone
        expected_measured = 1e3 / point.qos.t_transfer_ms
        expected = expected_measured / point.qos.tokens_per_s
        assert t.derate == pytest.approx(expected, rel=1e-6)
        assert t.derate > 1.0      # overlap beats the additive estimate
