"""EP parity suite (DESIGN.md §4 / §16).

Token-gather EP dispatch (§Perf kimi iteration B1): numerical
equivalence with the dense oracle and with the weight-gather path, plus
the regime gate. Extended for the EP serving mesh: decode over a
(1, ep) mesh must be BIT-identical to the single-device loop for
EP ∈ {1, 2, 4} — on binary and mixed (16, 8, 4) plans, and across a
replan that migrates experts between EP ranks (bank membership change).
Every multi-device case runs in a subprocess that forces the host
device count BEFORE importing jax."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import mixed_moe as MM

pytestmark = pytest.mark.skipif(
    jax.device_count() != 1, reason="spawns its own multi-device subprocess")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp, numpy as np
from repro.core import mixed_moe as MM
from repro.configs.base import MoEConfig

mesh = jax.make_mesh((4, 4), ("data", "model"))
from repro.launch.mesh import use_mesh
moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, capacity_factor=8.0)
d, t = 32, 16
ks = jax.random.split(jax.random.key(0), 5)
params = {
    "router": jax.random.normal(ks[0], (d, 8), jnp.float32) * 0.1,
    "w_gate": jax.random.normal(ks[1], (8, d, 64), jnp.bfloat16) * 0.1,
    "w_up": jax.random.normal(ks[2], (8, d, 64), jnp.bfloat16) * 0.1,
    "w_down": jax.random.normal(ks[3], (8, 64, d), jnp.bfloat16) * 0.1,
}
x = jax.random.normal(ks[4], (t, d), jnp.bfloat16)
ref = MM.moe_dense_ref(params, x, moe)
banks16 = {"q4": None,
           "f16": {k: params[k] for k in ("w_gate", "w_up", "w_down")}}
w, ids, _ = MM.route(params["router"], x, moe, train=False)
outs = {}
with use_mesh(mesh):
    for fsdp in (None, "data"):
        par = MM.MoEParallelism(mesh=mesh, dp_axes=("data",),
                                fsdp_axis=fsdp)
        y = MM.moe_apply(banks16, x, w, ids, moe, par)
        err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        outs[str(fsdp)] = err
for k, v in outs.items():
    print(f"RESULT {k} {v:.6f}")
assert all(v < 5e-3 for v in outs.values()), outs
print("OK")
"""


_PARITY_SCRIPT = r"""
import contextlib
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduce_for_smoke
from repro.core.precision_plan import balanced_ladder_plan
from repro.launch.mesh import make_ep_mesh, use_mesh
from repro.models.model import apply_precision_plan, build_model

cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
L, E, gs = cfg.num_layers, cfg.moe.num_experts, cfg.mop.group_size
base = build_model(cfg)
params = base.init(jax.random.key(0))
# per-layer bank sizes must divide by the largest ep under test (4)
plans = {
    "binary": balanced_ladder_plan(L, E, {4: 4 * L},
                                   ladder=(16, 4), group_size=gs),
    "mixed": balanced_ladder_plan(L, E, {4: 4 * L, 8: 4 * L},
                                  ladder=(16, 8, 4), group_size=gs),
    "replan": balanced_ladder_plan(L, E, {4: 8 * L},
                                   ladder=(16, 4), group_size=gs),
}
tok = np.asarray(jax.random.randint(jax.random.key(1), (2, 8), 1,
                                    cfg.vocab_size))
ref = {}
for name, plan in plans.items():
    sp = apply_precision_plan(params, cfg, plan)
    for ep in (1, 2, 4):
        mesh = None if ep == 1 else make_ep_mesh(ep)
        model = build_model(cfg, mesh)
        ctx = use_mesh(mesh) if mesh is not None \
            else contextlib.nullcontext()
        with ctx:
            cache = model.init_cache(2, 24)
            logits, cache = model.prefill(sp, {"tokens": jnp.asarray(tok)},
                                          cache)
            chunks = [np.asarray(jax.device_get(logits)).tobytes()]
            cur = jnp.argmax(logits, -1)[:, None]
            pos = jnp.full((2,), tok.shape[1], jnp.int32)
            for step in range(4):
                logits, cache = model.decode_step(sp, cache, cur,
                                                  pos + step)
                chunks.append(np.asarray(jax.device_get(logits)).tobytes())
                cur = jnp.argmax(logits, -1)[:, None]
        blob = b"".join(chunks)
        if ep == 1:
            ref[name] = blob
        assert blob == ref[name], f"{name}: ep={ep} diverges from ep=1"
    print(f"PARITY {name} OK")
# the replan moved every f16 expert into the q4 bank: bank membership
# changed, so the contiguous per-bank sharding migrates experts between
# EP ranks -- and decode stayed bit-identical on both sides (above)
a = plans["binary"].device_assignment(4)
b = plans["replan"].device_assignment(4)
assert (a != b).any(), "replan migrated no expert between EP ranks"
print("MIGRATION OK")
print("OK")
"""

_ENGINE_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings
import jax, numpy as np
from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.serving.api import EngineConfig
from repro.serving.ep import build_ep_engine

cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
model = build_model(cfg)
params = model.init(jax.random.key(0))
outs = {}
for ep in (1, 2):
    eng = build_ep_engine(cfg, params,
                          EngineConfig(max_slots=2, max_len=16), ep=ep)
    full = eng.planner.size_ne + \
        eng.planner.num_experts_total * eng.planner.size_e16
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng.configure(full, "quality", 4 * cfg.num_layers)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(1, cfg.vocab_size, 6),
                       max_new_tokens=4) for _ in range(3)]
    eng.step(temperature=0.0)
    # mid-deployment replan: every expert drops to q4, bank membership
    # changes, experts migrate between EP ranks
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng.configure(full, "quality", 8 * cfg.num_layers)
    rids2 = [eng.submit(rng.integers(1, cfg.vocab_size, 6),
                        max_new_tokens=4) for _ in range(3)]
    eng.step(temperature=0.0)
    outs[ep] = ([eng.result(r).tokens for r in rids],
                [eng.result(r).tokens for r in rids2])
    eng.close()
assert outs[1] == outs[2], outs
print("OK")
"""


def _run_sub(script, timeout=900):
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


class TestEPDecodeParity:
    """Serving-mesh bit-identity (DESIGN.md §16, ISSUE acceptance)."""

    def test_decode_bit_identical_across_ep(self):
        """prefill + 4 greedy decode steps: logits BYTES equal for
        EP ∈ {1, 2, 4}, on binary and mixed (16, 8, 4) plans, plus the
        rank-migration assertion across a replan."""
        r = _run_sub(_PARITY_SCRIPT)
        assert "OK" in r.stdout and "MIGRATION OK" in r.stdout, \
            r.stdout + r.stderr

    def test_engine_tokens_identical_across_ep(self):
        """Full engine (scheduler + paged KV + replan) on a (1, 2) mesh
        generates the same greedy tokens as the single-device engine,
        including after a rung replan that migrates experts."""
        r = _run_sub(_ENGINE_PARITY_SCRIPT)
        assert "OK" in r.stdout, r.stdout + r.stderr


class TestTokenGatherEP:
    def test_matches_oracle_on_mesh(self):
        env = dict(os.environ,
                   PYTHONPATH=os.path.abspath(
                       os.path.join(os.path.dirname(__file__), "..", "src")))
        env.pop("XLA_FLAGS", None)
        r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                           capture_output=True, text=True, timeout=300)
        assert "OK" in r.stdout, r.stdout + r.stderr

    def test_regime_gate_decode_vs_train(self):
        """Gate math: decode token sets gather; train-scale don't."""
        d = 7168
        fs = 16
        decode_tokens = 128 // 16          # per dp rank
        train_tokens = 65536 // 16
        assert decode_tokens * fs * d * 2 <= MM.TOKEN_GATHER_MAX_BYTES
        assert train_tokens * fs * d * 2 > MM.TOKEN_GATHER_MAX_BYTES

    def test_fsdp_inactive_without_axis(self):
        """fsdp never activates on a 1-device mesh / without the axis."""
        moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64)
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = jax.sharding.Mesh(dev, ("data", "model"))
        par = MM.MoEParallelism(mesh=mesh, dp_axes=("data",),
                                fsdp_axis="data")
        assert par.fsdp_size == 1
        banks = {"q4": None,
                 "f16": {"w_gate": jnp.zeros((8, 32, 64), jnp.bfloat16),
                         "w_up": jnp.zeros((8, 32, 64), jnp.bfloat16),
                         "w_down": jnp.zeros((8, 64, 32), jnp.bfloat16)}}
        assert not MM._fsdp_active(banks, moe, par, ep=True)
