"""Token-gather EP dispatch (§Perf kimi iteration B1): numerical
equivalence with the dense oracle and with the weight-gather path, plus
the regime gate."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import mixed_moe as MM

pytestmark = pytest.mark.skipif(
    jax.device_count() != 1, reason="spawns its own multi-device subprocess")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp, numpy as np
from repro.core import mixed_moe as MM
from repro.configs.base import MoEConfig

mesh = jax.make_mesh((4, 4), ("data", "model"))
from repro.launch.mesh import use_mesh
moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, capacity_factor=8.0)
d, t = 32, 16
ks = jax.random.split(jax.random.key(0), 5)
params = {
    "router": jax.random.normal(ks[0], (d, 8), jnp.float32) * 0.1,
    "w_gate": jax.random.normal(ks[1], (8, d, 64), jnp.bfloat16) * 0.1,
    "w_up": jax.random.normal(ks[2], (8, d, 64), jnp.bfloat16) * 0.1,
    "w_down": jax.random.normal(ks[3], (8, 64, d), jnp.bfloat16) * 0.1,
}
x = jax.random.normal(ks[4], (t, d), jnp.bfloat16)
ref = MM.moe_dense_ref(params, x, moe)
banks16 = {"q4": None,
           "f16": {k: params[k] for k in ("w_gate", "w_up", "w_down")}}
w, ids, _ = MM.route(params["router"], x, moe, train=False)
outs = {}
with use_mesh(mesh):
    for fsdp in (None, "data"):
        par = MM.MoEParallelism(mesh=mesh, dp_axes=("data",),
                                fsdp_axis=fsdp)
        y = MM.moe_apply(banks16, x, w, ids, moe, par)
        err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        outs[str(fsdp)] = err
for k, v in outs.items():
    print(f"RESULT {k} {v:.6f}")
assert all(v < 5e-3 for v in outs.values()), outs
print("OK")
"""


class TestTokenGatherEP:
    def test_matches_oracle_on_mesh(self):
        env = dict(os.environ,
                   PYTHONPATH=os.path.abspath(
                       os.path.join(os.path.dirname(__file__), "..", "src")))
        env.pop("XLA_FLAGS", None)
        r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                           capture_output=True, text=True, timeout=300)
        assert "OK" in r.stdout, r.stdout + r.stderr

    def test_regime_gate_decode_vs_train(self):
        """Gate math: decode token sets gather; train-scale don't."""
        d = 7168
        fs = 16
        decode_tokens = 128 // 16          # per dp rank
        train_tokens = 65536 // 16
        assert decode_tokens * fs * d * 2 <= MM.TOKEN_GATHER_MAX_BYTES
        assert train_tokens * fs * d * 2 > MM.TOKEN_GATHER_MAX_BYTES

    def test_fsdp_inactive_without_axis(self):
        """fsdp never activates on a 1-device mesh / without the axis."""
        moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64)
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = jax.sharding.Mesh(dev, ("data", "model"))
        par = MM.MoEParallelism(mesh=mesh, dp_axes=("data",),
                                fsdp_axis="data")
        assert par.fsdp_size == 1
        banks = {"q4": None,
                 "f16": {"w_gate": jnp.zeros((8, 32, 64), jnp.bfloat16),
                         "w_up": jnp.zeros((8, 32, 64), jnp.bfloat16),
                         "w_down": jnp.zeros((8, 64, 32), jnp.bfloat16)}}
        assert not MM._fsdp_active(banks, moe, par, ep=True)
