"""Unit tests for the HLO collective parser (roofline input)."""
import pytest

from repro.roofline.hlo_parse import (collective_summary, comp_multipliers,
                                      shape_bytes)

SYNTH = """\
HloModule jit_step, num_partitions=16

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %ar = f32[128,256]{1,0} all-reduce(%x), to_apply=%add
  %ag = f32[128,512]{1,0} all-gather(%x), dimensions={1}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256]{1,0} parameter(0)
  %big = bf16[1024,1024]{1,0} all-reduce(%x2), to_apply=%add
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%zero, %x)
  %w = (s32[], f32[128,256]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


class TestShapeBytes:
    def test_basic(self):
        assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
        assert shape_bytes("bf16[8]") == 16
        assert shape_bytes("s8[4,4]") == 16
        assert shape_bytes("f32[]") == 4

    def test_tuple(self):
        assert shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


class TestSummary:
    def test_trip_count_weighting(self):
        mult = comp_multipliers(SYNTH)
        assert mult.get("body") == 12

    def test_collective_bytes(self):
        s = collective_summary(SYNTH)
        # 12 loop iterations x (AR 128*256*4) + entry AR 1024*1024*2
        assert s["all-reduce_bytes"] == 12 * 128 * 256 * 4 + 1024 * 1024 * 2
        assert s["all-reduce_count"] == 13
        # all-gather counts the gathered result
        assert s["all-gather_bytes"] == 12 * 128 * 512 * 4
        assert s["total_bytes"] == (s["all-reduce_bytes"]
                                    + s["all-gather_bytes"])

    def test_known_trip_count_attr_preferred(self):
        hlo = SYNTH.replace(
            "condition=%cond, body=%body",
            'condition=%cond, body=%body, backend_config='
            '{"known_trip_count":{"n":"7"}}')
        assert comp_multipliers(hlo).get("body") == 7

    def test_no_collectives(self):
        s = collective_summary("ENTRY %e (x: f32[2]) -> f32[2] {\n"
                               "  ROOT %x = f32[2]{0} parameter(0)\n}\n")
        assert s["total_bytes"] == 0
