"""Multi-tenant serving under one shared budget (serving/multi.py,
DESIGN.md §10), driven entirely on the deterministic simulator
(serving/simulator.py): joint water-filling arbitration, exactly-once
re-arbitration on a global budget shift, partial (diff-only) expert
migration, and violation-driven joint rebalancing."""
import math

import pytest

from repro.configs import get_config
from repro.core.pareto import InfeasibleTarget, ParetoFrontier, QoSTarget
from repro.core.precision_plan import migrated_expert_keys, reconfig_delta
from repro.serving.multi import (GlobalBudgetInfeasible, MultiTenantEngine,
                                 ResourceArbiter, TenantSpec)
from repro.serving.qos import QoSControllerConfig
from repro.serving.simulator import SimulatedEngine, VirtualClock

MIXTRAL = get_config("mixtral-8x7b")
GIB = 2**30

CTL = QoSControllerConfig(tolerance=0.1, min_dwell_iterations=4,
                          window_iterations=2)


@pytest.fixture(scope="module")
def frontier():
    return ParetoFrontier(MIXTRAL)


def make_mt(frontier, budget_gib, specs_errors, **kw):
    """MultiTenantEngine over simulated tenants sharing one virtual
    clock; specs_errors = [(TenantSpec, model_error), ...]."""
    clock = VirtualClock()
    mt = MultiTenantEngine(budget_gib * GIB, controller_config=CTL, **kw)
    engines = []
    for spec, err in specs_errors:
        eng = SimulatedEngine(model_error=err, clock=clock)
        mt.add_tenant(spec, eng, frontier)
        engines.append(eng)
    return mt, engines


def run_joint(mt, engines, iterations):
    for _ in range(iterations):
        for eng in engines:
            eng.run_iteration()
        mt.step()


INTERACTIVE = TenantSpec("interactive", QoSTarget(min_tokens_per_s=20.0))
BATCH = TenantSpec("batch", QoSTarget(min_tokens_per_s=1.0,
                                      max_quality_loss=0.0))


class TestJointArbitration:
    def test_distinct_points_under_shared_budget(self, frontier):
        """Two tenants with different SLOs (a tokens/s-hungry interactive
        tenant, a quality-pinned batch tenant) land on DISTINCT frontier
        points whose joint footprint fits the shared budget, and stay
        there (one arbitration, no further replans) when measurements
        match the model."""
        mt, (eng_i, eng_b) = make_mt(
            frontier, 40.0, [(INTERACTIVE, 1.0), (BATCH, 1.0)])
        sel = mt.arbitrate()
        assert sel["interactive"] is not sel["batch"]
        assert (sel["interactive"].qos.device_bytes
                + sel["batch"].qos.device_bytes) <= 40 * GIB
        # each SLO shaped its own point
        assert sel["interactive"].qos.tokens_per_s >= 20.0
        assert sel["batch"].qos.quality_proxy == 1.0
        run_joint(mt, [eng_i, eng_b], 100)
        # converged: measured throughput holds each tenant's target
        assert eng_i.point is sel["interactive"]
        assert eng_b.point is sel["batch"]
        assert mt.metrics["arbitrations"] == 1
        assert eng_i.replans == 1 and eng_b.replans == 1
        ctl_i = mt.tenants["interactive"].controller
        assert ctl_i.metrics["last_measured_tps"] \
            >= 20.0 * (1 - CTL.tolerance)
        assert ctl_i.metrics["violations"] == 0

    def test_budget_shrink_exactly_one_rearbitration(self, frontier):
        """A global budget shrink triggers EXACTLY one joint
        re-arbitration — the downsized tenant migrates once, the other
        keeps its point, and no replan storm follows."""
        specs = [(TenantSpec("interactive",
                             QoSTarget(min_tokens_per_s=8.0)), 1.0),
                 (BATCH, 1.0)]
        mt, engines = make_mt(frontier, 40.0, specs)
        sel0 = mt.arbitrate()
        run_joint(mt, engines, 30)
        assert mt.metrics["arbitrations"] == 1
        replans0 = mt.metrics["replans"]

        assert mt.set_budget(20.0 * GIB) is True
        assert mt.metrics["arbitrations"] == 2      # the one re-arbitration
        sel1 = {n: t.point for n, t in mt.tenants.items()}
        assert sel1["interactive"] is not sel0["interactive"]
        assert sel1["batch"] is sel0["batch"]       # untouched tenant
        assert (sel1["interactive"].qos.device_bytes
                + sel1["batch"].qos.device_bytes) <= 20 * GIB
        # exactly one tenant replanned, with a partial migration report
        assert mt.metrics["replans"] == replans0 + 1
        assert mt.reports[-1].tenant == "interactive"
        assert 0 < mt.reports[-1].migrated_experts \
            < MIXTRAL.num_layers * MIXTRAL.moe.num_experts
        # quiet afterwards: still meeting floors -> no storm
        run_joint(mt, engines, 80)
        assert mt.metrics["arbitrations"] == 2
        assert mt.metrics["replans"] == replans0 + 1
        assert mt.tenants["interactive"].controller.metrics[
            "last_measured_tps"] >= 8.0 * (1 - CTL.tolerance)

    def test_placement_only_replan_migrates_only_the_diff(self, frontier):
        """A budget change that moves a tenant along the residency axis
        (same bank split) must migrate EXACTLY the experts the plan diff
        names — not the full expert set (the paper's partial runtime
        reconfiguration)."""
        spec = TenantSpec("pinned", QoSTarget(min_tokens_per_s=math.inf,
                                              max_quality_loss=0.0))
        mt, engines = make_mt(frontier, 14.0, [(spec, 1.0)])
        mt.arbitrate()
        old = mt.tenants["pinned"].point
        mt.set_budget(25.0 * GIB)                   # residency-only grow
        new = mt.tenants["pinned"].point
        assert new is not old
        assert new.plan.bank_sizes() == old.plan.bank_sizes()
        report = mt.reports[-1]
        expected = migrated_expert_keys(
            reconfig_delta(old.plan, new.plan), new.plan)
        total = MIXTRAL.num_layers * MIXTRAL.moe.num_experts
        assert report.placement_only is True
        assert report.migrated_experts == len(expected)
        # the diff is the residency delta, NOT the whole expert set
        assert report.migrated_experts \
            == new.resident_experts - old.resident_experts
        assert 0 < report.migrated_experts < total
        assert report.evicted_experts == 0
        assert report.migrated_bytes > 0 and report.downtime_s > 0

    def test_qos_miss_triggers_joint_rearbitration(self, frontier):
        """A tenant whose measured throughput misses its floor (2x
        cost-model error) reports violations; the arbiter re-arbitrates
        with the observed derate and shifts bytes until the floor holds
        — then goes quiet."""
        specs = [(TenantSpec("interactive",
                             QoSTarget(min_tokens_per_s=8.0)), 0.5),
                 (BATCH, 1.0)]
        mt, engines = make_mt(frontier, 26.0, specs, cooldown_iterations=8)
        mt.arbitrate()
        t = mt.tenants["interactive"]
        assert t.point.qos.tokens_per_s >= 8.0      # analytically fine
        assert t.point.qos.tokens_per_s * 0.5 < 8.0  # measured will miss
        run_joint(mt, engines, 200)
        ctl = t.controller
        assert ctl.metrics["violations"] > 0
        assert mt.metrics["arbitrations"] >= 2       # rebalanced jointly
        assert t.derate == pytest.approx(0.5, rel=1e-6)
        assert ctl.metrics["last_measured_tps"] \
            >= 8.0 * (1 - CTL.tolerance)
        # the joint footprint never overflows the envelope
        used = sum(tt.point.qos.device_bytes for tt in mt.tenants.values())
        assert used <= 26 * GIB

    def test_shared_swap_is_tenant_namespaced(self, frontier):
        """Both tenants get scoped views of ONE shared swap space; their
        identical (layer, expert) ids never collide."""
        mt, _ = make_mt(frontier, 40.0,
                        [(INTERACTIVE, 1.0), (BATCH, 1.0)])
        va = mt.tenants["interactive"].cache_view
        vb = mt.tenants["batch"].cache_view
        assert va.parent is mt.cache and vb.parent is mt.cache
        va.bind_fetch(lambda key: __import__("numpy").zeros(8, "uint8"))
        vb.bind_fetch(lambda key: __import__("numpy").ones(8, "uint8"))
        assert int(va.get((0, 0))[0]) == 0
        assert int(vb.get((0, 0))[0]) == 1          # distinct entry
        assert mt.cache.stats.misses == 2


class TestResourceArbiter:
    def test_deterministic(self, frontier):
        arb = ResourceArbiter()
        entries = [(INTERACTIVE, frontier, 1.0), (BATCH, frontier, 1.0)]
        sel1, used1 = arb.arbitrate(entries, 40 * GIB)
        sel2, used2 = arb.arbitrate(entries, 40 * GIB)
        assert used1 == used2
        assert all(sel1[k] is sel2[k] for k in sel1)

    def test_global_budget_infeasible(self, frontier):
        arb = ResourceArbiter()
        entries = [(INTERACTIVE, frontier, 1.0), (BATCH, frontier, 1.0)]
        with pytest.raises(GlobalBudgetInfeasible):
            arb.arbitrate(entries, 5 * GIB)     # < 2 non-expert floors

    def test_tenant_cap_respected_and_named_on_infeasible(self, frontier):
        spec = TenantSpec("capped", QoSTarget(mem_budget_bytes=1 * GIB))
        with pytest.raises(InfeasibleTarget, match="capped"):
            ResourceArbiter().arbitrate([(spec, frontier, 1.0)], 40 * GIB)

    def test_weight_tilts_water_filling(self, frontier):
        """Same SLO, 3x weight: the heavier tenant wins the marginal
        bytes of a tight budget."""
        heavy = TenantSpec("heavy", QoSTarget(min_tokens_per_s=math.inf),
                           weight=3.0)
        light = TenantSpec("light", QoSTarget(min_tokens_per_s=math.inf),
                           weight=1.0)
        sel, used = ResourceArbiter().arbitrate(
            [(heavy, frontier, 1.0), (light, frontier, 1.0)], 20 * GIB)
        assert used <= 20 * GIB
        assert sel["heavy"].qos.device_bytes > sel["light"].qos.device_bytes
        assert sel["heavy"].qos.tokens_per_s > sel["light"].qos.tokens_per_s

    def test_floor_saturation_spends_surplus_on_quality(self, frontier):
        """Once a finite tokens/s floor is met, additional bytes buy
        QUALITY (lower quality proxy), not more speed — the
        water-filling objective of DESIGN.md §10.2."""
        spec = TenantSpec("t", QoSTarget(min_tokens_per_s=8.0))
        arb = ResourceArbiter()
        sel_small, _ = arb.arbitrate([(spec, frontier, 1.0)], 20 * GIB)
        sel_big, _ = arb.arbitrate([(spec, frontier, 1.0)], 60 * GIB)
        assert sel_small["t"].qos.tokens_per_s >= 8.0
        assert sel_big["t"].qos.tokens_per_s >= 8.0
        assert sel_big["t"].qos.quality_proxy \
            < sel_small["t"].qos.quality_proxy

    def test_duplicate_tenant_rejected(self, frontier):
        mt = MultiTenantEngine(40 * GIB, controller_config=CTL)
        mt.add_tenant(BATCH, SimulatedEngine(), frontier)
        with pytest.raises(ValueError, match="already hosted"):
            mt.add_tenant(BATCH, SimulatedEngine(), frontier)

    def test_zero_weight_rejected(self):
        with pytest.raises(ValueError, match="weight"):
            TenantSpec("t", QoSTarget(), weight=0.0)