"""Optimizers + microbatched train step: convergence & equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.adafactor import adafactor_update, init_adafactor_state
from repro.training.optimizer import (OptConfig, adamw_update, global_norm,
                                      init_opt_state, schedule)
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)


def quad_loss(params, batch):
    # simple convex objective: ||W x - y||^2
    pred = batch["x"] @ params["w"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {"nll": loss}


def make_problem(seed=0, n=64, d=8):
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal((d, 1)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = x @ w_true
    params = {"w": jnp.zeros((d, 1), jnp.float32)}
    return params, {"x": jnp.asarray(x), "y": jnp.asarray(y)}


class TestOptimizers:
    @pytest.mark.parametrize("optname", ["adamw", "adafactor"])
    def test_loss_decreases(self, optname):
        params, batch = make_problem()
        cfg = OptConfig(lr=0.05, warmup_steps=5, total_steps=200,
                        weight_decay=0.0)
        tcfg = TrainConfig(opt=cfg, optimizer=optname, num_microbatches=1)
        state = init_train_state(params, tcfg)
        step = jax.jit(make_train_step(quad_loss, tcfg))
        losses = []
        for _ in range(60):
            params, state, metrics = step(params, state, batch)
            losses.append(float(metrics["nll"]))
        assert losses[-1] < 0.05 * losses[0]

    def test_schedule_shape(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
        vals = [float(schedule(cfg, jnp.asarray(s)))
                for s in (0, 5, 10, 50, 100)]
        assert vals[0] < vals[1] < vals[2] == pytest.approx(1.0)
        assert vals[3] < vals[2] and vals[4] < vals[3]

    def test_grad_clip_applied(self):
        params = {"w": jnp.zeros((2,), jnp.float32)}
        grads = {"w": jnp.asarray([1e6, 1e6], jnp.float32)}
        cfg = OptConfig(lr=1.0, warmup_steps=0, clip_norm=1.0,
                        weight_decay=0.0)
        state = init_opt_state(params)
        newp, _, m = adamw_update(params, grads, state, cfg)
        assert float(m["grad_norm"]) > 1e5
        assert np.abs(np.asarray(newp["w"])).max() < 10.0

    def test_adafactor_memory_shape(self):
        params = {"w": jnp.zeros((64, 32), jnp.float32),
                  "b": jnp.zeros((64,), jnp.float32)}
        st = init_adafactor_state(params)
        assert st["f"]["w"]["vr"].shape == (64,)
        assert st["f"]["w"]["vc"].shape == (32,)
        assert st["f"]["b"]["v"].shape == (64,)


class TestMicrobatching:
    def test_microbatch_equivalent_to_full(self):
        params, batch = make_problem(n=64)
        cfg = OptConfig(lr=0.01, warmup_steps=0, weight_decay=0.0)
        t1 = TrainConfig(opt=cfg, num_microbatches=1,
                         grad_dtype=jnp.float32)
        t4 = TrainConfig(opt=cfg, num_microbatches=4,
                         grad_dtype=jnp.float32)
        s1 = init_train_state(params, t1)
        s4 = init_train_state(params, t4)
        p1, _, _ = jax.jit(make_train_step(quad_loss, t1))(params, s1, batch)
        p4, _, _ = jax.jit(make_train_step(quad_loss, t4))(params, s4, batch)
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                                   rtol=1e-4, atol=1e-6)

    def test_microbatch_on_real_model(self):
        """Reduced smollm: 1-vs-2 microbatch param update must agree."""
        from repro.configs import get_config, reduce_for_smoke
        from repro.models.model import build_model
        cfg = reduce_for_smoke(get_config("smollm-360m"))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                                  jnp.int32),
        }
        ocfg = OptConfig(lr=1e-3, warmup_steps=0)
        outs = []
        for n in (1, 2):
            tcfg = TrainConfig(opt=ocfg, num_microbatches=n,
                               grad_dtype=jnp.float32)
            st = init_train_state(params, tcfg)
            p, _, m = jax.jit(make_train_step(model.loss_fn, tcfg))(
                params, st, batch)
            outs.append((p, float(m["nll"])))
        # losses differ only by batch-split averaging of the metrics
        w1 = np.asarray(outs[0][0]["layers"]["mlp"]["w_up"], np.float32)
        w2 = np.asarray(outs[1][0]["layers"]["mlp"]["w_up"], np.float32)
        np.testing.assert_allclose(w1, w2, rtol=0.1, atol=2e-3)
