"""Shared deterministic routing-trace builders + assertions (DESIGN.md
§15 test harness).

Promoted from the ad-hoc assertions in ``test_routing_capture.py`` so
the sensitivity/dynamic-precision suites, the overlap A/B harness and
the capture tests all validate routed traces the same way, and build
synthetic route streams from one seeded generator.
"""
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["routed_trace", "route_histogram", "zipf_probs",
           "assert_valid_route_trace", "make_route_fn"]


def zipf_probs(num_experts: int, alpha: float = 1.2,
               rotate: int = 0) -> np.ndarray:
    """Zipf-law expert probabilities (expert ``rotate`` hottest, then
    descending by rank)."""
    ranks = np.arange(1, num_experts + 1, dtype=np.float64)
    p = ranks ** -float(alpha)
    p /= p.sum()
    return np.roll(p, rotate)


def routed_trace(tokens: int, num_experts: int, top_k: int, *,
                 alpha: float = 0.0, seed: int = 0,
                 rotate: int = 0) -> np.ndarray:
    """Deterministic synthetic route stream: ``(tokens, top_k)`` int32
    expert ids, DISTINCT per row (like ``top_k`` of a real router).
    ``alpha=0`` is uniform routing; larger alpha skews Zipf-style toward
    low expert indices (``rotate`` shifts the hot set)."""
    if top_k > num_experts:
        raise ValueError(f"top_k {top_k} > num_experts {num_experts}")
    rng = np.random.default_rng(seed)
    p = zipf_probs(num_experts, alpha, rotate) if alpha > 0 \
        else np.full(num_experts, 1.0 / num_experts)
    ids = np.stack([
        rng.choice(num_experts, size=top_k, replace=False, p=p)
        for _ in range(tokens)])
    return ids.astype(np.int32)


def route_histogram(traces: Sequence[np.ndarray],
                    num_experts: int) -> np.ndarray:
    """Per-layer access histogram ``[L, E]`` from per-layer ``(T, k)``
    traces (the shape ``capture_routing`` collects)."""
    out = np.zeros((len(traces), num_experts), np.int64)
    for li, ids in enumerate(traces):
        np.add.at(out[li], np.asarray(ids, np.int64).ravel(), 1)
    return out


def make_route_fn(num_layers: int, num_experts: int, top_k: int, *,
                  alpha: float = 1.2, tokens_per_iter: int = 32,
                  seed: int = 0, rotate_every: int = 0):
    """A ``SimulatedEngine`` ``route_fn`` built from :func:`routed_trace`
    — per-iteration ``[L, E]`` count arrays, deterministic per seed.
    ``rotate_every > 0`` flips the hot set by half the expert grid every
    that many iterations (the hysteresis adversary)."""
    def fn(point, it: int) -> np.ndarray:
        rotate = (num_experts // 2) \
            if rotate_every and (it // rotate_every) % 2 else 0
        traces = [routed_trace(tokens_per_iter, num_experts, top_k,
                               alpha=alpha, seed=seed + 1000 * li + it,
                               rotate=rotate)
                  for li in range(num_layers)]
        return route_histogram(traces, num_experts)

    return fn


def assert_valid_route_trace(ids: np.ndarray, *, tokens: int,
                             top_k: int, num_experts: int,
                             dtype: Optional[type] = np.int32) -> None:
    """The routed-trace contract (promoted from test_routing_capture):
    shape ``(tokens, top_k)``, int32, ids in ``[0, num_experts)`` and
    DISTINCT within each token's top-k."""
    ids = np.asarray(ids)
    assert ids.shape == (tokens, top_k), ids.shape
    if dtype is not None:
        assert ids.dtype == dtype, ids.dtype
    assert (ids >= 0).all() and (ids < num_experts).all()
    for row in ids:
        assert len(set(int(v) for v in row)) == top_k, \
            f"top-k ids must be distinct per token: {row}"
