"""Failure detector, straggler monitor, elastic mesh planning, recovery loop."""
import numpy as np
import pytest

from repro.ft.elastic import (ElasticPlan, HeartbeatFailureDetector,
                              StragglerMonitor, WorkerFailure, plan_mesh,
                              remap_data_shards, run_with_recovery)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestFailureDetector:
    def test_timeout_detection(self):
        clk = FakeClock()
        d = HeartbeatFailureDetector(["w0", "w1"], timeout_s=10, clock=clk)
        clk.t = 5
        d.heartbeat("w0")
        clk.t = 12
        assert d.failed() == ["w1"]
        assert d.healthy() == ["w0"]

    def test_explicit_failure(self):
        d = HeartbeatFailureDetector(["w0", "w1"], timeout_s=1e9)
        d.mark_failed("w0")
        assert d.failed() == ["w0"]


class TestStraggler:
    def test_flags_persistent_straggler(self):
        workers = [f"w{i}" for i in range(8)]
        m = StragglerMonitor(workers, z_thresh=3.0, patience=2)
        for _ in range(3):
            t = {w: 1.0 + np.random.default_rng(0).normal() * 0.01
                 for w in workers}
            t["w3"] = 5.0
            m.record_step(t)
        assert m.quarantine() == ["w3"]

    def test_no_false_positives_on_noise(self):
        workers = [f"w{i}" for i in range(8)]
        m = StragglerMonitor(workers, z_thresh=4.0, patience=3)
        rng = np.random.default_rng(1)
        for _ in range(10):
            m.record_step({w: 1.0 + rng.normal() * 0.05 for w in workers})
        assert m.quarantine() == []


class TestElasticPlan:
    def test_full_fleet(self):
        p = plan_mesh(512)
        assert p.mesh_shape == (2, 16, 16)
        assert not p.degraded

    def test_one_pod(self):
        p = plan_mesh(256)
        assert p.mesh_shape == (16, 16)

    def test_partial_failures_shrink(self):
        p = plan_mesh(300)
        assert p.mesh_shape == (16, 16)
        assert p.dropped_workers == 44

    def test_small(self):
        assert plan_mesh(17).mesh_shape == (1, 16)

    def test_impossible(self):
        with pytest.raises(RuntimeError):
            plan_mesh(3)

    def test_remap_gap_free(self):
        mapping = remap_data_shards(16, 8, step=0)
        covered = sorted(s for shards in mapping for s in shards)
        assert covered == list(range(16))


class TestRecoveryLoop:
    def test_recovers_from_failure(self):
        state = {"restores": 0, "saved": 0}
        d = HeartbeatFailureDetector([f"w{i}" for i in range(17)],
                                     timeout_s=1e9)

        def step_fn(step):
            if step == 7 and state["restores"] == 0:
                raise WorkerFailure("w2")

        def save_fn(step):
            state["saved"] = step

        def restore_fn():
            state["restores"] += 1
            return state["saved"]

        hist = run_with_recovery(
            step_fn=step_fn, save_fn=save_fn, restore_fn=restore_fn,
            detector=d, max_steps=12, checkpoint_every=5,
            on_rescale=lambda plan, dead: None)
        assert hist["failures"] == 1
        assert state["restores"] == 1
        assert len(hist["rescales"]) == 1
        assert hist["completed"] >= 12
