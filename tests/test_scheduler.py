"""Continuous batching: iteration-level scheduler + slot-based engine
(DESIGN.md §3).

Covers: staggered arrivals (short requests retire before long ones in the
same slot generation), heterogeneous max_new_tokens, slot reuse after
retirement, join/batch invariance of greedy outputs, mid-flight
``configure()`` (placement-only preserves in-flight outputs; bank-split
changes drain gracefully), and the measured expert-streaming metrics.

The pure scheduler tests run on the deterministic simulation clock
(``repro.serving.simulator.VirtualClock``, DESIGN.md §10.4): every
``now=`` the scheduler sees comes from one explicitly advanced virtual
timeline, so wait-dependent behaviour (TTFT, latency percentiles,
priority aging) is scripted rather than wall-clock-dependent."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.serving.engine import AdaptiveServingEngine
from repro.serving.scheduler import (ContinuousScheduler, RequestSLO,
                                     SchedulerConfig)
from repro.serving.simulator import VirtualClock


# ---------------------------------------------------------------------------
# Pure scheduler unit tests (no jax)
# ---------------------------------------------------------------------------

class TestSchedulerUnit:
    def mk(self, **kw):
        return ContinuousScheduler(SchedulerConfig(**kw))

    def test_oversize_request_rejected(self):
        s = self.mk(max_slots=2, max_len=16)
        with pytest.raises(ValueError, match="slot window"):
            s.submit(np.arange(10), max_new_tokens=10)

    def test_fifo_admission_into_free_slots(self):
        s = self.mk(max_slots=2, max_len=32)
        r1 = s.submit(np.arange(4), 4)
        r2 = s.submit(np.arange(4), 4)
        r3 = s.submit(np.arange(4), 4)
        joined = s.admit()
        assert [(sl, rq.rid) for sl, rq in joined] == [(0, r1), (1, r2)]
        assert [r.rid for r in s.queue] == [r3]

    def test_slot_reuse_after_retirement(self):
        s = self.mk(max_slots=2, max_len=32)
        s.submit(np.arange(4), 4)
        s.submit(np.arange(4), 4)
        s.admit()
        s.retire(0)
        r3 = s.submit(np.arange(4), 4)
        joined = s.admit()
        assert joined[0][0] == 0 and joined[0][1].rid == r3
        assert s.num_active == 2

    def test_max_active_tokens_blocks_admission(self):
        s = self.mk(max_slots=4, max_len=32, max_active_tokens=20)
        s.submit(np.arange(8), 8)      # claim 16
        s.submit(np.arange(8), 8)      # claim 16 > 20-16 -> must wait
        assert len(s.admit()) == 1
        assert len(s.queue) == 1
        s.retire(0)
        assert len(s.admit()) == 1     # admitted once capacity freed

    def test_empty_prompt_rejected(self):
        s = self.mk(max_slots=1, max_len=16)
        with pytest.raises(ValueError, match="at least one token"):
            s.submit(np.array([], np.int32), 2)
        with pytest.raises(ValueError, match="max_new_tokens"):
            s.submit(np.arange(1, 3), 0)

    def test_max_queue_and_drain_queue(self):
        s = self.mk(max_slots=1, max_len=32, max_queue=2)
        s.submit(np.arange(1, 4), 4)
        s.submit(np.arange(1, 4), 4)
        with pytest.raises(RuntimeError, match="queue full"):
            s.submit(np.arange(1, 4), 4)
        dropped = s.drain_queue()
        assert len(dropped) == 2 and not s.queue

    def test_ttft_tracked_from_submit(self):
        clock = VirtualClock(start=1.0)
        s = self.mk(max_slots=1, max_len=32)
        rid = s.submit(np.arange(1, 4), 2, now=clock.now())
        s.admit(now=clock.advance(1.5))
        st = s.slots[0]
        st.req.t_first = clock.advance(0.5)
        s.retire(0, now=clock.advance(1.0))
        assert s.done[rid].ttft_s == pytest.approx(2.0)
        assert s.done[rid].latency_s == pytest.approx(3.0)

    def test_latency_percentiles_shape(self):
        clock = VirtualClock()
        s = self.mk(max_slots=1, max_len=32)
        s.submit(np.arange(2), 2, now=clock.now())
        s.admit(now=clock.advance(1.0))
        s.retire(0, now=clock.advance(2.0))
        lat = s.latency_percentiles()
        assert lat["p50"] == pytest.approx(3.0)
        assert set(lat) == {"p50", "p95"}

    def test_high_priority_stream_starves_low_without_aging(self):
        """Strict priority classes (aging disabled): a sustained stream
        of high-priority arrivals keeps a low-priority request queued
        forever — the failure mode aging exists to fix."""
        clock = VirtualClock()
        s = self.mk(max_slots=1, max_len=32)
        lo = s.submit(np.arange(4), 4, now=clock.now())
        for _ in range(30):
            s.submit(np.arange(4), 4, now=clock.now(),
                     slo=RequestSLO(priority=3))
            for slot, _req in s.admit(now=clock.now()):
                s.retire(slot, now=clock.advance(1.0))
        assert lo not in s.done
        assert any(r.rid == lo for r in s.queue)

    def test_aging_rescues_low_priority_under_sustained_load(self):
        """Deadline-style aging (SchedulerConfig.aging_s): queue wait
        promotes the low-priority request one class per aging_s, so it
        completes despite an unbroken priority-3 arrival stream."""
        clock = VirtualClock()
        s = self.mk(max_slots=1, max_len=32, aging_s=1.0)
        lo = s.submit(np.arange(4), 4, now=clock.now())
        hi_done = 0
        for _ in range(30):
            s.submit(np.arange(4), 4, now=clock.now(),
                     slo=RequestSLO(priority=3))
            for slot, _req in s.admit(now=clock.now()):
                s.retire(slot, now=clock.advance(1.0))
            if lo in s.done:
                break
        else:
            pytest.fail("low-priority request starved despite aging")
        # it waited at least long enough to out-age priority 3 (4 classes
        # at aging_s=1.0), and high-priority requests ran meanwhile
        hi_done = sum(1 for r in s.done.values()
                      if r.rid != lo and r.slo.priority == 3)
        assert s.done[lo].latency_s >= 3.0
        assert hi_done >= 3

    def test_aging_keeps_fifo_within_class(self):
        """Two deadline-less requests of one class age in lockstep —
        aging must not reorder FIFO inside a priority class."""
        clock = VirtualClock()
        s = self.mk(max_slots=2, max_len=32, aging_s=0.5)
        r1 = s.submit(np.arange(4), 4, now=clock.now())
        r2 = s.submit(np.arange(4), 4, now=clock.now())
        clock.advance(5.0)
        assert [rq.rid for _, rq in s.admit(now=clock.now())] == [r1, r2]


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return AdaptiveServingEngine(cfg, params, max_batch=2, max_len=24)


def _full_size(engine):
    return engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16


def _all_f16(engine, frac=1.1):
    engine.configure(_full_size(engine) * frac, "quality", num_q_experts=0)


PROMPT = np.array([3, 1, 4, 1, 5])


def _solo_tokens(engine, prompt, n):
    rid = engine.submit(prompt, max_new_tokens=n)
    engine.step()
    return list(engine.done[rid].out_tokens)


class TestContinuousBatching:
    def test_staggered_short_finishes_before_long(self, engine):
        """A short request joining a live slot generation mid-flight must
        retire BEFORE the long request it shares the batch with — the
        defining property iteration-level scheduling adds over
        batch-to-completion."""
        _all_f16(engine)
        rid_long = engine.submit(np.array([2, 7, 1]), max_new_tokens=12)
        engine.run_iteration()
        engine.run_iteration()          # long request is mid-generation
        rid_short = engine.submit(PROMPT, max_new_tokens=3)
        finish_order = []
        while engine.has_work():
            finish_order.extend(engine.run_iteration())
        assert finish_order.index(rid_short) < finish_order.index(rid_long)
        assert len(engine.done[rid_long].out_tokens) == 12
        assert len(engine.done[rid_short].out_tokens) == 3

    def test_heterogeneous_max_new_tokens(self, engine):
        _all_f16(engine)
        rids = [engine.submit(PROMPT, max_new_tokens=n) for n in (2, 9, 5)]
        assert engine.step() == 3
        for rid, n in zip(rids, (2, 9, 5)):
            assert len(engine.done[rid].out_tokens) == n
            assert all(0 <= t < engine.cfg.vocab_size
                       for t in engine.done[rid].out_tokens)

    def test_slot_reuse_after_retirement(self, engine):
        """Three requests through two slots: the third must reuse a freed
        slot while the first generation's long request is still active,
        and still produce the same greedy tokens as a solo run."""
        _all_f16(engine)
        base = _solo_tokens(engine, PROMPT, 4)
        rid_long = engine.submit(np.array([9, 9, 2]), max_new_tokens=14)
        rid_a = engine.submit(PROMPT, max_new_tokens=4)
        engine.run_iteration()          # both admitted (slots 0 and 1)
        used = {i for i, _ in engine.scheduler.active()}
        assert used == {0, 1}
        while rid_a not in engine.done:
            engine.run_iteration()
        freed = [i for i in (0, 1)
                 if engine.scheduler.slots[i] is None][0]
        rid_b = engine.submit(PROMPT, max_new_tokens=4)
        engine.run_iteration()          # rid_b joins the freed slot
        assert engine.scheduler.slots[freed] is not None
        assert engine.scheduler.slots[freed].req.rid == rid_b
        assert rid_long not in engine.done   # long one still in flight
        while engine.has_work():
            engine.run_iteration()
        # batch composition must not change greedy outputs
        assert engine.done[rid_a].out_tokens == base
        assert engine.done[rid_b].out_tokens == base

    def test_midflight_placement_reconfig_keeps_outputs(self, engine):
        """configure() with an unchanged bank split applies between decode
        iterations and must not perturb in-flight generations."""
        _all_f16(engine, 1.2)
        base = _solo_tokens(engine, PROMPT, 6)
        rid = engine.submit(PROMPT, max_new_tokens=6)
        engine.run_iteration()
        engine.run_iteration()
        assert rid not in engine.done
        engine.configure(_full_size(engine) * 0.4, "quality",
                         num_q_experts=0)   # placement-only: offload
        assert engine.scheduler.num_active == 1   # no drain happened
        while engine.has_work():
            engine.run_iteration()
        assert engine.done[rid].out_tokens == base

    def test_bank_split_change_drains_gracefully(self, engine):
        """A (E4, E16) signature change with requests in flight finishes
        them on the old banks before re-specializing."""
        _all_f16(engine)
        rid = engine.submit(PROMPT, max_new_tokens=8)
        engine.run_iteration()
        drains0 = engine.metrics["drains"]
        per_layer = engine.cfg.moe.num_experts // 2
        engine.configure(
            _full_size(engine) * 1.1, "quality",
            num_q_experts=per_layer * engine.cfg.num_layers)
        assert engine.metrics["drains"] == drains0 + 1
        assert rid in engine.done                 # finished by the drain
        assert len(engine.done[rid].out_tokens) == 8

    def test_measured_expert_streaming_metrics(self, engine):
        """Offloaded placement must fetch non-resident experts through the
        runtime ExpertCache: measured transfer_s is reported alongside the
        retained analytical estimate."""
        _all_f16(engine, 0.4)           # most experts host-resident
        engine.reset_counters()
        engine.submit(PROMPT, max_new_tokens=6)
        engine.step()
        m = engine.metrics
        assert m["expert_accesses"] > 0
        assert m["expert_fetches"] > 0
        assert m["transfer_s"] > 0.0
        assert m["transfer_s_est"] > 0.0
        assert 0.0 < m["miss_rate_measured"] <= 1.0
        assert engine.expert_cache.stats.bytes_in > 0
        # the cache never exceeds its swap budget
        assert engine.expert_cache.used_bytes <= engine.expert_cache.capacity

    def test_single_token_request_counted(self, engine):
        """max_new_tokens=1 retires at prefill; its rid must still be
        reported by run_iteration/step."""
        _all_f16(engine)
        rid = engine.submit(PROMPT, max_new_tokens=1)
        retired = engine.run_iteration()
        assert rid in retired
        assert len(engine.done[rid].out_tokens) == 1

    def test_generation_past_sliding_window(self, engine):
        """Total length may exceed the SWA ring window (the buffer wraps,
        position tags + SWA masking stay correct); only the PROMPT must
        fit the prefill window."""
        cfg = engine.cfg
        assert cfg.attention.sliding_window is not None
        window = cfg.attention.sliding_window
        eng = AdaptiveServingEngine(cfg, engine.params_train,
                                    max_batch=1, max_len=window + 16)
        assert eng.window == window
        eng.configure(_full_size(eng) * 1.1, "quality", num_q_experts=0)
        rid = eng.submit(np.arange(1, 9), max_new_tokens=window)  # 8+64>64
        assert eng.step() == 1
        out = eng.done[rid].out_tokens
        assert len(out) == window
        assert all(0 <= t < cfg.vocab_size for t in out)
        with pytest.raises(ValueError, match="prefill window"):
            eng.submit(np.arange(window + 1), max_new_tokens=1)

    def test_idle_slots_never_displace_expert_capacity(self):
        """Idle decode rows (position=-1) must not occupy MoE expert
        capacity: with a tight capacity_factor, a lone active row's
        logits must be identical whether it decodes alone or surrounded
        by idle slots (idle ids are remapped to the drop sentinel)."""
        import dataclasses
        import jax.numpy as jnp
        cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=1.0))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        prompt = jnp.asarray(np.arange(1, 6)[None], jnp.int32)
        pos = jnp.asarray(np.arange(5)[None], jnp.int32)

        def decode_after_prefill(batch, slot):
            cache = model.init_cache(batch, 16)
            _, cache = jax.jit(model.prefill_into_slot)(
                params, cache, prompt, pos, jnp.int32(slot), jnp.int32(4))
            toks = np.zeros((batch, 1), np.int32)
            p = np.full((batch,), -1, np.int32)
            toks[slot, 0], p[slot] = 7, 5
            logits, _, _ = jax.jit(model.decode_step_routed)(
                params, cache, jnp.asarray(toks), jnp.asarray(p))
            return np.asarray(logits[slot])

        solo = decode_after_prefill(1, 0)
        # 7 idle rows sorted BEFORE the active row in the dispatch: without
        # the sentinel remap they exhaust the per-expert capacity first
        crowded = decode_after_prefill(8, 7)
        np.testing.assert_allclose(solo, crowded, rtol=1e-5, atol=1e-5)

    def test_queue_requires_configure(self):
        cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        eng = AdaptiveServingEngine(cfg, params, max_batch=2, max_len=24)
        eng.submit(PROMPT, max_new_tokens=2)
        with pytest.raises(RuntimeError):
            eng.run_iteration()
