"""Data pipeline: determinism, resumability, DP-sharding, packing."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import (DataPipeline, SyntheticCorpus,
                                 SyntheticCorpusConfig, make_eval_stream)

CFG = SyntheticCorpusConfig(vocab_size=128, doc_len_mean=64, seed=7)


def make(rank=0, size=1, batch=4, seq=32):
    return DataPipeline(SyntheticCorpus(CFG), batch=batch, seq=seq,
                        dp_rank=rank, dp_size=size)


class TestPipeline:
    def test_shapes_and_ranges(self):
        b = make().next_batch()
        assert b["tokens"].shape == (4, 32)
        assert b["labels"].shape == (4, 32)
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < 128

    def test_deterministic(self):
        a = [make().next_batch() for _ in range(1)][0]
        b = [make().next_batch() for _ in range(1)][0]
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_resume_bit_exact(self):
        p1 = make()
        for _ in range(3):
            p1.next_batch()
        state = p1.state()
        want = [p1.next_batch() for _ in range(2)]
        p2 = make()
        p2.restore(state)
        got = [p2.next_batch() for _ in range(2)]
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w["tokens"], g["tokens"])
            np.testing.assert_array_equal(w["labels"], g["labels"])

    def test_dp_ranks_disjoint_docs(self):
        """Leapfrog sharding: rank streams must differ (disjoint docs)."""
        a = make(rank=0, size=4).next_batch()["tokens"]
        b = make(rank=1, size=4).next_batch()["tokens"]
        assert not np.array_equal(a, b)

    def test_label_shift(self):
        p = make(batch=1, seq=64)
        b = p.next_batch()
        # wherever the label is not masked, it equals the next token
        tok, lab = b["tokens"][0], b["labels"][0]
        unmasked = lab >= 0
        np.testing.assert_array_equal(
            lab[unmasked][:-1],
            tok[1:][unmasked[:-1]][:len(lab[unmasked]) - 1])

    def test_eod_masking(self):
        p = make(batch=2, seq=256)
        b = p.next_batch()
        after_eod = b["tokens"][:, :-0 or None] == 0
        assert (b["labels"][after_eod] == -1).all()

    def test_eval_stream_disjoint_from_train(self):
        train = make(batch=2, seq=64).next_batch()["tokens"]
        ev = make_eval_stream(SyntheticCorpus(CFG), batch=2, seq=64,
                              n_batches=1)[0]["tokens"]
        assert not np.array_equal(train, ev)

    @given(nsteps=st.integers(1, 6), batch=st.sampled_from([1, 2, 4]),
           seq=st.sampled_from([16, 64]))
    @settings(max_examples=10, deadline=None)
    def test_resume_property(self, nsteps, batch, seq):
        p1 = make(batch=batch, seq=seq)
        for _ in range(nsteps):
            p1.next_batch()
        p2 = make(batch=batch, seq=seq)
        p2.restore(p1.state())
        np.testing.assert_array_equal(p1.next_batch()["tokens"],
                                      p2.next_batch()["tokens"])

    def test_corpus_is_learnable_structure(self):
        """The Markov corpus must be lower-entropy than uniform (so a model
        trained on it can beat log(V) — fig2 benchmark's premise)."""
        c = SyntheticCorpus(CFG)
        doc = np.concatenate([c.document(i) for i in range(50)])
        _, counts = np.unique(doc, return_counts=True)
        p = counts / counts.sum()
        ent = -(p * np.log(p)).sum()
        assert ent < 0.95 * np.log(CFG.vocab_size)
