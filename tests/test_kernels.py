"""Per-kernel allclose vs the pure-jnp oracle (interpret mode on CPU).

Sweeps shapes/dtypes per the deliverable-(c) requirement and adds
hypothesis property tests on tiling invariance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantization import QTensor, quantize
from repro.kernels import (
    expert_matmul_ref, q_expert_matmul, q_matmul, quantized_matmul_ref,
)
from repro.kernels.q4_matmul import quantized_matmul


def make_case(m, k, n, bits, group, seed=0, xdtype=jnp.bfloat16):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), xdtype)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return x, quantize(w, bits, group)


def assert_matches_oracle(out, x, qt, rtol=5e-2, atol=5e-2):
    ref = quantized_matmul_ref(x, qt.q, qt.scales, bits=qt.bits,
                               group_size=qt.group_size,
                               out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=rtol, atol=atol * float(
                                   jnp.abs(ref).max()))


class TestQ4MatmulKernel:
    @pytest.mark.parametrize("m,k,n", [
        (128, 128, 256), (128, 256, 256), (256, 512, 512), (128, 1024, 256),
    ])
    @pytest.mark.parametrize("bits", [4, 8])
    def test_shape_sweep(self, m, k, n, bits):
        x, qt = make_case(m, k, n, bits, 64)
        out = quantized_matmul(x, qt.q, qt.scales, bits=bits, group_size=64,
                               interpret=True)
        assert out.shape == (m, n) and out.dtype == jnp.bfloat16
        assert_matches_oracle(out, x, qt)

    @pytest.mark.parametrize("xdtype", [jnp.bfloat16, jnp.float32])
    @pytest.mark.parametrize("odtype", [jnp.bfloat16, jnp.float32])
    def test_dtype_sweep(self, xdtype, odtype):
        x, qt = make_case(128, 256, 256, 4, 64, xdtype=xdtype)
        out = quantized_matmul(x, qt.q, qt.scales, bits=4, group_size=64,
                               out_dtype=odtype, interpret=True)
        assert out.dtype == odtype
        assert_matches_oracle(out, x, qt)

    @pytest.mark.parametrize("group", [32, 64, 128])
    def test_group_sweep(self, group):
        x, qt = make_case(128, 256, 256, 4, group)
        out = quantized_matmul(x, qt.q, qt.scales, bits=4, group_size=group,
                               interpret=True)
        assert_matches_oracle(out, x, qt)

    @pytest.mark.parametrize("bm,bn,bk", [
        (128, 128, 128), (64, 256, 64), (128, 256, 256), (32, 128, 128),
    ])
    def test_tile_sweep(self, bm, bn, bk):
        x, qt = make_case(128, 512, 256, 4, 32)
        out = quantized_matmul(x, qt.q, qt.scales, bits=4, group_size=32,
                               block_m=bm, block_n=bn, block_k=bk,
                               interpret=True)
        assert_matches_oracle(out, x, qt)

    @given(mi=st.integers(1, 3), ki=st.integers(1, 4), ni=st.integers(1, 2),
           bits=st.sampled_from([4, 8]), seed=st.integers(0, 99))
    @settings(max_examples=12, deadline=None)
    def test_property_tiling_invariance(self, mi, ki, ni, bits, seed):
        """Output is independent of the tiling decomposition."""
        m, k, n = 64 * mi, 128 * ki, 128 * ni
        x, qt = make_case(m, k, n, bits, 32, seed)
        outs = [
            np.asarray(quantized_matmul(
                x, qt.q, qt.scales, bits=bits, group_size=32,
                block_m=bm, block_n=bn, block_k=bk, out_dtype=jnp.float32,
                interpret=True))
            for (bm, bn, bk) in ((64, 128, 128), (m, n, 32), (32, 128, 64))]
        np.testing.assert_allclose(outs[0], outs[1], rtol=2e-5, atol=1e-3)
        np.testing.assert_allclose(outs[0], outs[2], rtol=2e-5, atol=1e-3)
        assert_matches_oracle(outs[0], x, qt)

    def test_error_on_bad_scales(self):
        x, qt = make_case(128, 256, 128, 4, 64)
        with pytest.raises(ValueError):
            quantized_matmul(x, qt.q, qt.scales[:1], bits=4, group_size=64,
                             interpret=True)


class TestParityPropertyGrid:
    """Property-style parity vs the pure-jnp oracle across a SEEDED grid
    of shapes, group sizes and int4/int8 — including edge tiles where
    M/N/K are NOT multiples of the default (128, 256, 128) blocks, so
    the clamp/divisor tile-selection paths and the M-padding wrapper are
    exercised, not just the aligned fast path (interpret mode on CPU)."""

    @given(m=st.integers(1, 200), ki=st.integers(1, 5),
           ni=st.integers(1, 6), bits=st.sampled_from([4, 8]),
           group=st.sampled_from([32, 64]), seed=st.integers(0, 9999))
    @settings(max_examples=20, deadline=None)
    def test_wrapper_parity_any_shape(self, m, ki, ni, bits, group, seed):
        """q_matmul == oracle for arbitrary M (padded inside the
        wrapper) and K/N that are multiples of the group size but NOT of
        the default blocks (the wrapper shrinks tiles to divisors)."""
        k, n = group * ki, 32 * ni
        x, qt = make_case(m, k, n, bits, group, seed)
        out = q_matmul(x, qt, out_dtype=jnp.float32, interpret=True)
        assert out.shape == (m, n)
        assert_matches_oracle(out, x, qt)

    @given(mi=st.integers(1, 4), ki=st.integers(1, 4), ni=st.integers(1, 4),
           bits=st.sampled_from([4, 8]), seed=st.integers(0, 9999))
    @settings(max_examples=12, deadline=None)
    def test_kernel_parity_odd_explicit_tiles(self, mi, ki, ni, bits,
                                              seed):
        """The raw kernel with deliberately odd (non-default,
        non-square) tile choices: 3 tiles per axis of sizes that never
        equal the defaults. Output must not depend on the tiling."""
        m, k, n = 32 * mi, 96 * ki, 96 * ni
        x, qt = make_case(m, k, n, bits, 32, seed)
        out = quantized_matmul(
            x, qt.q, qt.scales, bits=bits, group_size=32,
            block_m=32, block_n=96, block_k=96,
            out_dtype=jnp.float32, interpret=True)
        assert_matches_oracle(out, x, qt)

    def test_edge_tile_clamp_below_default_blocks(self):
        """Dims smaller than every default block (M=8 < 128, N=64 < 256,
        K=64 < 128): the kernel clamps each block to the dim."""
        x, qt = make_case(8, 64, 64, 4, 32)
        out = quantized_matmul(x, qt.q, qt.scales, bits=4, group_size=32,
                               out_dtype=jnp.float32, interpret=True)
        assert out.shape == (8, 64)
        assert_matches_oracle(out, x, qt)

    @pytest.mark.parametrize("bits", [4, 8])
    def test_wrapper_parity_prime_ish_edge_case(self, bits):
        """A deliberately awkward single case: M prime, K=160 and N=96
        not multiples of any default block (the divisor search lands on
        32-multiples)."""
        x, qt = make_case(37, 160, 96, bits, 32, seed=7)
        out = q_matmul(x, qt, out_dtype=jnp.float32, interpret=True)
        assert out.shape == (37, 96)
        assert_matches_oracle(out, x, qt)

    @pytest.mark.parametrize("group", [32, 64, 128])
    def test_q8_group_sweep(self, group):
        """int8 across all group sizes (the q4 group sweep's twin — the
        q8 bank is first-class on the precision ladder)."""
        x, qt = make_case(128, 256, 256, 8, group)
        out = quantized_matmul(x, qt.q, qt.scales, bits=8, group_size=group,
                               interpret=True)
        assert_matches_oracle(out, x, qt)

    def test_q8_edge_tile_clamp_below_default_blocks(self):
        """int8 with dims smaller than every default block (M=8 < 128,
        N=64 < 256, K=64 < 128): the clamp path, not just the aligned
        fast path."""
        x, qt = make_case(8, 64, 64, 8, 32)
        out = quantized_matmul(x, qt.q, qt.scales, bits=8, group_size=32,
                               out_dtype=jnp.float32, interpret=True)
        assert out.shape == (8, 64)
        assert_matches_oracle(out, x, qt)

    def test_q8_odd_explicit_tiles(self):
        """int8 with deliberately odd non-default tiles (96-multiples):
        exercises the q8 kernel body off the (128, 256, 128) defaults."""
        x, qt = make_case(64, 192, 192, 8, 32, seed=11)
        out = quantized_matmul(x, qt.q, qt.scales, bits=8, group_size=32,
                               block_m=32, block_n=96, block_k=96,
                               out_dtype=jnp.float32, interpret=True)
        assert_matches_oracle(out, x, qt)

    @given(e=st.integers(1, 3), c=st.sampled_from([8, 40]),
           bits=st.sampled_from([4, 8]), seed=st.integers(0, 999))
    @settings(max_examples=6, deadline=None)
    def test_expert_batched_parity_edge_tiles(self, e, c, bits, seed):
        """The vmapped expert path on non-default tile shapes."""
        rng = np.random.default_rng(seed)
        k, n = 96, 96
        x = jnp.asarray(rng.standard_normal((e, c, k)), jnp.bfloat16)
        w = jnp.asarray(rng.standard_normal((e, k, n)), jnp.float32)
        qt = quantize(w, bits, 32)
        out = q_expert_matmul(x, qt, interpret=True)
        ref = expert_matmul_ref(x, qt.q, qt.scales, bits=bits,
                                group_size=32, out_dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=5e-2, atol=5e-2 * float(jnp.abs(ref).max()))


class TestOpsWrappers:
    @pytest.mark.parametrize("bits", [4, 8])
    @pytest.mark.parametrize("m", [1, 7, 128, 200])
    def test_q_matmul_pads_m(self, m, bits):
        """Decode calls with tiny M must work (padding inside the wrapper),
        on both quantized rungs."""
        x, qt = make_case(m, 256, 256, bits, 64)
        out = q_matmul(x, qt, interpret=True)
        assert out.shape == (m, 256)
        assert_matches_oracle(out, x, qt)

    def test_q_expert_matmul_matches_batched_oracle(self):
        rng = np.random.default_rng(3)
        e, c, k, n = 4, 64, 128, 256
        x = jnp.asarray(rng.standard_normal((e, c, k)), jnp.bfloat16)
        w = jnp.asarray(rng.standard_normal((e, k, n)), jnp.float32)
        qt = quantize(w, 4, 64)
        out = q_expert_matmul(x, qt, block_m=64, interpret=True)
        ref = expert_matmul_ref(x, qt.q, qt.scales, bits=4, group_size=64,
                                out_dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=5e-2, atol=5e-2 * float(jnp.abs(ref).max()))

    def test_grad_does_not_exist(self):
        """Quantized weights are serving-only: no grad path expected."""
        x, qt = make_case(64, 128, 128, 4, 64)
        with pytest.raises(Exception):
            jax.grad(lambda x: q_matmul(x, qt, interpret=True).sum())(x)


class TestKernelNumerics:
    def test_exact_on_integer_friendly_scales(self):
        """With scales=1 and integer x the kernel result is exact."""
        k, n, m = 128, 128, 32
        rng = np.random.default_rng(0)
        q = rng.integers(-8, 8, (k, n)).astype(np.int8)
        from repro.core.quantization import pack_int4
        packed = pack_int4(jnp.asarray(q))
        scales = jnp.ones((k // 64, n), jnp.float32)
        x = jnp.asarray(rng.integers(-4, 5, (m, k)), jnp.float32)
        out = quantized_matmul(x, packed, scales, bits=4, group_size=64,
                               block_m=32, block_n=128, block_k=128,
                               out_dtype=jnp.float32, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(x, np.float32) @ q.astype(np.float32))
