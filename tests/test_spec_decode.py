"""Ladder-draft self-speculative decoding (DESIGN.md §17): greedy
token-identity across the KV-layout x streaming-mode grid, exactness
under a sabotaged draft (acceptance ~0), the speculate=0 no-op, the
model-level rollback hooks, the QoSController acceptance fallback (on
the simulator), and the gated cost-model pricing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core.cost_model import (HardwareModel, estimate_qos,
                                   speculative_tokens_per_cycle)
from repro.core.precision_plan import DEVICE, quantized_rungs
from repro.models.model import (apply_precision_plan, build_model,
                                init_cache)
from repro.serving.api import EngineConfig, ServeRequest
from repro.serving.engine import AdaptiveServingEngine


@pytest.fixture(scope="module")
def smoke():
    cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _full_size(engine):
    return engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16


def _make_engine(cfg, params, econf, preference="quality"):
    """Engine on the all-16-bit resident plan by default, so the int4
    draft is a genuinely different model (acceptance < 1 is possible)."""
    engine = AdaptiveServingEngine(cfg, params, config=econf)
    engine.configure(_full_size(engine) * 1.1, preference,
                     0 if preference == "quality" else None)
    return engine


def _serve(engine, cfg, n_req=3, max_new=7, temperature=0.0):
    """3 requests over 2 slots: one slot retires and is rejoined
    mid-flight. Returns per-rid token lists."""
    rng = np.random.default_rng(0)
    rids = [engine.submit_request(ServeRequest(
        prompt=rng.integers(1, cfg.vocab_size, 5 + 2 * i),
        max_new_tokens=max_new)) for i in range(n_req)]
    while engine.has_work():
        engine.run_iteration(temperature=temperature)
    return {rid: list(engine.done[rid].out_tokens) for rid in rids}


class TestGreedyParity:
    """Acceptance criterion: greedy speculative decode is token-identical
    to plain decode for every (paged x overlap) config."""

    @pytest.mark.parametrize("paged,overlap", [
        (False, False), (True, False), (False, True), (True, True)])
    def test_token_identical(self, smoke, paged, overlap):
        cfg, _, params = smoke
        base = dict(max_slots=2, max_len=24, paged_kv=paged,
                    overlap=overlap, page_size=4)
        ep = _make_engine(cfg, params, EngineConfig(**base, speculate=0))
        plain = _serve(ep, cfg)
        es = _make_engine(cfg, params, EngineConfig(**base, speculate=3))
        spec = _serve(es, cfg)
        assert spec == plain
        assert es.metrics["spec_proposed"] > 0
        assert 0.0 <= es.metrics["acceptance_rate"] <= 1.0
        # accepted drafts shorten the iteration count
        assert es.metrics["iterations"] <= ep.metrics["iterations"]
        assert "spec[k=3" in es.summary()
        ep.close()
        es.close()

    def test_sabotaged_draft_still_exact(self, smoke):
        """A garbage draft model (different random init) drives
        acceptance to ~0 — output must STILL be token-identical, proving
        the verify forward + rollback are exact regardless of draft
        quality."""
        cfg, model, params = smoke
        base = dict(max_slots=2, max_len=24, page_size=4, paged_kv=True)
        ep = _make_engine(cfg, params, EngineConfig(**base, speculate=0))
        plain = _serve(ep, cfg)
        es = _make_engine(cfg, params, EngineConfig(**base, speculate=3))
        plan = es._plan_result.plan
        low = quantized_rungs(plan.ladder)[0]
        draft_plan = dataclasses.replace(
            plan, bits=np.full_like(plan.bits, low),
            location=np.full_like(plan.location, DEVICE))
        es._draft_params = apply_precision_plan(
            model.init(jax.random.key(9)), cfg, draft_plan)
        es._draft_sig = (tuple(plan.ladder), plan.group_size, low)
        spec = _serve(es, cfg)
        assert spec == plain
        m = es.metrics
        assert m["spec_proposed"] > 0
        assert m["acceptance_rate"] < 0.5    # garbage rarely matches
        ep.close()
        es.close()

    def test_speculate_zero_is_plain_engine(self, smoke):
        """speculate=0 must be byte-identical to the pre-speculation
        engine: same tokens, iterations == tokens per request, zero spec
        counters, no spec column in the summary."""
        cfg, _, params = smoke
        base = dict(max_slots=2, max_len=24)
        ea = _make_engine(cfg, params, EngineConfig(**base))
        eb = _make_engine(cfg, params, EngineConfig(**base, speculate=0))
        ta = _serve(ea, cfg)
        tb = _serve(eb, cfg)
        assert ta == tb
        assert ea.metrics["iterations"] == eb.metrics["iterations"]
        for m in (ea.metrics, eb.metrics):
            assert m["spec_proposed"] == 0 and m["spec_accepted"] == 0
            assert m["acceptance_rate"] == 0.0
        assert "spec[" not in eb.summary()
        ea.close()
        eb.close()

    def test_set_speculation_mid_run(self, smoke):
        """The QoS fallback path: disabling speculation mid-flight (no
        drain, no recompile) keeps the stream correct and stops
        proposing."""
        cfg, _, params = smoke
        engine = _make_engine(cfg, params, EngineConfig(
            max_slots=2, max_len=24, speculate=3))
        ep = _make_engine(cfg, params, EngineConfig(
            max_slots=2, max_len=24, speculate=0))
        plain = _serve(ep, cfg)
        rng = np.random.default_rng(0)
        rids = [engine.submit_request(ServeRequest(
            prompt=rng.integers(1, cfg.vocab_size, 5 + 2 * i),
            max_new_tokens=7)) for i in range(3)]
        engine.run_iteration(temperature=0.0)
        engine.set_speculation(0)
        proposed = engine.metrics["spec_proposed"]
        assert proposed > 0
        while engine.has_work():
            engine.run_iteration(temperature=0.0)
        assert engine.metrics["spec_proposed"] == proposed
        assert {r: list(engine.done[r].out_tokens) for r in rids} == plain
        engine.close()
        ep.close()


class TestTemperaturePath:
    def test_rejection_sampled_run_completes(self, smoke):
        """temperature>0 rides the rejection-sampling verify: every
        request still emits exactly max_new tokens in range, counters
        stay consistent."""
        cfg, _, params = smoke
        engine = _make_engine(cfg, params, EngineConfig(
            max_slots=2, max_len=24, speculate=2))
        toks = _serve(engine, cfg, temperature=0.8)
        for t in toks.values():
            assert len(t) == 7
            assert all(0 <= x < cfg.vocab_size for x in t)
        m = engine.metrics
        assert 0 < m["spec_accepted"] + 1 and m["spec_proposed"] > 0
        assert m["spec_accepted"] <= m["spec_proposed"]
        assert m["acceptance_rate"] == pytest.approx(
            m["spec_accepted"] / m["spec_proposed"])
        engine.close()


class TestRollbackHooks:
    def test_rollback_slots_invalidates_tail_tags(self, smoke):
        cfg, model, _ = smoke
        cache = init_cache(cfg, batch=2, max_len=8)
        pos = np.asarray(cache["pos"]).copy()
        pos[:, 0, :6] = np.arange(6)
        pos[:, 1, :3] = np.arange(3)
        cache = dict(cache, pos=jnp.asarray(pos))
        rolled = model.rollback_slots(cache, jnp.asarray([3, 10]))
        got = np.asarray(rolled["pos"])
        # slot 0: positions > 3 invalidated, 0..3 kept
        np.testing.assert_array_equal(got[:, 0, :4], pos[:, 0, :4])
        assert (got[:, 0, 4:] == -1).all()
        # slot 1: keep=10 >= every tag -> untouched
        np.testing.assert_array_equal(got[:, 1], pos[:, 1])
        # k/v payloads are never touched (tags alone gate attention)
        np.testing.assert_array_equal(np.asarray(rolled["k"]),
                                      np.asarray(cache["k"]))

    def test_paged_rollback_invalidates_mapped_pages_only(self, smoke):
        from repro.models.model import init_paged_cache
        from repro.serving.paged_kv import PageAllocator
        cfg, model, _ = smoke
        pool, meta = init_paged_cache(cfg, 2, 16, page_size=4)
        al = PageAllocator(2, meta.chunks_per_slot, meta.num_pages,
                           meta.page_size)
        al.ensure_prefix(0, 8)          # slot 0: ring 0..7 mapped
        al.ensure_prefix(1, 4)
        ppos = np.asarray(pool["pos"]).copy()
        for slot, n in ((0, 8), (1, 4)):
            for r in range(n):
                page = al.table[slot, r // meta.page_size]
                ppos[:, page, r % meta.page_size] = r
        pool = dict(pool, pos=jnp.asarray(ppos))
        rolled = model.paged_rollback(
            pool, jnp.asarray(al.table), jnp.asarray([2, 3]))
        got = np.asarray(rolled["pos"])
        for slot, keep, n in ((0, 2, 8), (1, 3, 4)):
            for r in range(n):
                page = al.table[slot, r // meta.page_size]
                tag = got[0, page, r % meta.page_size]
                assert tag == (r if r <= keep else -1), (slot, r)
        # the shared null page stays invalid
        assert (got[:, 0] == -1).all()


class TestQoSFallback:
    def _drive(self, acceptance, iters=24):
        from repro.core.pareto import ParetoFrontier, QoSTarget
        from repro.serving.qos import QoSController, QoSControllerConfig
        from repro.serving.simulator import SimulatedEngine, run_scripted
        cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
        frontier = ParetoFrontier(cfg, HardwareModel())
        eng = SimulatedEngine(batch=8, spec_k=4, acceptance=acceptance)
        ctl = QoSController(eng, frontier, config=QoSControllerConfig(
            window_iterations=2, min_dwell_iterations=4,
            spec_min_proposed=32))
        ctl.set_target(QoSTarget(min_tokens_per_s=0.001))
        run_scripted(eng, ctl, iters)
        return eng, ctl

    def test_low_acceptance_falls_back(self):
        eng, ctl = self._drive(acceptance=0.1)
        assert ctl.metrics["spec_fallbacks"] == 1
        assert eng.spec_k == 0
        # round(0.1 * 8 slots * 4 drafts) = 3 accepted per iteration
        assert ctl.metrics["last_acceptance_rate"] == pytest.approx(3 / 32)
        # proposals stop after the fallback
        assert eng.metrics["spec_proposed"] < 24 * 8 * 4

    def test_healthy_acceptance_keeps_speculating(self):
        eng, ctl = self._drive(acceptance=0.8)
        assert ctl.metrics["spec_fallbacks"] == 0
        assert eng.spec_k == 4
        assert eng.metrics["spec_proposed"] == 24 * 8 * 4
        # round(0.8 * 32) = 26 accepted per iteration
        assert eng.metrics["acceptance_rate"] == pytest.approx(26 / 32)
        assert "spec[k=4" in eng.summary()


class TestSpecCostModel:
    def test_tokens_per_cycle(self):
        assert speculative_tokens_per_cycle(0, 0.9) == 1.0
        assert speculative_tokens_per_cycle(3, 0.0) == 1.0
        assert speculative_tokens_per_cycle(3, 1.0) == 4.0
        a = speculative_tokens_per_cycle(4, 0.6)
        assert a == pytest.approx(sum(0.6 ** i for i in range(5)))
        assert speculative_tokens_per_cycle(4, 0.8) > a

    def test_spec_off_is_bitwise_plain(self):
        """spec_k=0 (default) must not move a single bit of the QoS
        estimate — the frontier golden fixture depends on it."""
        cfg = get_config("mixtral-8x7b")
        from repro.core.planner import AdaptivePlanner
        planner = AdaptivePlanner(cfg, hw=HardwareModel())
        res = planner.plan(40e9, "quality", 8, batch_size=1)
        a = estimate_qos(cfg, res.plan, HardwareModel())
        b = estimate_qos(cfg, res.plan,
                         HardwareModel(spec_k=0, spec_acceptance=0.9))
        assert a.tokens_per_s.hex() == b.tokens_per_s.hex()
        assert b.t_draft_ms == 0.0 and b.spec_tokens_per_cycle == 1.0

    def test_speculation_prices_the_cycle(self):
        cfg = get_config("mixtral-8x7b")
        from repro.core.planner import AdaptivePlanner
        planner = AdaptivePlanner(cfg, hw=HardwareModel())
        full = cfg.non_expert_bytes() + cfg.num_layers \
            * cfg.moe.num_experts * cfg.expert_param_bytes(16)
        res = planner.plan(full * 1.05, "quality", 0, batch_size=1)
        plain = estimate_qos(cfg, res.plan, HardwareModel())
        spec = estimate_qos(cfg, res.plan, HardwareModel(
            spec_k=3, spec_acceptance=0.95))
        # draft reads ~4x fewer expert bytes -> cheaper than a token
        assert 0 < spec.t_draft_ms < plain.t_compute_ms
        assert spec.tokens_per_s > plain.tokens_per_s
        # zero acceptance only ever adds draft time
        worst = estimate_qos(cfg, res.plan, HardwareModel(
            spec_k=3, spec_acceptance=0.0))
        assert worst.tokens_per_s < plain.tokens_per_s

    def test_spec_variant_frontier_gated(self):
        """spec_variant(0, .) reproduces the base frontier's records
        byte-for-byte (fixture safety); a high-acceptance variant speeds
        every point up."""
        from repro.core.pareto import ParetoFrontier
        cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
        base = ParetoFrontier(cfg, HardwareModel())
        off = base.spec_variant(0, 0.9)
        assert off.records() == base.records()
        on = base.spec_variant(3, 0.9)
        assert len(on.points) > 0
        base_tps = {(p.num_q_experts, p.resident_experts):
                    p.qos.tokens_per_s for p in base.all_points}
        for p in on.all_points:
            assert p.qos.tokens_per_s > base_tps[
                (p.num_q_experts, p.resident_experts)] * 1.0 or \
                p.qos.spec_tokens_per_cycle >= 1.0
