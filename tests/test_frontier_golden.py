"""Golden regression fixture for the ParetoFrontier (DESIGN.md §10.4).

The frontier is the contract every declarative-serving layer (QoS
controller, multi-tenant arbiter, launch CLI) builds on: a silent
cost-model drift — a changed constant, a reordered float reduction, a
different rng consumption pattern in plan assignment — would move every
tenant's operating point without failing any behavioural test. This
fixture pins the ENUMERATED DOMINANT SET for one canonical
configuration (mixtral-8x7b, default HardwareModel, batch 1, seed 0)
bit-exactly: QoS floats are compared via ``float.hex()`` and each
point's concrete PrecisionPlan via a sha256 of its arrays.

On an INTENTIONAL cost-model/planner change, regenerate with:

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/test_frontier_golden.py -q

and commit the fixture diff alongside the change that caused it.
"""
import json
import os
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.core.pareto import ParetoFrontier

FIXTURE = Path(__file__).parent / "fixtures" \
    / "frontier_mixtral-8x7b_hw-default_b1_s0.json"


@pytest.fixture(scope="module")
def frontier():
    # pinned config: default HardwareModel(), batch_size=1, seed=0
    return ParetoFrontier(get_config("mixtral-8x7b"))


def test_dominant_set_matches_golden_fixture(frontier):
    records = frontier.records()
    if os.environ.get("REGEN_GOLDEN"):
        FIXTURE.parent.mkdir(parents=True, exist_ok=True)
        FIXTURE.write_text(json.dumps(records, indent=1) + "\n")
        pytest.skip(f"regenerated {FIXTURE.name}")
    assert FIXTURE.exists(), \
        f"golden fixture missing — regenerate with REGEN_GOLDEN=1 " \
        f"({FIXTURE})"
    golden = json.loads(FIXTURE.read_text())
    assert len(records) == len(golden), \
        f"dominant set size drifted: {len(records)} != {len(golden)}"
    for i, (got, want) in enumerate(zip(records, golden)):
        assert got == want, (
            f"frontier point {i} drifted:\n  got  {got}\n  want {want}\n"
            f"(bit-exact compare; intentional cost-model changes must "
            f"regenerate the fixture)")


def test_enumeration_is_deterministic_run_to_run(frontier):
    """Two independent enumerations in one process are bit-identical —
    no hidden global rng/state feeds the frontier."""
    again = ParetoFrontier(get_config("mixtral-8x7b"))
    assert again.records() == frontier.records()


def test_records_roundtrip_floats_bitexact(frontier):
    """float.hex() survives JSON round-tripping without precision loss."""
    rt = json.loads(json.dumps(frontier.records()))
    for rec, p in zip(rt, frontier.points):
        assert float.fromhex(rec["tokens_per_s"]) == p.qos.tokens_per_s
        assert float.fromhex(rec["quality_proxy"]) == p.qos.quality_proxy