"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward/train step + prefill/decode on CPU; output shapes + no NaNs.
The FULL configs are exercised via the dry-run only (ShapeDtypeStruct).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.models.model import build_model

BATCH, SEQ = 2, 32


def make_batch(cfg, rng):
    s_text = SEQ - (cfg.frontend_len if cfg.frontend == "vision" else 0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (BATCH, s_text)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (BATCH, s_text)), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((BATCH, cfg.frontend_len, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "encdec":
        batch["src"] = jnp.asarray(
            rng.standard_normal((BATCH, cfg.frontend_len, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = reduce_for_smoke(get_config(request.param))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return request.param, cfg, model, params


class TestSmoke:
    def test_train_step(self, arch_setup):
        arch, cfg, model, params = arch_setup
        batch = make_batch(cfg, np.random.default_rng(0))
        loss, metrics = jax.jit(model.loss_fn)(params, batch)
        assert np.isfinite(float(loss)), f"{arch}: loss not finite"
        assert float(loss) > 0
        g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                             for x in jax.tree_util.tree_leaves(g)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0, \
            f"{arch}: bad grad norm"

    def test_prefill_decode(self, arch_setup):
        arch, cfg, model, params = arch_setup
        rng = np.random.default_rng(1)
        batch = make_batch(cfg, rng)
        cache = model.init_cache(BATCH, SEQ + 8)
        logits, cache = jax.jit(model.prefill)(params, batch, cache)
        assert logits.shape == (BATCH, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), \
            f"{arch}: prefill logits not finite"
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        pos = jnp.full((BATCH,), SEQ, jnp.int32)
        logits2, cache = jax.jit(model.decode_step)(
            params, cache, tok.astype(jnp.int32), pos)
        assert logits2.shape == (BATCH, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits2, np.float32)).all(), \
            f"{arch}: decode logits not finite"

    def test_decode_matches_prefill(self, arch_setup):
        """Decoding token-by-token must equal a full prefill forward
        (cache-correctness invariant across every family)."""
        arch, cfg, model, params = arch_setup
        if cfg.family == "encdec":
            pytest.skip("covered via test_prefill_decode (src handling)")
        rng = np.random.default_rng(2)
        batch = make_batch(cfg, rng)
        n_text = batch["tokens"].shape[1]
        # full prefill logits for the last position
        cache_a = model.init_cache(BATCH, SEQ + 8)
        logits_full, _ = jax.jit(model.prefill)(params, batch, cache_a)
        # prefill on the first n-1 tokens, then one decode step
        short = dict(batch)
        short["tokens"] = batch["tokens"][:, :-1]
        short["labels"] = batch["labels"][:, :-1]
        cache_b = model.init_cache(BATCH, SEQ + 8)
        _, cache_b = jax.jit(model.prefill)(params, short, cache_b)
        pos = jnp.full((BATCH,), SEQ - 1, jnp.int32) \
            if cfg.frontend == "vision" else \
            jnp.full((BATCH,), n_text - 1, jnp.int32)
        logits_step, _ = jax.jit(model.decode_step)(
            params, cache_b, batch["tokens"][:, -1:], pos)
        np.testing.assert_allclose(
            np.asarray(logits_step, np.float32),
            np.asarray(logits_full, np.float32), rtol=0.15, atol=0.3)
