"""Unit + property tests for group-wise int4/int8 quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantization import (
    QTensor, dequantize, dequantize_nf4, dequantize_tree, pack_int4,
    quantization_rmse, quantize, quantize_nf4, quantize_tree, tree_nbytes,
    unpack_int4,
)


def rand(shape, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       dtype=dtype)


class TestPacking:
    def test_roundtrip_exact(self):
        q = jnp.asarray(
            np.random.default_rng(0).integers(-8, 8, (64, 32)), jnp.int8)
        np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(q))),
                                      np.asarray(q))

    def test_batched(self):
        q = jnp.asarray(
            np.random.default_rng(1).integers(-8, 8, (3, 16, 8)), jnp.int8)
        np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(q))),
                                      np.asarray(q))

    def test_odd_k_rejected(self):
        with pytest.raises(ValueError):
            pack_int4(jnp.zeros((3, 5), jnp.int8))

    @given(k2=st.integers(1, 16), n=st.integers(1, 16),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pack_unpack_property(self, k2, n, seed):
        q = jnp.asarray(
            np.random.default_rng(seed).integers(-8, 8, (2 * k2, n)),
            jnp.int8)
        np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(q))),
                                      np.asarray(q))


class TestQuantize:
    @pytest.mark.parametrize("bits,tol", [(4, 0.08), (8, 0.006)])
    @pytest.mark.parametrize("group", [32, 64, 128])
    def test_roundtrip_error(self, bits, tol, group):
        w = rand((256, 96))
        dq = dequantize(quantize(w, bits, group)).astype(jnp.float32)
        # symmetric absmax: max error <= scale/2 = absmax/(2*qmax)
        err = float(jnp.abs(w - dq).max() / jnp.abs(w).max())
        assert err < tol

    def test_shape_property(self):
        qt = quantize(rand((4, 128, 64)), 4, 32)
        assert qt.shape == (4, 128, 64)
        assert qt.q.shape == (4, 64, 64)
        assert qt.scales.shape == (4, 4, 64)

    def test_memory_ratio(self):
        w = rand((1024, 1024))
        q4, q8 = quantize(w, 4, 64), quantize(w, 8, 64)
        fp16 = w.size * 2
        assert q4.nbytes() < fp16 * 0.30      # ~0.28 with scales
        assert q8.nbytes() < fp16 * 0.55

    def test_zero_weight(self):
        dq = dequantize(quantize(jnp.zeros((64, 8)), 4, 64))
        assert not jnp.isnan(dq).any()
        np.testing.assert_array_equal(np.asarray(dq, np.float32), 0.0)

    def test_indivisible_group_rejected(self):
        with pytest.raises(ValueError):
            quantize(rand((100, 8)), 4, 64)

    @given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 1000),
           scale=st.floats(1e-3, 1e3))
    @settings(max_examples=20, deadline=None)
    def test_scale_invariance(self, bits, seed, scale):
        """Relative quantization error is invariant to weight scale."""
        w = rand((128, 16), seed)
        e1 = quantization_rmse(w, bits, 64)
        e2 = quantization_rmse(w * scale, bits, 64)
        assert e1 == pytest.approx(e2, rel=0.05, abs=1e-4)

    def test_error_monotone_in_bits(self):
        w = rand((512, 64), 7)
        e4 = quantization_rmse(w, 4, 64)
        e8 = quantization_rmse(w, 8, 64)
        assert e8 < e4 < 0.15

    def test_error_monotone_in_group(self):
        """Smaller groups = finer scales = lower error (outlier isolation)."""
        w = jnp.asarray(
            np.random.default_rng(3).standard_t(2, (512, 64)), jnp.float32)
        errs = [quantization_rmse(w, 4, g) for g in (32, 128, 512)]
        assert errs[0] < errs[-1]


class TestNF4:
    def test_nf4_beats_int4_on_gaussians(self):
        """bnb's NF4 codebook is quantile-optimal for normal weights —
        sanity check the quality-comparison path."""
        w = rand((512, 64), 5)
        assert quantization_rmse(w, nf4=True) < quantization_rmse(w, bits=4)

    def test_nf4_roundtrip(self):
        w = rand((128, 32), 9)
        dq = dequantize_nf4(*quantize_nf4(w, 64), 64).astype(jnp.float32)
        assert float(jnp.abs(w - dq).max() / jnp.abs(w).max()) < 0.2

    def test_nf4_roundtrip_exact_on_codebook_values(self):
        """Weights that ARE codebook entries (times a per-group absmax)
        must round-trip exactly: quantize_nf4 snaps to the nearest code,
        dequantize_nf4 rescales it — zero error when the input sits on
        the lattice."""
        from repro.core.quantization import NF4_CODE
        rng = np.random.default_rng(4)
        g, n = 16, 8
        codes = rng.integers(0, 16, (2 * g, n))
        w = NF4_CODE[codes].astype(np.float32)
        # give each group a distinct scale; keep one entry at ±1 per
        # (group, col) so absmax reconstructs the scale exactly
        w[0, :], w[g, :] = 1.0, -1.0
        scale = np.array([1.5, 0.25])[:, None, None]     # (2 groups)
        w = (w.reshape(2, g, n) * scale).reshape(2 * g, n)
        q, absmax = quantize_nf4(jnp.asarray(w), g)
        dq = dequantize_nf4(q, absmax, g).astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(dq), w, rtol=2e-2, atol=2e-2)

    @given(seed=st.integers(0, 500), g=st.sampled_from([16, 32, 64]))
    @settings(max_examples=15, deadline=None)
    def test_nf4_roundtrip_error_bounded_property(self, seed, g):
        """Property form of the round trip: relative max error is
        bounded by half the widest codebook gap (~0.14 of the group
        absmax) for any input."""
        w = rand((2 * g, 16), seed)
        dq = dequantize_nf4(*quantize_nf4(w, g), g).astype(jnp.float32)
        err = float(jnp.abs(w - dq).max() / (jnp.abs(w).max() + 1e-12))
        assert err < 0.15


class TestLadderMonotonicity:
    """rmse(4) >= rmse(8) >= 0 across the precision ladder — the
    assumption behind the cost model's per-rung quality costs
    (DESIGN.md §11)."""

    @given(seed=st.integers(0, 1000), group=st.sampled_from([32, 64]))
    @settings(max_examples=20, deadline=None)
    def test_rmse_monotone_across_ladder(self, seed, group):
        w = rand((256, 32), seed)
        e4 = quantization_rmse(w, 4, group)
        e8 = quantization_rmse(w, 8, group)
        assert e4 >= e8 >= 0.0
        assert e8 > 0.0            # int8 is lossy, not a no-op

    def test_rmse_ladder_ordering_heavy_tails(self):
        """Monotonicity must survive outlier-heavy weights (student-t),
        not just gaussians."""
        w = jnp.asarray(
            np.random.default_rng(6).standard_t(2, (512, 64)), jnp.float32)
        errs = [quantization_rmse(w, b, 64) for b in (4, 8)]
        assert errs[0] >= errs[1] >= 0.0


class TestTreeQuant:
    def test_tree_selectivity(self):
        params = {"big": rand((256, 64)), "norm": jnp.ones((256,)),
                  "small": rand((8, 8))}
        qp = quantize_tree(params, 4, 64)
        assert isinstance(qp["big"], QTensor)
        assert not isinstance(qp["norm"], QTensor)
        assert not isinstance(qp["small"], QTensor)
        dq = dequantize_tree(qp)
        assert dq["big"].shape == (256, 64)

    def test_tree_nbytes(self):
        params = {"w": rand((256, 64))}
        full = tree_nbytes(params)
        q = tree_nbytes(quantize_tree(params, 4, 64))
        assert q < full / 2
