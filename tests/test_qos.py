"""Declarative QoS surface (DESIGN.md §9): QoSController convergence /
hysteresis / budget-drop behaviour against the deterministic simulator
(``repro.serving.simulator``, DESIGN.md §10.4), the typed serving/api.py
types, and priority/deadline-aware admission.

The simulated engine implements exactly the interface the controller
needs (``metrics``, ``apply_frontier_point``) and reports a *measured*
throughput equal to the frontier point's analytic estimate times a
model-error factor — the controller must close that gap by walking the
frontier, just as it would against wall-clock drift in production.
"""
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.api import (EngineConfig, ParetoFrontier, QoSTarget,
                               RequestSLO, SamplingParams, ServeRequest,
                               ServeResult)
from repro.serving.qos import QoSController, QoSControllerConfig
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig
from repro.serving.simulator import (SimulatedEngine, budget_shock,
                                     run_scripted)

MIXTRAL = get_config("mixtral-8x7b")
GIB = 2**30

SimEngine = SimulatedEngine      # the promoted harness (was ad-hoc here)


@pytest.fixture(scope="module")
def frontier():
    return ParetoFrontier(MIXTRAL)


def run_sim(engine, controller, iterations: int):
    run_scripted(engine, controller, iterations)


class TestQoSController:
    def test_converges_onto_target(self, frontier):
        """The end-to-end declarative path: a QoSTarget(min_tokens_per_s)
        submitted through serving/api.py lands on a frontier point whose
        MEASURED throughput meets the target within tolerance, even
        though the cost model overestimates throughput 2x."""
        eng = SimEngine(model_error=0.5)
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            tolerance=0.1, min_dwell_iterations=4, window_iterations=2))
        target = QoSTarget(min_tokens_per_s=5.0,
                           mem_budget_bytes=60 * GIB)
        first = ctl.set_target(target)
        # analytically the first point meets 5 tok/s, but measured is 2x
        # lower: the controller must walk to faster points
        assert first.qos.tokens_per_s >= 5.0
        run_sim(eng, ctl, 200)
        measured = ctl.metrics["last_measured_tps"]
        assert measured >= 5.0 * (1 - ctl.config.tolerance)
        assert eng.point in frontier.points
        assert eng.point.qos.device_bytes <= 60 * GIB

    def test_no_action_when_on_target(self, frontier):
        """Perfect model -> selected point already measures on target ->
        zero further replans."""
        eng = SimEngine(model_error=1.0)
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            tolerance=0.1, min_dwell_iterations=4, window_iterations=2))
        ctl.set_target(QoSTarget(min_tokens_per_s=5.0,
                                 mem_budget_bytes=60 * GIB))
        run_sim(eng, ctl, 100)
        assert eng.replans == 1        # the initial set_target apply only

    def test_hysteresis_min_dwell(self, frontier):
        """After a replan the controller must dwell: replans are spaced
        at least min_dwell_iterations apart even under a persistently
        violated target."""
        eng = SimEngine(model_error=1e-6)      # target unreachable
        dwell = 16
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            tolerance=0.1, min_dwell_iterations=dwell,
            window_iterations=2))
        ctl.set_target(QoSTarget(min_tokens_per_s=5.0,
                                 mem_budget_bytes=60 * GIB))
        replan_iters = []
        for _ in range(150):
            eng.run_iteration()
            if ctl.step():
                replan_iters.append(eng.metrics["iterations"])
        assert replan_iters, "controller never walked despite violation"
        gaps = np.diff([0] + replan_iters)
        assert (gaps >= dwell).all()

    def test_budget_drop_single_replan_no_storm(self, frontier):
        """A scripted budget shock: exactly one immediate replan onto a
        feasible point, then quiet (no replan storm)."""
        eng = SimEngine(model_error=1.0)
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            tolerance=0.1, min_dwell_iterations=8, window_iterations=2))
        ctl.set_target(QoSTarget(min_tokens_per_s=math.inf,
                                 mem_budget_bytes=60 * GIB))
        run_sim(eng, ctl, 30)
        replans_before = eng.replans
        big_point = eng.point
        # the job manager shrinks the allocation under the active point
        run_scripted(eng, ctl, 60,
                     events={0: budget_shock(ctl, 20 * GIB)})
        assert not big_point.feasible_under(ctl.target)
        # exactly one feasibility fix, and it was IMMEDIATE (the first
        # post-shock replan already lands inside the new budget); then
        # best-effort at the fast end — no storm over 60 iterations
        assert eng.replans == replans_before + 1
        assert eng.applied[replans_before].qos.device_bytes <= 20 * GIB
        assert eng.point.qos.device_bytes <= 20 * GIB

    def test_quality_recovery_with_headroom(self, frontier):
        """Measured throughput far above target + quality headroom: the
        controller walks BACK toward better quality, but never below the
        target's predicted floor."""
        eng = SimEngine(model_error=1.0)
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            tolerance=0.1, min_dwell_iterations=2, window_iterations=2))
        t = QoSTarget(min_tokens_per_s=2.0, mem_budget_bytes=60 * GIB)
        # start the sim at the FASTEST feasible point, far over target
        fast = frontier.feasible(t)[-1]
        ctl.target = t
        ctl._apply(fast)
        q0 = fast.qos.quality_proxy
        run_sim(eng, ctl, 200)
        assert eng.point.qos.quality_proxy < q0
        assert eng.point.qos.tokens_per_s >= 2.0

    def test_inf_target_never_counts_violations(self, frontier):
        """min_tokens_per_s=inf is best effort, not a violable SLO: a
        healthy run must not report an ever-growing violation count."""
        eng = SimEngine(model_error=1.0)
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            tolerance=0.1, min_dwell_iterations=2, window_iterations=2))
        ctl.set_target(QoSTarget(min_tokens_per_s=math.inf,
                                 mem_budget_bytes=60 * GIB))
        run_sim(eng, ctl, 60)
        assert ctl.metrics["violations"] == 0
        assert ctl.metrics["decisions"] > 0

    def test_p95_violation_walks_faster(self, frontier):
        """Scriptable per-point latency: a p95 ceiling only the runtime
        can see walks the controller to faster points until it holds."""
        eng = SimEngine(model_error=1.0,
                        latency_fn=lambda p, it: 4.0 / p.qos.tokens_per_s)
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            tolerance=0.1, min_dwell_iterations=2, window_iterations=2))
        p0 = ctl.set_target(QoSTarget(min_tokens_per_s=1.0,
                                      mem_budget_bytes=60 * GIB))
        # ceiling needs ~2x the initial point's speed
        ceiling = 2.0 / p0.qos.tokens_per_s
        ctl.target = QoSTarget(min_tokens_per_s=1.0,
                               mem_budget_bytes=60 * GIB,
                               max_p95_latency_s=ceiling)
        run_sim(eng, ctl, 120)
        assert eng.point.qos.tokens_per_s >= 2.0 * p0.qos.tokens_per_s \
            * (1 - ctl.config.tolerance)
        assert ctl.metrics["violations"] > 0

    def test_violation_hook_fires(self, frontier):
        """on_violation (the multi-tenant arbiter's trigger) fires once
        per recorded violation."""
        fired = []
        eng = SimEngine(model_error=1e-6)      # target unreachable
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            tolerance=0.1, min_dwell_iterations=2, window_iterations=2),
            on_violation=lambda: fired.append(1))
        ctl.set_target(QoSTarget(min_tokens_per_s=5.0,
                                 mem_budget_bytes=60 * GIB))
        run_sim(eng, ctl, 40)
        assert len(fired) == ctl.metrics["violations"] > 0


class TestSimulatorHarness:
    """The promoted simulator itself (serving/simulator.py): determinism,
    the virtual clock, and the scripting hooks."""

    def test_virtual_clock_tracks_simulated_decode_time(self, frontier):
        eng = SimEngine(model_error=1.0)
        ctl = QoSController(eng, frontier, QoSControllerConfig(
            min_dwell_iterations=2, window_iterations=2))
        ctl.set_target(QoSTarget(min_tokens_per_s=math.inf,
                                 mem_budget_bytes=60 * GIB))
        run_sim(eng, ctl, 25)
        assert eng.clock.now() == pytest.approx(eng.metrics["decode_s"])
        assert eng.clock.now() > 0.0

    def test_replay_is_bit_identical(self, frontier):
        """Two runs of the same scenario produce identical traces — the
        property every convergence assertion in this file leans on."""
        def scenario():
            eng = SimEngine(model_error=0.7)
            ctl = QoSController(eng, frontier, QoSControllerConfig(
                tolerance=0.1, min_dwell_iterations=4,
                window_iterations=2))
            ctl.set_target(QoSTarget(min_tokens_per_s=4.0,
                                     mem_budget_bytes=60 * GIB))
            run_scripted(eng, ctl, 80,
                         events={40: budget_shock(ctl, 30 * GIB)})
            return eng
        a, b = scenario(), scenario()
        assert a.metrics == b.metrics
        assert a.clock.now() == b.clock.now()
        assert [id(p) for p in a.applied] == [id(p) for p in b.applied]

    def test_scriptable_throughput_schedule(self, frontier):
        """throughput_fn overrides model_error with an iteration-indexed
        schedule (co-tenant interference arriving mid-run)."""
        point = frontier.points[len(frontier.points) // 2]
        tps = point.qos.tokens_per_s
        eng = SimEngine(
            throughput_fn=lambda p, it: tps * (1.0 if it < 10 else 0.5))
        eng.apply_frontier_point(point)
        for _ in range(10):
            eng.run_iteration()
        t_fast = eng.metrics["decode_s"]
        for _ in range(10):
            eng.run_iteration()
        t_all = eng.metrics["decode_s"]
        assert (t_all - t_fast) == pytest.approx(2 * t_fast)

    def test_clock_rejects_negative_time(self):
        from repro.serving.simulator import VirtualClock
        clk = VirtualClock()
        with pytest.raises(ValueError):
            clk.advance(-1.0)


class TestServingApiTypes:
    def test_engine_config_defaults(self):
        c = EngineConfig()
        assert c.max_slots == 8 and c.max_len == 256
        assert c.hw is None and not c.prefetch

    def test_serve_result_from_request(self):
        s = ContinuousScheduler(SchedulerConfig(max_slots=1, max_len=32))
        rid = s.submit(np.arange(1, 4), 2, now=1.0,
                       slo=RequestSLO(priority=3, deadline_s=5.0))
        s.admit(now=2.0)
        s.slots[0].req.t_first = 2.5
        s.slots[0].req.out_tokens.extend([7, 8])
        s.retire(0, now=3.0)
        r = ServeResult.from_request(s.done[rid])
        assert r.tokens == [7, 8]
        assert r.latency_s == pytest.approx(2.0)
        assert r.ttft_s == pytest.approx(1.5)
        assert r.priority == 3 and r.deadline_met is True
        assert "MET" in r.summary()

    def test_serve_result_requires_completion(self):
        s = ContinuousScheduler(SchedulerConfig(max_slots=1, max_len=32))
        rid = s.submit(np.arange(1, 4), 2)
        with pytest.raises(ValueError, match="in flight"):
            ServeResult.from_request(s.queue[0])
        del rid

    def test_deadline_missed(self):
        s = ContinuousScheduler(SchedulerConfig(max_slots=1, max_len=32))
        rid = s.submit(np.arange(1, 4), 1, now=0.0,
                       slo=RequestSLO(deadline_s=1.0))
        s.admit(now=0.5)
        s.retire(0, now=2.0)
        assert s.done[rid].deadline_met is False

    def test_latency_percentiles_windowed(self):
        """last_n restricts percentiles to the most recent completions —
        the QoSController's p95 must forget cold-start samples."""
        s = ContinuousScheduler(SchedulerConfig(max_slots=1, max_len=32))
        for i, lat in enumerate((10.0, 10.0, 1.0, 1.0)):
            rid = s.submit(np.arange(2), 1, now=float(i * 100))
            s.admit(now=float(i * 100))
            s.retire(0, now=float(i * 100) + lat)
            del rid
        assert s.latency_percentiles((95,))["p95"] > 5.0
        assert s.latency_percentiles((95,), last_n=2)["p95"] <= 1.0


class TestPriorityAdmission:
    def mk(self, **kw):
        return ContinuousScheduler(SchedulerConfig(**kw))

    def test_priority_jumps_queue(self):
        s = self.mk(max_slots=1, max_len=32)
        s.submit(np.arange(4), 4, now=0.0)
        hi = s.submit(np.arange(4), 4, now=1.0,
                      slo=RequestSLO(priority=5))
        joined = s.admit()
        assert joined[0][1].rid == hi

    def test_deadline_orders_within_priority(self):
        s = self.mk(max_slots=1, max_len=32)
        s.submit(np.arange(4), 4, now=0.0,
                 slo=RequestSLO(priority=1, deadline_s=100.0))
        urgent = s.submit(np.arange(4), 4, now=1.0,
                          slo=RequestSLO(priority=1, deadline_s=5.0))
        joined = s.admit()
        assert joined[0][1].rid == urgent

    def test_deadline_beats_no_deadline_fifo_otherwise(self):
        s = self.mk(max_slots=1, max_len=32)
        nodl = s.submit(np.arange(4), 4, now=0.0)
        dl = s.submit(np.arange(4), 4, now=1.0,
                      slo=RequestSLO(deadline_s=9.0))
        assert s.admit()[0][1].rid == dl
        s.retire(0)
        assert s.admit()[0][1].rid == nodl

    def test_fifo_preserved_without_slo(self):
        s = self.mk(max_slots=2, max_len=32)
        r1 = s.submit(np.arange(4), 4)
        r2 = s.submit(np.arange(4), 4)
        assert [rq.rid for _, rq in s.admit()] == [r1, r2]

    def test_sampling_params_attached(self):
        s = self.mk(max_slots=1, max_len=32)
        s.submit(np.arange(4), 4,
                 sampling=SamplingParams(temperature=0.7, top_k=5))
        (_, req), = s.admit()
        assert req.sampling.temperature == 0.7
        assert req.sampling.top_k == 5

    def test_serve_request_defaults(self):
        r = ServeRequest(prompt=np.arange(3))
        assert r.slo.priority == 0 and r.sampling is None
