"""AsyncExpertCache — the overlapped staging engine (DESIGN.md §12):
non-blocking prefetch, demand wait, LRU correctness with fetches in
flight, drain/close lifecycle and worker-thread hygiene."""
import threading
import time

import numpy as np
import pytest

from repro.core.expert_cache import AsyncExpertCache, ExpertCache


def make_async(capacity_experts=4, expert_kb=1, fetch_delay_s=0.0, **kw):
    nbytes = expert_kb * 1024
    fetched = []

    def fetch(key):
        if fetch_delay_s:
            time.sleep(fetch_delay_s)
        fetched.append(key)
        return np.zeros(nbytes, np.uint8) + (key[1] % 250)

    cache = AsyncExpertCache(fetch,
                             capacity_bytes=capacity_experts * nbytes,
                             **kw)
    return cache, fetched, nbytes


def xfer_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("expert-xfer")]


class TestAsyncStaging:
    def test_prefetch_is_non_blocking(self):
        c, fetched, _ = make_async(fetch_delay_s=0.05)
        t0 = time.perf_counter()
        n = c.prefetch([(0, 0), (0, 1)])
        enqueue_s = time.perf_counter() - t0
        assert n == 2
        assert enqueue_s < 0.04          # returned before the fetches ran
        c.drain()
        assert set(fetched) == {(0, 0), (0, 1)}
        assert set(c.resident_keys()) == {(0, 0), (0, 1)}
        c.close()

    def test_speculative_traffic_never_pollutes_demand_stats(self):
        c, _, nb = make_async()
        c.prefetch([(0, 0), (0, 1)])
        c.drain()
        assert c.stats.prefetch_bytes == 2 * nb
        assert c.stats.bytes_in == 0
        assert c.stats.misses == 0
        assert c.stats.transfer_s == 0.0
        assert c.stats.prefetch_s > 0.0
        # demanding the prefetched keys is a hit, not a transfer
        assert c.wait([(0, 0), (0, 1)]) == 0
        assert c.stats.hits == 2 and c.stats.bytes_in == 0
        c.close()

    def test_wait_demand_fetches_and_accounts(self):
        c, _, nb = make_async()
        fetched = c.wait([(1, 0), (1, 1), (1, 2)])
        assert fetched == 3
        assert c.stats.misses == 3
        assert c.stats.bytes_in == 3 * nb
        assert c.stats.transfer_s > 0.0
        assert set(c.resident_keys()) == {(1, 0), (1, 1), (1, 2)}
        c.close()

    def test_demand_on_inflight_speculative_blocks_remainder_only(self):
        c, _, _ = make_async(fetch_delay_s=0.05)
        c.prefetch([(2, 0)])
        # the speculative fetch is (very likely) still in flight: the
        # demand attaches to its future instead of re-transferring
        fetched = c.wait([(2, 0)])
        assert fetched == 0
        assert c.stats.misses == 0
        assert c.stats.bytes_in == 0            # traffic stayed speculative
        assert c.stats.prefetch_bytes > 0
        assert (2, 0) in c.resident_keys()
        c.close()

    def test_get_demand_and_hit_paths(self):
        c, _, _ = make_async()
        v = c.get((3, 7))
        assert int(np.asarray(v)[0]) == 7
        assert c.stats.misses == 1
        c.get((3, 7))
        assert c.stats.hits == 1
        c.close()

    def test_prefetch_dedupes_inflight_and_resident(self):
        c, fetched, _ = make_async(fetch_delay_s=0.02)
        assert c.prefetch([(0, 0)]) == 1
        assert c.prefetch([(0, 0)]) == 0        # already in flight
        c.drain()
        assert c.prefetch([(0, 0)]) == 0        # already resident
        assert c.prefetch_hits == 1
        assert fetched.count((0, 0)) == 1
        c.close()


class TestAsyncLRU:
    def test_capacity_respected_with_inflight_fetches(self):
        c, _, nb = make_async(capacity_experts=2, fetch_delay_s=0.005)
        c.prefetch([(0, i) for i in range(6)])
        c.drain()
        assert len(c.resident_keys()) <= 2
        assert c.used_bytes <= c.capacity
        assert c.stats.evictions >= 4
        c.close()

    def test_prefetch_hit_touches_lru_recency(self):
        """A predicted key about to be demanded must move to MRU on the
        prefetch hit — otherwise the current layer's admissions evict it
        right before its wait() and the prediction buys nothing."""
        c, _, _ = make_async(capacity_experts=2)
        c.wait([(0, 0), (0, 1)])                # LRU order: 0 then 1
        c.prefetch([(0, 0)])                    # predicted next: touch
        c.wait([(0, 2)])                        # evicts LRU -> now (0, 1)
        assert (0, 0) in c.resident_keys()
        assert (0, 1) not in c.resident_keys()
        c.close()

    def test_evicted_prefetch_is_refetched_on_demand(self):
        c, _, _ = make_async(capacity_experts=2)
        c.prefetch([(0, 0)])
        c.drain()
        c.wait([(0, 1), (0, 2)])                # LRU-evicts (0, 0)
        assert (0, 0) not in c.resident_keys()
        assert c.wait([(0, 0)]) == 1            # honest demand re-fetch
        assert (0, 0) in c.resident_keys()
        c.close()

    def test_resize_shrink_evicts_down_immediately(self):
        """DESIGN.md §12 / satellite: shrinking below used_bytes must not
        leave the cache over budget until the next admission."""
        c, _, nb = make_async(capacity_experts=4)
        c.wait([(0, i) for i in range(4)])
        assert c.used_bytes == 4 * nb
        c.resize(2 * nb)
        assert c.used_bytes <= c.capacity == 2 * nb
        assert len(c.resident_keys()) <= 2
        assert c.stats.evictions >= 2
        c.close()


class TestLifecycle:
    def test_close_joins_workers_and_is_idempotent(self):
        c, _, _ = make_async(fetch_delay_s=0.01)
        c.prefetch([(0, i) for i in range(4)])
        c.close()
        assert not any(t.is_alive() for t in xfer_threads())
        c.close()                               # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            c.wait([(9, 9)])

    def test_drain_is_a_barrier(self):
        c, fetched, _ = make_async(capacity_experts=8, fetch_delay_s=0.01)
        c.prefetch([(0, i) for i in range(5)])
        c.drain()
        assert len(fetched) == 5
        c.close()


class TestScopedAsync:
    def make_shared(self, capacity_experts=4):
        nbytes = 1024
        parent = AsyncExpertCache(capacity_bytes=capacity_experts * nbytes)
        a = parent.scoped("A", lambda k: np.full(nbytes, 1, np.uint8))
        b = parent.scoped("B", lambda k: np.full(nbytes, 2, np.uint8))
        return parent, a, b, nbytes

    def test_views_report_async_and_namespace_keys(self):
        parent, a, b, nb = self.make_shared()
        assert a.is_async and b.is_async
        a.prefetch([(0, 0)])
        parent.drain()
        assert a.resident_keys() == [(0, 0)]
        assert b.resident_keys() == []          # other namespace untouched
        assert parent.stats.prefetch_bytes == nb
        parent.close()

    def test_wait_demand_accounting_per_owner(self):
        parent, a, b, nb = self.make_shared()
        assert a.wait([(0, 0), (0, 1)]) == 2
        assert a.stats.misses == 2 and a.stats.bytes_in == 2 * nb
        assert b.stats.misses == 0
        assert b.wait([(0, 0)]) == 1            # same key, own namespace
        assert b.stats.misses == 1
        assert int(np.asarray(b.get((0, 0)))[0]) == 2
        parent.close()

    def test_scoped_get_threadsafe_demand(self):
        parent, a, _, nb = self.make_shared()
        v = a.get((4, 4))
        assert int(np.asarray(v)[0]) == 1
        assert a.stats.misses == 1 and a.stats.bytes_in == nb
        a.get((4, 4))
        assert a.stats.hits == 1
        parent.close()

    def test_sync_parent_rejects_async_ops(self):
        parent = ExpertCache(capacity_bytes=4096)
        view = parent.scoped("solo", lambda k: np.zeros(16, np.uint8))
        assert not view.is_async
        with pytest.raises(RuntimeError, match="synchronous"):
            view.wait([(0, 0)])
        # but hint() still works: inline speculative admit
        view.hint([(0, 0)])
        assert view.resident_keys() == [(0, 0)]
        assert parent.stats.prefetch_bytes == 16
        assert parent.stats.bytes_in == 0
