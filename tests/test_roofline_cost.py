"""Unit tests for the trip-count-corrected FLOP/traffic parser and the
roofline analysis (deliverable g)."""
import numpy as np
import pytest

from repro.roofline.hlo_parse import (comp_multipliers_full, cost_summary,
                                      shape_bytes)

# A scan-shaped module: 8-trip while whose body does one 16x256 @ 256x128
# dot inside; a dynamic-slice of a stacked weight; a DUS stash; a fusion
# whose body scatter-adds into an aliased buffer.
SYNTH = """\
HloModule jit_step, num_partitions=4

%scatter_body (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%wrapped_scatter_comp (p0: f32[1024,64], p1: s32[512,1], p2: f32[512,64]) -> f32[1024,64] {
  %p0 = f32[1024,64]{1,0} parameter(0)
  %p1 = s32[512,1]{1,0} parameter(1)
  %p2 = f32[512,64]{1,0} parameter(2)
  ROOT %sc = f32[1024,64]{1,0} scatter(%p0, %p1, %p2), to_apply=%scatter_body
}

%stash_comp (p0: s32[], p1: bf16[8,16,128], p2: bf16[16,128]) -> bf16[8,16,128] {
  %p0 = s32[] parameter(0)
  %p1 = bf16[8,16,128]{2,1,0} parameter(1)
  %cv1 = f32[8,16,128]{2,1,0} convert(%p1)
  %p2 = bf16[16,128]{1,0} parameter(2)
  %cv2 = f32[16,128]{1,0} convert(%p2)
  %bc = f32[1,16,128]{2,1,0} bitcast(%cv2)
  %c0 = s32[] constant(0)
  %dus = f32[8,16,128]{2,1,0} dynamic-update-slice(%cv1, %bc, %p0, %c0, %c0)
  ROOT %out = bf16[8,16,128]{2,1,0} convert(%dus)
}

%body (p: (s32[], f32[16,256], f32[8,256,128], bf16[8,16,128])) -> (s32[], f32[16,256], f32[8,256,128], bf16[8,16,128]) {
  %p = (s32[], f32[16,256], f32[8,256,128], bf16[8,16,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16,256]{1,0} get-tuple-element(%p), index=1
  %ws = f32[8,256,128]{2,1,0} get-tuple-element(%p), index=2
  %st = bf16[8,16,128]{2,1,0} get-tuple-element(%p), index=3
  %c0 = s32[] constant(0)
  %w = f32[1,256,128]{2,1,0} dynamic-slice(%ws, %i, %c0, %c0), dynamic_slice_sizes={1,256,128}
  %wb = f32[256,128]{1,0} bitcast(%w)
  %y = f32[16,128]{1,0} dot(%x, %wb), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %yb = bf16[16,128]{1,0} convert(%y)
  %st2 = bf16[8,16,128]{2,1,0} fusion(%i, %st, %yb), kind=kLoop, calls=%stash_comp
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[16,256], f32[8,256,128], bf16[8,16,128]) tuple(%ni, %x, %ws, %st2)
}

%cond (p: (s32[], f32[16,256], f32[8,256,128], bf16[8,16,128])) -> pred[] {
  %p = (s32[], f32[16,256], f32[8,256,128], bf16[8,16,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[16,256], ws: f32[8,256,128]) -> f32[1024,64] {
  %x = f32[16,256]{1,0} parameter(0)
  %ws = f32[8,256,128]{2,1,0} parameter(1)
  %zero = s32[] constant(0)
  %stash = bf16[8,16,128]{2,1,0} broadcast(%zero)
  %t0 = (s32[], f32[16,256], f32[8,256,128], bf16[8,16,128]) tuple(%zero, %x, %ws, %stash)
  %w = (s32[], f32[16,256], f32[8,256,128], bf16[8,16,128]) while(%t0), condition=%cond, body=%body
  %buf = f32[1024,64]{1,0} broadcast(%zero)
  %idx = s32[512,1]{1,0} broadcast(%zero)
  %upd = f32[512,64]{1,0} broadcast(%zero)
  ROOT %out = f32[1024,64]{1,0} fusion(%buf, %idx, %upd), kind=kLoop, calls=%wrapped_scatter_comp
}
"""


class TestCostSummary:
    def test_dot_flops_trip_weighted(self):
        cs = cost_summary(SYNTH)
        # one dot per iteration: 2*16*128*256 flops, 8 iterations
        assert cs["flops"] == 8 * 2 * 16 * 128 * 256
        assert cs["dot_count"] == 8

    def test_dynamic_slice_counts_slice_not_stack(self):
        cs = cost_summary(SYNTH)
        # the (8,256,128) weight stack must NOT be charged per iteration:
        # 8 iters x full stack would alone be 8*8*256*128*4 = 8.4 MB
        full_stack_per_iter = 8 * 8 * 256 * 128 * 4
        assert cs["bytes_accessed"] < full_stack_per_iter

    def test_dus_fusion_charges_update_not_buffer(self):
        cs = cost_summary(SYNTH)
        # stash fusion: aliased bf16[8,16,128] target; per iteration charge
        # = update read (16,128 bf16) + update write (f32 bitcast) + index
        per_iter = 16 * 128 * 2 + 1 * 16 * 128 * 4 + 4
        # exact accounting: DS(2x slice) + dot(x+w+y) + convert + stash
        # fusion + entry broadcasts + scatter fusion
        assert cs["bytes_accessed"] < 5.5e6      # aliased: not 8x full stash
        got_stash = per_iter * 8
        # 8 un-aliased iterations would re-read+write the buffer each time
        # (8 * 2 * 32 KiB = 512 KiB); the aliased charge stays under 1/4
        # of one such pass
        assert got_stash < 4 * (8 * 16 * 128 * 2)

    def test_scatter_fusion_alias(self):
        # the entry scatter fusion: target f32[1024,64] aliased; charge
        # ~3x update (512,64) + indices, NOT 2x full target + update
        cs = cost_summary(SYNTH)
        comps, mult, called = comp_multipliers_full(SYNTH)
        assert "wrapped_scatter_comp" in called
        assert mult["body"] == 8

    def test_multiplier_propagates_into_fusion_bodies(self):
        comps, mult, called = comp_multipliers_full(SYNTH)
        assert mult.get("stash_comp") == 8   # called from the loop body


class TestAnalysis:
    def test_cells_load_and_terms_positive(self):
        from repro.roofline import analysis as A
        cells = A.load_all()
        if not cells:
            pytest.skip("no dryrun results present")
        assert len({(c.arch, c.shape, c.mesh) for c in cells}) == len(cells)
        for c in cells:
            assert c.t_memory > 0
            assert c.bound >= max(c.t_compute, c.t_collective)
            assert c.dominant in ("compute", "memory", "collective")
            assert 0 <= c.mfu_bound <= 1.05

    def test_model_flops_conventions(self):
        from repro.roofline import analysis as A
        rec = {"active_params_b": 1.0}
        # train: 6*N*D, decode: 2*N*batch
        assert A.model_flops_for("train_4k", rec) == \
            6 * 1e9 * 4096 * 256
        assert A.model_flops_for("decode_32k", rec) == 2 * 1e9 * 128

    def test_pick_three(self):
        from repro.roofline import analysis as A
        cells = A.load_all()
        if not cells:
            pytest.skip("no dryrun results present")
        picks = A.pick_hillclimb_cells(cells)
        assert set(picks) == {"worst-mfu", "most-collective",
                              "paper-representative"}
        assert picks["paper-representative"].arch == "mixtral-8x7b"
