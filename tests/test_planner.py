"""Planner / precision-plan / cost-model tests against the paper's numbers."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (
    AdaptivePlanner, DEVICE, HOST, balanced_random_plan, estimate_qos,
    num_e16_eq1, pareto_frontier, reconfig_delta,
)
from repro.core.cost_model import HardwareModel
from repro.core.precision_plan import delta_cost_bytes

GIB = 2**30
MIXTRAL = get_config("mixtral-8x7b")


class TestPaperConstants:
    def test_expert_size_matches_paper(self):
        """Paper §4.1: 'Each expert occupies 336 MB'."""
        assert MIXTRAL.expert_param_bytes(16) == 336 * 2**20

    def test_non_expert_size_close_to_paper(self):
        """Paper §4.1: non-expert layers total 3.16 GB (ours ~3.0 GB — the
        paper includes framework buffers)."""
        ne = MIXTRAL.non_expert_bytes() / 1e9
        assert 2.5 < ne < 3.5

    def test_eq1_regimes(self):
        s_ne = MIXTRAL.non_expert_bytes()
        s4 = MIXTRAL.expert_param_bytes(4)
        s16 = MIXTRAL.expert_param_bytes(16)
        # below the all-4-bit footprint -> 0 sixteen-bit experts
        assert num_e16_eq1(20 * GIB, s_ne, 256, s4, s16) == 0
        # enough for everything in 16-bit -> all 256
        assert num_e16_eq1(95 * GIB, s_ne, 256, s4, s16) == 256
        # monotone in the budget
        vals = [num_e16_eq1(g * GIB, s_ne, 256, s4, s16)
                for g in range(20, 96, 5)]
        assert vals == sorted(vals)


class TestBalancedRandomPlan:
    @given(nq=st.integers(0, 256), seed=st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_counts_balanced(self, nq, seed):
        p = balanced_random_plan(32, 8, nq, seed=seed)
        per_layer = p.quant.sum(axis=1)
        assert (per_layer == per_layer[0]).all()
        assert abs(p.num_q_experts - nq) <= 16  # rounding to L multiples

    def test_randomness_across_layers(self):
        p = balanced_random_plan(32, 8, 128, seed=0)
        # with 4 of 8 quantized per layer, layers should differ
        assert len({tuple(r) for r in p.quant}) > 4

    def test_priority_quantized_resident_first(self):
        """Paper §3: 4-bit experts get device priority."""
        p = balanced_random_plan(4, 8, 16, resident_experts=16, seed=1)
        assert ((p.location == DEVICE) == p.quant).all()

    def test_resident_zero_and_all(self):
        p0 = balanced_random_plan(4, 8, 8, resident_experts=0)
        assert (p0.location == HOST).all()
        p1 = balanced_random_plan(4, 8, 8, resident_experts=32)
        assert (p1.location == DEVICE).all()

    def test_expert_order_is_permutation(self):
        p = balanced_random_plan(8, 8, 24, seed=3)
        order = p.expert_order()
        for l in range(8):
            assert sorted(order[l]) == list(range(8))
            e4 = p.bank_sizes()[0]
            assert p.quant[l, order[l][:e4]].all()
            assert not p.quant[l, order[l][e4:]].any()


class TestPlanner:
    def setup_method(self, _):
        self.pl = AdaptivePlanner(MIXTRAL)

    @pytest.mark.parametrize("gb", [10, 20, 26.28, 40, 53.03, 94])
    def test_budget_respected(self, gb):
        r = self.pl.plan(gb * GIB, "throughput")
        assert r.qos.device_bytes <= gb * GIB * 1.001

    def test_throughput_monotone_in_budget_offload_region(self):
        """Fig. 3: more memory -> fewer misses -> faster (hyperbolic)."""
        ts = [self.pl.plan(g * GIB, "throughput").qos.tokens_per_s
              for g in (8, 12, 16, 20, 24, 26)]
        assert ts == sorted(ts)

    def test_quality_mode_more_q4_is_faster_but_worse(self):
        lo = self.pl.plan(30 * GIB, "quality", num_q_experts=64)
        hi = self.pl.plan(30 * GIB, "quality", num_q_experts=256)
        assert hi.qos.tokens_per_s > lo.qos.tokens_per_s
        assert hi.qos.quality_proxy > lo.qos.quality_proxy

    def test_paper_throughput_range_covered(self):
        """Paper: 26.28..53.03 GB budgets span ~0.63..13 tok/s on A100+PCIe.
        With paper-like hardware constants (no fused-kernel advantage) our
        model must cover a comparable dynamic range."""
        hw = HardwareModel(host_link_bw=20e9, hbm_bw=1555e9, mbu=0.35,
                           q4_speedup_decode=0.9)
        pl = AdaptivePlanner(MIXTRAL, hw=hw)
        lo = pl.plan(8 * GIB, "throughput").qos.tokens_per_s
        hi = pl.plan(53.03 * GIB, "throughput").qos.tokens_per_s
        assert hi / lo > 5.0
        assert 0.1 < lo < 5.0
        assert 3.0 < hi < 60.0

    def test_reconfig_delta_minimal(self):
        r1, _ = self.pl.replan(40 * GIB, "quality", num_q_experts=128)
        r2, delta = self.pl.replan(40 * GIB, "quality", num_q_experts=128)
        # identical plan -> zero ops
        assert delta["traffic_bytes"] == 0
        assert len(delta["to_quantize"]) == 0

    def test_reconfig_traffic_less_than_reload(self):
        r1, _ = self.pl.replan(40 * GIB, "quality", num_q_experts=128)
        r2, delta = self.pl.replan(36 * GIB, "quality", num_q_experts=160)
        assert delta["traffic_bytes"] < r2.qos.device_bytes

    def test_sweep_pareto(self):
        res, pareto = self.pl.sweep(40 * GIB)
        assert len(res) >= 9
        pts = [(r.qos.tokens_per_s, r.qos.quality_proxy) for r in res]
        # every non-pareto point is dominated by some pareto point
        for i, p in enumerate(pts):
            if i in pareto:
                continue
            assert any(pts[j][0] >= p[0] and pts[j][1] <= p[1]
                       for j in pareto)

    def test_dense_arch_rejected(self):
        with pytest.raises(ValueError):
            AdaptivePlanner(get_config("qwen3-8b"))

    def test_kimi_scale(self):
        """1T-param MoE: planner must handle per-chip budgets that hold only
        a small expert fraction."""
        pl = AdaptivePlanner(get_config("kimi-k2-1t-a32b"))
        r = pl.plan(100 * GIB, "throughput")
        assert r.plan.num_q_experts == 61 * 384       # all 4-bit
        assert 0 < r.plan.resident_fraction() < 0.5
        assert r.qos.device_bytes <= 100 * GIB


class TestParetoFrontier:
    def test_simple(self):
        pts = [(1.0, 1.0), (2.0, 1.05), (0.5, 0.99), (2.0, 1.2)]
        f = pareto_frontier(pts)
        assert 1 in f and 2 in f and 3 not in f

    @given(st.lists(st.tuples(st.floats(0.1, 100), st.floats(1.0, 2.0)),
                    min_size=1, max_size=30))
    @settings(max_examples=30, deadline=None)
    def test_frontier_nonempty_and_nondominated(self, pts):
        f = pareto_frontier(pts)
        assert f
        for i in f:
            for j in f:
                if i != j:
                    assert not (pts[j][0] >= pts[i][0]
                                and pts[j][1] < pts[i][1])
