"""Test bootstrap.

This container has no ``hypothesis`` wheel and nothing may be pip-installed,
so when the real package is missing we register a minimal deterministic
stand-in: ``@given`` degrades to N seeded examples per test (seeded from the
test's qualified name, so runs are reproducible). With the real package
installed the stub never activates.
"""
import sys

try:
    import hypothesis  # noqa: F401  (real package wins when present)
except ImportError:

    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def _span(min_value, max_value, args):
        if args:                      # positional (min, max) call style
            min_value, max_value = args
        return min_value, max_value

    def integers(min_value=0, max_value=None, *args):
        lo, hi = _span(min_value, max_value, args)
        hi = (1 << 31) - 1 if hi is None else hi
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def floats(min_value=0.0, max_value=1.0, *args, **_):
        lo, hi = _span(min_value, max_value, args)
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def tuples(*elems):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def lists(elem, min_size=0, max_size=None, **_):
        hi = min_size + 10 if max_size is None else max_size
        return _Strategy(lambda rng: [elem.draw(rng) for _ in
                                      range(rng.randint(min_size, hi))])

    def settings(**kw):
        def deco(fn):
            merged = {**getattr(fn, "_hyp_settings", {}), **kw}
            fn._hyp_settings = merged
            return fn
        return deco

    def given(*gargs, **gkwargs):
        def deco(fn):
            # NB: no functools.wraps — __wrapped__ would make pytest
            # introspect fn's signature and hunt fixtures for drawn args
            def wrapper(*args, **kwargs):
                conf = {**getattr(fn, "_hyp_settings", {}),
                        **getattr(wrapper, "_hyp_settings", {})}
                n = conf.get("max_examples", 10)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = [g.draw(rng) for g in gargs]
                    dkw = {k: g.draw(rng) for k, g in gkwargs.items()}
                    fn(*args, *drawn, **kwargs, **dkw)
            for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
                setattr(wrapper, attr, getattr(fn, attr, None))
            wrapper._hyp_settings = getattr(fn, "_hyp_settings", {})
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    for _name, _fn in (("integers", integers), ("floats", floats),
                       ("sampled_from", sampled_from), ("tuples", tuples),
                       ("lists", lists)):
        setattr(_st, _name, _fn)
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow="too_slow")
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
