"""Trace-driven control plane (DESIGN.md §14): determinism,
no-starvation under preemption, autoscaler hysteresis, the
one-arbitration-per-budget-shock invariant at 1000-tenant scale, the
golden scenario report, and the pluggable policy seams.

Regenerate the golden fixture after an INTENTIONAL behaviour change:

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/test_control_plane.py -k golden -q
"""
import dataclasses
import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pareto import ParetoFrontier, QoSTarget
from repro.serving.control_plane import (ControlPlane, DEFAULT_SLO_CLASSES,
                                         MMPPArrivals, ReplicaAutoscaler,
                                         Scenario, build_population,
                                         get_scenario, make_arrival_model,
                                         run_scenario, trace_events)
from repro.serving.multi import (FloorSaturationUtility, ResourceArbiter,
                                 TenantSpec, UtilityPolicy)
from repro.serving.qos import (BandedWalkPolicy, QoSController,
                               QoSControllerConfig, WalkPolicy)
from repro.serving.simulator import SimulatedEngine

GIB = 2**30
FIXTURES = Path(__file__).parent / "fixtures"
GOLDEN = FIXTURES / "sim_control_plane_golden.json"

MIXTRAL = get_config("mixtral-8x7b")


@pytest.fixture(scope="module")
def frontier():
    return ParetoFrontier(MIXTRAL)


@pytest.fixture(scope="module")
def golden_plane(frontier):
    return run_scenario(get_scenario("golden-32"), frontier=frontier)


# ---------------------------------------------------------------------------
# trace layer
# ---------------------------------------------------------------------------
class TestTraces:
    def test_population_replays_identically(self):
        scn = get_scenario("golden-32")
        p1 = build_population(scn, 3, np.random.default_rng(scn.seed))
        p2 = build_population(scn, 3, np.random.default_rng(scn.seed))
        for f in ("join_t", "leave_t", "base_rate", "cls", "phase"):
            np.testing.assert_array_equal(getattr(p1, f), getattr(p2, f))
        assert trace_events(p1, scn) == trace_events(p2, scn)

    def test_trace_events_sorted_and_complete(self):
        scn = get_scenario("golden-32")
        pop = build_population(scn, 3, np.random.default_rng(scn.seed))
        evs = trace_events(pop, scn)
        assert all(evs[i].t <= evs[i + 1].t for i in range(len(evs) - 1))
        kinds = [e.kind for e in evs]
        assert kinds.count("budget") == len(scn.budget_shocks)
        n_churn = int(round(scn.churn_fraction * scn.tenants))
        assert kinds.count("join") == n_churn // 2
        assert kinds.count("leave") == n_churn - n_churn // 2

    def test_class_mix_exact(self):
        scn = get_scenario("diurnal-1k")
        pop = build_population(scn, 3, np.random.default_rng(0))
        for c, (_, frac) in enumerate(scn.class_mix):
            assert int((pop.cls == c).sum()) == int(round(frac * scn.tenants))

    def test_arrivals_churn_independent_stream(self):
        """Arrivals draw over the FULL population each tick, so the rng
        stream position — and thus every other tenant's sample — is
        identical whether or not some tenant is active."""
        scn = get_scenario("steady-64")
        pop = build_population(scn, 3, np.random.default_rng(7))
        model = make_arrival_model(scn, pop)
        act_all = np.ones(pop.n, dtype=bool)
        act_some = act_all.copy()
        act_some[::3] = False
        r1, r2 = np.random.default_rng(1), np.random.default_rng(1)
        model.reset(pop.n, r1)
        c1 = model.counts(0.0, scn.tick_s, pop.base_rate, act_all, r1)
        model.reset(pop.n, r2)
        c2 = model.counts(0.0, scn.tick_s, pop.base_rate, act_some, r2)
        np.testing.assert_array_equal(c1[act_some], c2[act_some])
        assert (c2[~act_some] == 0).all()

    def test_mmpp_requires_reset(self):
        m = MMPPArrivals(6.0, 0.04, 0.25)
        with pytest.raises(RuntimeError, match="reset"):
            m.mean_rate(0.0, np.ones(4))

    def test_diurnal_mean_rate_swings(self):
        scn = get_scenario("diurnal-1k")
        pop = build_population(scn, 3, np.random.default_rng(0))
        model = make_arrival_model(scn, pop)
        rates = [model.mean_rate(t, pop.base_rate).sum()
                 for t in np.linspace(0, scn.diurnal_period_s, 40)]
        assert max(rates) > 1.3 * min(rates)

    def test_smoke_variant_truncates(self):
        scn = get_scenario("diurnal-1k")
        s = scn.smoke()
        assert s.horizon_s == scn.smoke_horizon_s
        assert all(t < s.horizon_s for t, _ in s.budget_shocks)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
class TestDeterminism:
    def test_byte_identical_reports(self, frontier):
        scn = get_scenario("golden-32").smoke()
        b1 = run_scenario(scn, frontier=frontier).report_bytes()
        b2 = run_scenario(scn, frontier=frontier).report_bytes()
        assert b1 == b2

    def test_seed_changes_report(self, frontier):
        scn = get_scenario("golden-32").smoke()
        b1 = run_scenario(scn, frontier=frontier).report_bytes()
        b2 = run_scenario(dataclasses.replace(scn, seed=1),
                          frontier=frontier).report_bytes()
        assert b1 != b2

    def test_run_is_single_shot(self, frontier):
        plane = ControlPlane(get_scenario("golden-32").smoke(),
                             frontier=frontier)
        plane.run()
        with pytest.raises(RuntimeError, match="single-shot"):
            plane.run()

    def test_golden_fixture(self, golden_plane):
        body = golden_plane.report_bytes()
        if os.environ.get("REGEN_GOLDEN"):
            GOLDEN.write_bytes(body)
        assert GOLDEN.exists(), "run with REGEN_GOLDEN=1 to create"
        assert body == GOLDEN.read_bytes(), (
            "golden-32 report drifted; regenerate the fixture with "
            "REGEN_GOLDEN=1 if the change is intentional")


# ---------------------------------------------------------------------------
# the reference scenario exercises the whole control surface
# ---------------------------------------------------------------------------
class TestGoldenScenario:
    def test_accounting_closes(self, golden_plane):
        led = golden_plane.ledger
        backlog = float(golden_plane.queue.sum())
        assert float(led.arrived.sum()) == pytest.approx(
            float(led.served.sum()) + float(led.dropped.sum()) + backlog)

    def test_preemption_and_autoscaling_happened(self, golden_plane):
        t = golden_plane.report()["totals"]
        assert t["preemptions"] >= 1
        assert t["scale_ups"] + t["scale_downs"] >= 1
        assert t["replans"] >= 1

    def test_violation_under_ceiling(self, golden_plane):
        t = golden_plane.report()["totals"]
        assert t["violation_rate"] <= golden_plane.scn.violation_ceiling

    def test_budget_respected_at_end(self, golden_plane):
        t = golden_plane.report()["totals"]
        assert t["used_bytes_final"] <= golden_plane.budget_bytes

    def test_replan_reports_flow_through_diff_path(self, golden_plane):
        assert golden_plane.reports, "no ReplanReports recorded"
        for rep in golden_plane.reports:
            assert rep.tenant.startswith("replica-")
            assert rep.migrated_bytes >= 0
            assert rep.downtime_s >= 0.0

    def test_event_log_capped(self, golden_plane):
        t = golden_plane.report()["totals"]
        assert t["events_recorded"] <= golden_plane.scn.max_recorded_events
        assert t["events_recorded"] + t["events_dropped"] >= \
            t["arbitrations"]


# ---------------------------------------------------------------------------
# no starvation: aging forces admission, weighted-fair service
# guarantees progress once admitted
# ---------------------------------------------------------------------------
class TestNoStarvation:
    def test_max_unserved_span_bounded_by_aging(self, golden_plane):
        scn = golden_plane.scn
        led = golden_plane.ledger
        aging = np.array([c.aging_s for c in DEFAULT_SLO_CLASSES])
        bound = aging[golden_plane.cls] + 2 * scn.tick_s
        assert (led.max_unserved_span_s <= bound + 1e-6).all(), (
            "some tenant starved past its aging window: spans="
            f"{led.max_unserved_span_s.max()}")

    def test_preempted_tenants_made_progress(self, golden_plane):
        led = golden_plane.ledger
        pre = led.preemptions > 0
        assert pre.any()
        assert (led.served[pre] > 0).all()

    def test_aging_forces_admission_and_bounds_spans(self, frontier):
        """A fleet pinned far below demand: normal admission fails for
        most tenants, so ONLY the aging path can give them service — and
        it must, within aging_s + two ticks, despite the preemption
        churn it causes."""
        from repro.serving.control_plane import SLOClass
        classes = tuple(
            SLOClass(n, p, f, cap, aging_s=120.0, weight=w)
            for (n, p, f, cap, w) in [("gold", 2, 4.0, 2400.0, 4.0),
                                      ("silver", 1, 1.0, 1200.0, 2.0),
                                      ("bronze", 0, 0.25, 600.0, 1.0)])
        scn = Scenario(
            name="starve", tenants=24, horizon_s=2000.0, tick_s=20.0,
            rate_range_tps=(0.8, 1.2), slots_per_replica=2,
            budget_bytes=7.0 * GIB, min_replicas=2, max_replicas=2,
            util_band=(0.01, 0.999),
        )
        plane = ControlPlane(scn, classes=classes, frontier=frontier)
        plane.run()
        t = plane.report()["totals"]
        assert t["forced_admissions"] >= 1
        assert (plane.ledger.max_unserved_span_s
                <= 120.0 + 2 * scn.tick_s + 1e-6).all()
        # every tenant got SOME service despite 6x overcommit
        assert (plane.ledger.served[plane.active
                                    | (plane.pop.join_t <= 0)] > 0).all()


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------
class TestAutoscaler:
    def test_steady_trace_never_oscillates(self, frontier):
        plane = run_scenario(get_scenario("steady-64"), frontier=frontier)
        t = plane.report()["totals"]
        assert t["scale_ups"] == 0 and t["scale_downs"] == 0
        assert t["preemptions"] == 0

    def test_patience_required(self):
        a = ReplicaAutoscaler(band=(0.4, 0.85), patience_ticks=3,
                              cooldown_s=0.0)
        assert a.step(0.0, 0.95, 2) == 0
        assert a.step(1.0, 0.95, 2) == 0
        assert a.step(2.0, 0.95, 2) == 1      # third consecutive breach
        # streak resets after the action
        assert a.step(3.0, 0.95, 3) == 0

    def test_dip_resets_streak(self):
        a = ReplicaAutoscaler(patience_ticks=3, cooldown_s=0.0)
        a.step(0.0, 0.9, 2)
        a.step(1.0, 0.9, 2)
        a.step(2.0, 0.5, 2)                   # back in band
        assert a.step(3.0, 0.9, 2) == 0       # streak restarted

    def test_cooldown_blocks_consecutive_actions(self):
        a = ReplicaAutoscaler(patience_ticks=1, cooldown_s=100.0)
        assert a.step(0.0, 0.95, 2) == 1
        assert a.step(50.0, 0.95, 3) == 0     # cooling down
        assert a.step(150.0, 0.95, 3) == 1

    def test_scale_down_projection_guard(self):
        a = ReplicaAutoscaler(band=(0.4, 0.85), patience_ticks=1,
                              cooldown_s=0.0)
        # util 0.35 < lo, but 0.35 * 3/2 = 0.525 fits under hi: allowed
        assert a.step(0.0, 0.35, 3) == -1
        # util 0.39 < lo but projected 0.39 * 2/1 = 0.78 is within
        # margin of hi (0.85 * 0.95 = 0.8075): allowed
        assert a.step(1.0, 0.39, 2) == -1
        # projected 0.42 * 2/1 = 0.84 > 0.8075: vetoed
        assert a.step(2.0, 0.42, 2) == 0

    def test_bounds_and_feasibility_respected(self):
        a = ReplicaAutoscaler(patience_ticks=1, cooldown_s=0.0,
                              min_replicas=2, max_replicas=4)
        assert a.step(0.0, 0.95, 4) == 0            # at max
        assert a.step(1.0, 0.95, 3, can_add=False) == 0
        assert a.step(2.0, 0.05, 2) == 0            # at min
        assert a.step(3.0, 0.05, 3, can_remove=False) == 0

    def test_bad_band_rejected(self):
        with pytest.raises(ValueError, match="band"):
            ReplicaAutoscaler(band=(0.9, 0.5))


# ---------------------------------------------------------------------------
# exactly one re-arbitration per budget shock, at 1000-tenant scale
# ---------------------------------------------------------------------------
class TestArbitrationTriggers:
    def test_one_arbitration_per_shock_1k(self, frontier):
        scn = Scenario(
            name="shock-1k", tenants=1000, horizon_s=2500.0, tick_s=25.0,
            arrival="poisson", rate_range_tps=(0.05, 0.15),
            budget_bytes=400.0 * GIB, slots_per_replica=24,
            min_replicas=2, max_replicas=2,
            budget_shocks=((1000.0, 0.9), (2000.0, 1.0)),
            util_band=(0.005, 0.999),
        )
        plane = run_scenario(scn, frontier=frontier)
        t = plane.report()["totals"]
        assert t["preemptions"] == 0
        assert t["scale_ups"] == 0 and t["scale_downs"] == 0
        # initial + one per shock, nothing else
        assert t["arbitrations"] == 1 + len(scn.budget_shocks)

    def test_infeasible_budget_raises(self, frontier):
        from repro.serving.multi import GlobalBudgetInfeasible
        scn = Scenario(name="tiny", tenants=4, horizon_s=100.0,
                       tick_s=10.0, budget_bytes=1.0 * GIB,
                       min_replicas=2)
        with pytest.raises(GlobalBudgetInfeasible):
            run_scenario(scn, frontier=frontier)

    def test_deep_shock_retires_replicas(self, frontier):
        """A shock below the fleet's cheapest joint footprint forcibly
        retires replicas down to feasibility, still with ONE
        re-arbitration charged to the shock itself."""
        cheapest = min(p.qos.device_bytes for p in frontier.points)
        scn = Scenario(
            name="crunch", tenants=32, horizon_s=600.0, tick_s=20.0,
            rate_range_tps=(0.05, 0.15), slots_per_replica=4,
            budget_bytes=8.0 * cheapest, min_replicas=2, max_replicas=4,
            budget_shocks=((300.0, 0.3),),   # fits 2 of 4 replicas
            util_band=(0.005, 0.999),
        )
        plane = ControlPlane(scn, frontier=frontier)
        for _ in range(2):
            plane._add_replica(0.0)          # start with 4 replicas
        plane.run()
        t = plane.report()["totals"]
        assert t["replicas_final"] == 2
        assert t["scale_downs"] >= 2
        assert t["arbitrations"] == 1 + 1    # initial + the shock


# ---------------------------------------------------------------------------
# pluggable policy seams (DESIGN.md §14.4)
# ---------------------------------------------------------------------------
class TestPolicyPlugins:
    def test_custom_walk_policy_drives_controller(self, frontier):
        class Pin(WalkPolicy):
            """Always returns the fastest point, whatever is measured."""
            def decide(self, ctl, measured):
                return max(ctl.frontier.points,
                           key=lambda p: p.qos.tokens_per_s)

        from repro.serving.simulator import run_scripted
        eng = SimulatedEngine(model_error=0.5)
        ctl = QoSController(eng, frontier, policy=Pin())
        ctl.set_target(QoSTarget(min_tokens_per_s=1.0))
        run_scripted(eng, ctl, 40)
        fastest = max(frontier.points, key=lambda p: p.qos.tokens_per_s)
        assert ctl.point is fastest

    def test_default_policy_is_banded_walk(self, frontier):
        eng = SimulatedEngine()
        ctl = QoSController(eng, frontier)
        assert isinstance(ctl.policy, BandedWalkPolicy)

    def test_custom_utility_changes_arbitration(self, frontier):
        class CheapestWins(UtilityPolicy):
            """Negative-footprint utility: the water-fill gains nothing
            from upgrades, so everyone stays at their cheapest point."""
            def build(self, feas, target, derate):
                return lambda p: -float(p.qos.device_bytes)

        specs = [(TenantSpec(f"t{i}", QoSTarget(min_tokens_per_s=20.0)),
                  frontier, 1.0) for i in range(3)]
        sel_default, used_default = ResourceArbiter().arbitrate(
            specs, 200.0 * GIB)
        sel_cheap, used_cheap = ResourceArbiter(
            utility=CheapestWins()).arbitrate(specs, 200.0 * GIB)
        cheapest = min(p.qos.device_bytes for p in frontier.points)
        assert used_cheap == pytest.approx(3 * cheapest)
        assert used_default > used_cheap    # default water-fills upward

    def test_floor_saturation_handles_zero_floor(self, frontier):
        u = FloorSaturationUtility().build(
            frontier.points, QoSTarget(min_tokens_per_s=0.0), 1.0)
        assert all(np.isfinite(u(p)) for p in frontier.points)

    def test_scenario_floor_weight_reaches_arbiter(self, frontier):
        scn = dataclasses.replace(get_scenario("steady-64"),
                                  floor_weight=123.0)
        plane = ControlPlane(scn, frontier=frontier)
        assert plane.arbiter.floor_weight == 123.0
