"""Offline sensitivity calibration (DESIGN.md §15): determinism,
monotonicity over the ladder, planted-outlier ranking, serialization,
and the uniform-profile compat guarantee against the cost model."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import cost_model
from repro.core.planner import AdaptivePlanner
from repro.core.precision_plan import balanced_ladder_plan
from repro.core.sensitivity import SensitivityProfile, calibrate_sensitivity
from repro.models.model import build_model

LADDER3 = (16, 8, 4)


@pytest.fixture(scope="module")
def smoke():
    cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
    params = build_model(cfg).init(jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def profile(smoke):
    cfg, params = smoke
    return calibrate_sensitivity(cfg, params, seed=0)


class TestCalibrationDeterminism:
    def test_same_seed_byte_identical(self, smoke, profile):
        cfg, params = smoke
        again = calibrate_sensitivity(cfg, params, seed=0)
        assert again.to_json_bytes() == profile.to_json_bytes()

    def test_different_seed_differs(self, smoke, profile):
        cfg, params = smoke
        other = calibrate_sensitivity(cfg, params, seed=1)
        assert other.to_json_bytes() != profile.to_json_bytes()

    def test_save_load_roundtrip_bytes(self, profile, tmp_path):
        path = tmp_path / "profile.json"
        profile.save(path)
        back = SensitivityProfile.load(path)
        assert back.to_json_bytes() == profile.to_json_bytes()
        assert back.ladder == profile.ladder
        np.testing.assert_array_equal(back.freq, profile.freq)


class TestMonotonicity:
    def test_sens_decreases_with_bits_every_expert(self, smoke):
        """4-bit error >= 8-bit error >= 16-bit == 0, per expert. The
        raw (unanchored) scores carry the property directly; 16-bit is
        0 by construction (not stored)."""
        cfg, params = smoke
        raw = calibrate_sensitivity(cfg, params, seed=0, ladder=LADDER3,
                                    anchor=False)
        assert sorted(raw.sens) == [4, 8]
        assert (raw.sens[4] >= raw.sens[8]).all()
        assert (raw.sens[8] > 0).all()

    def test_anchored_profile_preserves_rung_order(self, smoke):
        cfg, params = smoke
        anch = calibrate_sensitivity(cfg, params, seed=0, ladder=LADDER3)
        assert (anch.sens[4] >= anch.sens[8]).all()
        for b in (4, 8):
            assert anch.sens[b].mean() == pytest.approx(
                cost_model.RUNG_QUALITY_COST[b])

    def test_freq_normalized(self, profile):
        assert profile.freq.sum() == pytest.approx(1.0)
        assert (profile.freq > 0).all()


class TestPlantedOutlier:
    def test_spiked_expert_ranks_most_sensitive(self, smoke):
        """Plant a worst-case absmax pattern into ONE expert: per
        quantization group (dim -2, size ``group_size``) one dominant
        entry at 2*qmax times the uniform bulk magnitude. The outlier
        sets the group scale, the bulk falls below half the 4-bit step
        and quantizes to ZERO — a large fraction of the expert's output
        energy is erased, so calibration must rank it the most
        quantization-sensitive expert in its layer. (A uniform scale-up
        would NOT work: group-wise absmax quantization error is
        scale-invariant, and energy-normalisation below keeps the
        planted expert's output magnitude comparable.)"""
        cfg, params = smoke
        li, ei = 1, 3
        gs = cfg.mop.group_size
        spiked = jax.tree_util.tree_map(lambda x: x, params)
        spiked["layers"] = dict(params["layers"])
        moe = dict(params["layers"]["moe"])
        for k in ("w_gate", "w_up", "w_down"):
            w = np.asarray(moe[k]).copy()
            x = w[li, ei]
            m = float(np.sqrt((x ** 2).mean()))
            y = np.sign(x) * m            # uniform-magnitude bulk
            y[0::gs, :] *= 14.0           # ~2*qmax outlier per group
            y *= np.linalg.norm(x) / np.linalg.norm(y)
            w[li, ei] = y
            moe[k] = w
        spiked["layers"]["moe"] = moe
        prof = calibrate_sensitivity(cfg, spiked, seed=0, anchor=False)
        layer_sens = prof.sens[4][li]
        assert int(np.argmax(layer_sens)) == ei
        # and decisively: strictly above every sibling
        others = np.delete(layer_sens, ei)
        assert layer_sens[ei] > others.max()


class TestUniformProfileCompat:
    def test_uniform_quality_cost_matches_flat_formula(self, smoke):
        cfg, _ = smoke
        prof = SensitivityProfile.uniform(cfg)
        plan = balanced_ladder_plan(
            cfg.num_layers, cfg.moe.num_experts, {4: 8},
            ladder=cfg.mop.precision_ladder,
            group_size=cfg.mop.group_size, seed=0)
        flat = cost_model.quality_proxy(cfg, plan)
        assert cost_model.quality_proxy(cfg, plan, prof) == flat
        assert 1.0 + prof.quality_cost(plan) == pytest.approx(flat)

    def test_calibrated_profile_reprices_quality(self, smoke, profile):
        """A non-uniform profile changes quality_proxy for at least one
        enumerated plan (otherwise the calibration is vacuous)."""
        cfg, _ = smoke
        assert not profile.is_uniform()
        planner = AdaptivePlanner(cfg)
        frontier = planner.frontier()
        changed = any(
            cost_model.quality_proxy(cfg, p.plan, profile)
            != p.qos.quality_proxy
            for p in frontier.all_points if p.num_q_experts > 0)
        assert changed

    def test_planner_set_profile_invalidates_frontier(self, smoke,
                                                      profile):
        cfg, _ = smoke
        planner = AdaptivePlanner(cfg)
        f0 = planner.frontier()
        planner.set_profile(profile)
        f1 = planner.frontier()
        assert f1 is not f0
        assert f1.profile is profile
        # profile_variant round-trips back to the flat ranking
        f2 = f1.profile_variant(None)
        assert [p.qos.quality_proxy for p in f2.points] == \
            [p.qos.quality_proxy for p in f0.points]

    def test_with_freq_reweights_not_reprices(self, profile):
        skew = np.zeros(profile.shape)
        skew[0, 0] = 1.0
        rew = profile.with_freq(skew)
        np.testing.assert_array_equal(rew.sens[4], profile.sens[4])
        assert rew.freq[0, 0] == 1.0 and rew.freq.sum() == 1.0
        # all-zero histogram: keep current weights
        same = profile.with_freq(np.zeros(profile.shape))
        np.testing.assert_array_equal(same.freq, profile.freq)
