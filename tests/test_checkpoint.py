"""Checkpoint manager: codec roundtrip, atomic commit, keep-N, async,
corruption rejection, restore-with-validation."""
import json
import os
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft.checkpoint import (CheckpointManager, decode_tree, encode_tree)


def tree():
    return {"layers": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                       "b": np.zeros(3, np.int32)},
            "none_leaf": None,
            "step": np.asarray(7)}


class TestCodec:
    def test_roundtrip(self):
        t = tree()
        out = decode_tree(encode_tree(t))
        np.testing.assert_array_equal(out["layers"]["w"], t["layers"]["w"])
        assert out["none_leaf"] is None
        assert out["step"] == 7

    def test_bf16_roundtrip(self):
        t = {"w": np.asarray(jnp.ones((4, 4), jnp.bfloat16) * 1.5)}
        out = decode_tree(encode_tree(t))
        assert out["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(out["w"], np.float32), 1.5)

    def test_compression_effective(self):
        t = {"w": np.zeros((1000, 100), np.float32)}
        assert len(encode_tree(t)) < t["w"].nbytes / 20


class TestManager:
    def test_save_restore(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        m.save(10, tree(), extra={"lr": 0.1})
        got, manifest = m.restore()
        assert manifest["step"] == 10
        assert manifest["extra"]["lr"] == 0.1
        np.testing.assert_array_equal(got["layers"]["w"],
                                      tree()["layers"]["w"])

    def test_latest_and_keep(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            m.save(s, tree())
        assert m.all_steps() == [3, 4]
        assert m.latest_step() == 4

    def test_async_save(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=True)
        m.save(5, tree())
        m.wait()
        assert m.latest_step() == 5

    def test_uncommitted_ignored(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        m.save(1, tree())
        m.save(2, tree())
        (tmp_path / "step_2.COMMITTED").unlink()    # simulate crash
        assert m.latest_step() == 1
        got, manifest = m.restore()
        assert manifest["step"] == 1

    def test_restore_validates_target(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        m.save(1, {"w": np.ones((2, 2), np.float32)})
        bad = {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)}
        with pytest.raises(ValueError):
            m.restore(target=bad)

    def test_restore_with_sharding_single_device(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        m.save(1, {"w": np.ones((4, 4), np.float32)})
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        sh = {"w": jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, None))}
        got, _ = m.restore(shardings=sh)
        assert got["w"].sharding == sh["w"]

    def test_resume_training_state(self, tmp_path):
        """End-to-end: params + opt state + data cursor survive."""
        from repro.data.pipeline import (DataPipeline, SyntheticCorpus,
                                         SyntheticCorpusConfig)
        pipe = DataPipeline(
            SyntheticCorpus(SyntheticCorpusConfig(vocab_size=64)),
            batch=2, seq=16)
        pipe.next_batch()
        m = CheckpointManager(str(tmp_path), async_save=False)
        m.save(3, {"params": {"w": np.ones(4, np.float32)}},
               extra={"data_state": pipe.state()})
        got, manifest = m.restore()
        pipe2 = DataPipeline(
            SyntheticCorpus(SyntheticCorpusConfig(vocab_size=64)),
            batch=2, seq=16)
        pipe2.restore(manifest["extra"]["data_state"])
        np.testing.assert_array_equal(pipe.next_batch()["tokens"],
                                      pipe2.next_batch()["tokens"])
