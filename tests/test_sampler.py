"""Sampler contracts (serving/sampler.py): greedy determinism, the
temperature distribution, top-k masking, and the speculative-verify
rejection chain's exactness (DESIGN.md §17)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampler import (greedy, sample, sample_probs,
                                   speculative_verify)


def _logits(rng, shape, scale=3.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


class TestGreedy:
    def test_deterministic_and_matches_sample(self):
        rng = np.random.default_rng(0)
        lg = _logits(rng, (5, 64))
        key = jax.random.key(0)
        a = sample(lg, key=key, temperature=0.0)
        b = greedy(lg)
        c = greedy(lg)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(c))

    def test_vocab_pad_masked(self):
        lg = jnp.zeros((2, 8), jnp.float32).at[:, 6].set(100.0)
        # pad columns (>= vocab_size) can never win, however large
        assert np.asarray(greedy(lg, vocab_size=6)).max() < 6
        assert int(np.asarray(greedy(lg, vocab_size=8))[0]) == 6

    def test_shape_polymorphic(self):
        """The verify forward scores (B, S, V) in one call — same result
        as row-wise argmax."""
        rng = np.random.default_rng(1)
        lg = _logits(rng, (2, 4, 32))
        full = np.asarray(greedy(lg, vocab_size=30))
        rows = np.stack([np.asarray(greedy(lg[:, j], vocab_size=30))
                         for j in range(4)], axis=1)
        np.testing.assert_array_equal(full, rows)


class TestTemperature:
    def test_distribution_tracks_probs(self):
        """Empirical frequencies of sample() converge to sample_probs()
        — the q the rejection chain assumes the draft drew from."""
        rng = np.random.default_rng(2)
        lg = _logits(rng, (1, 16), scale=1.5)
        p = np.asarray(sample_probs(lg, temperature=0.7))[0]
        n = 4000
        keys = jax.random.split(jax.random.key(0), n)
        draws = np.asarray(jax.vmap(
            lambda k: sample(lg, key=k, temperature=0.7)[0])(keys))
        freq = np.bincount(draws, minlength=16) / n
        assert np.abs(freq - p).max() < 0.03

    def test_low_temperature_sharpens(self):
        rng = np.random.default_rng(3)
        lg = _logits(rng, (1, 16), scale=1.0)
        p_hot = np.asarray(sample_probs(lg, temperature=2.0))[0]
        p_cold = np.asarray(sample_probs(lg, temperature=0.25))[0]
        assert p_cold.max() > p_hot.max()
        assert int(p_cold.argmax()) == int(np.asarray(greedy(lg))[0])

    def test_sample_probs_rejects_greedy(self):
        with pytest.raises(ValueError, match="temperature"):
            sample_probs(jnp.zeros((1, 8)), temperature=0.0)


class TestTopK:
    def test_masking_zeroes_tail(self):
        rng = np.random.default_rng(4)
        lg = _logits(rng, (3, 32))
        p = np.asarray(sample_probs(lg, temperature=1.0, top_k=5))
        assert ((p > 0).sum(axis=-1) <= 5).all()
        top5 = np.argsort(np.asarray(lg), axis=-1)[:, -5:]
        for b in range(3):
            assert set(np.nonzero(p[b])[0]) <= set(top5[b])
        np.testing.assert_allclose(p.sum(axis=-1), 1.0, atol=1e-5)

    def test_sampler_never_draws_outside_top_k(self):
        rng = np.random.default_rng(5)
        lg = _logits(rng, (1, 32))
        top3 = set(np.argsort(np.asarray(lg)[0])[-3:].tolist())
        for i in range(64):
            tok = int(np.asarray(sample(lg, key=jax.random.key(i),
                                        temperature=1.5, top_k=3))[0])
            assert tok in top3

    def test_top_k_with_vocab_pad(self):
        lg = jnp.zeros((1, 8), jnp.float32).at[0, 7].set(50.0)
        p = np.asarray(sample_probs(lg, temperature=1.0, top_k=2,
                                    vocab_size=7))[0]
        assert p[7] == 0.0


class TestSpeculativeVerify:
    """The rejection chain must emit tokens distributed EXACTLY as k+1
    sequential samples from p — at any acceptance rate (Leviathan et
    al., Thm. 1). Checked empirically on a small vocab where the exact
    marginal of the FIRST emitted token is computable."""

    def _first_token_marginal(self, q, p0, n, seed):
        """Empirical distribution of the first emitted token when the
        draft proposes from q and verify row 0 is p0 (k=1)."""
        rng = np.random.default_rng(seed)
        v = len(q)
        counts = np.zeros(v)
        cdf_q = np.cumsum(q)
        for _ in range(n):
            d = int(np.searchsorted(cdf_q, rng.random(), side="right"))
            d = min(d, v - 1)
            acc, tok = speculative_verify(
                np.array([d]), q[None, :],
                np.stack([p0, p0]),         # row 1 unused unless accepted
                rng.random(1), rng.random(2))
            first = d if acc >= 1 else tok
            counts[first] += 1
        return counts / n

    def test_exact_marginal_mismatched_q(self):
        q = np.array([0.6, 0.2, 0.1, 0.1])
        p = np.array([0.1, 0.5, 0.2, 0.2])
        freq = self._first_token_marginal(q, p, 20000, seed=0)
        assert np.abs(freq - p).max() < 0.015

    def test_exact_marginal_matching_q(self):
        p = np.array([0.4, 0.3, 0.2, 0.1])
        freq = self._first_token_marginal(p, p, 20000, seed=1)
        assert np.abs(freq - p).max() < 0.015

    def test_identical_distributions_always_accept(self):
        """q == p: acceptance probability is exactly 1 for every draft."""
        p = np.array([0.25, 0.25, 0.25, 0.25])
        rng = np.random.default_rng(2)
        for _ in range(200):
            d = rng.integers(0, 4, size=3)
            acc, tok = speculative_verify(
                d, np.tile(p, (3, 1)), np.tile(p, (4, 1)),
                rng.random(3), rng.random(4))
            assert acc == 3 and 0 <= tok < 4

    def test_zero_q_mass_always_rejects(self):
        """A draft token q assigned zero mass to must reject (the guard
        against division blowups), resampling from the residual."""
        q = np.array([1.0, 0.0, 0.0, 0.0])
        p = np.array([0.0, 0.0, 1.0, 0.0])
        acc, tok = speculative_verify(
            np.array([1]), q[None, :], np.stack([p, p]),
            np.array([0.0]), np.array([0.5, 0.5]))
        assert acc == 0 and tok == 2

    def test_full_acceptance_bonus_from_last_row(self):
        q = np.array([0.5, 0.5])
        p_rows = np.array([[0.5, 0.5], [0.5, 0.5], [0.0, 1.0]])
        acc, tok = speculative_verify(
            np.array([0, 1]), np.tile(q, (2, 1)), p_rows,
            np.array([0.0, 0.0]), np.array([0.9, 0.9, 0.3]))
        assert acc == 2 and tok == 1       # bonus drawn from p[k]
