"""ParetoFrontier subsystem (core/pareto.py, DESIGN.md §9): dominance
invariants, declarative select() semantics, frontier/planner plan
identity, and monotonicity in the memory budget."""
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pareto import (FrontierPoint, InfeasibleTarget,
                               ParetoFrontier, QoSTarget)
from repro.core.planner import AdaptivePlanner

GIB = 2**30
MIXTRAL = get_config("mixtral-8x7b")


@pytest.fixture(scope="module")
def frontier():
    return ParetoFrontier(MIXTRAL)


def _dominates(a: FrontierPoint, b: FrontierPoint) -> bool:
    ge = (a.qos.tokens_per_s >= b.qos.tokens_per_s
          and a.qos.quality_proxy <= b.qos.quality_proxy
          and a.qos.device_bytes <= b.qos.device_bytes)
    gt = (a.qos.tokens_per_s > b.qos.tokens_per_s
          or a.qos.quality_proxy < b.qos.quality_proxy
          or a.qos.device_bytes < b.qos.device_bytes)
    return ge and gt


class TestDominance:
    def test_enumerates_full_config_space(self, frontier):
        e = MIXTRAL.moe.num_experts
        assert len(frontier.all_points) == (e + 1) ** 2
        nqs = {p.num_q_experts for p in frontier.all_points}
        assert len(nqs) == e + 1
        # balanced levels: every Num_E4 is a multiple of num_layers
        assert all(nq % MIXTRAL.num_layers == 0 for nq in nqs)

    def test_frontier_points_mutually_nondominated(self, frontier):
        pts = frontier.points
        for i, a in enumerate(pts):
            for b in pts[i + 1:]:
                assert not _dominates(a, b)
                assert not _dominates(b, a)

    def test_every_config_covered_by_frontier(self, frontier):
        """Each enumerated point is on the frontier or dominated/matched
        by a frontier point."""
        for p in frontier.all_points:
            assert any(
                q is p or _dominates(q, p)
                or (q.qos.tokens_per_s == p.qos.tokens_per_s
                    and q.qos.quality_proxy == p.qos.quality_proxy
                    and q.qos.device_bytes == p.qos.device_bytes)
                for q in frontier.points)

    def test_sorted_ascending_throughput(self, frontier):
        tps = [p.qos.tokens_per_s for p in frontier.points]
        assert tps == sorted(tps)


class TestSelect:
    def test_meets_soft_and_hard_constraints(self, frontier):
        t = QoSTarget(min_tokens_per_s=5.0, max_quality_loss=0.06,
                      mem_budget_bytes=40 * GIB)
        p = frontier.select(t)
        assert p.qos.tokens_per_s >= 5.0
        assert p.qos.quality_proxy <= 1.06 + 1e-12
        assert p.qos.device_bytes <= 40 * GIB

    def test_prefers_quality_then_lowest_bytes(self, frontier):
        t = QoSTarget(min_tokens_per_s=5.0, mem_budget_bytes=40 * GIB)
        p = frontier.select(t)
        meeting = [q for q in frontier.feasible(t)
                   if q.qos.tokens_per_s >= 5.0]
        best_quality = min(q.qos.quality_proxy for q in meeting)
        assert p.qos.quality_proxy == best_quality
        same_quality = [q for q in meeting
                        if q.qos.quality_proxy == best_quality]
        assert p.qos.device_bytes == min(q.qos.device_bytes
                                         for q in same_quality)

    def test_inf_tps_is_best_effort_fastest(self, frontier):
        t = QoSTarget(min_tokens_per_s=math.inf,
                      mem_budget_bytes=40 * GIB)
        p = frontier.select(t)
        assert p.qos.tokens_per_s == max(
            q.qos.tokens_per_s for q in frontier.feasible(t))

    def test_deterministic(self, frontier):
        t = QoSTarget(min_tokens_per_s=3.0, mem_budget_bytes=35 * GIB)
        assert frontier.select(t) is frontier.select(t)

    def test_infeasible_budget_raises(self, frontier):
        with pytest.raises(InfeasibleTarget):
            frontier.select(QoSTarget(mem_budget_bytes=1 * GIB))

    def test_quality_cap_filters(self, frontier):
        t = QoSTarget(max_quality_loss=0.0, mem_budget_bytes=60 * GIB,
                      min_tokens_per_s=1.0)
        p = frontier.select(t)
        assert p.num_q_experts == 0
        assert p.qos.quality_proxy == 1.0

    def test_monotone_best_throughput_in_budget(self, frontier):
        """More memory can never make the fastest feasible point slower —
        frontier monotonicity in the budget."""
        best = [frontier.select(
            QoSTarget(min_tokens_per_s=math.inf,
                      mem_budget_bytes=g * GIB)).qos.tokens_per_s
                for g in (8, 12, 16, 20, 26, 32, 40, 54, 70, 95)]
        assert best == sorted(best)

    def test_neighbors_walk(self, frontier):
        t = QoSTarget(mem_budget_bytes=40 * GIB)
        feas = frontier.feasible(t)
        mid = feas[len(feas) // 2]
        slower, faster = frontier.neighbors(mid, t)
        assert slower.qos.tokens_per_s <= mid.qos.tokens_per_s
        assert faster.qos.tokens_per_s >= mid.qos.tokens_per_s
        assert frontier.neighbors(feas[0], t)[0] is None
        assert frontier.neighbors(feas[-1], t)[1] is None


class TestPlannerIntegration:
    def test_frontier_plan_identical_to_planner_plan(self, frontier):
        """Applying a frontier point through the planner (budget = the
        point's device bytes, quality preference, its Num_E4) must
        reproduce the point's plan bit-for-bit — the property the
        engine's apply_frontier_point relies on."""
        pl = AdaptivePlanner(MIXTRAL)
        for p in frontier.points[:: max(1, len(frontier.points) // 6)]:
            r = pl.plan(float(p.qos.device_bytes), "quality",
                        p.num_q_experts)
            assert (r.plan.quant == p.plan.quant).all()
            assert (r.plan.location == p.plan.location).all()
            assert r.qos.device_bytes == p.qos.device_bytes

    def test_planner_frontier_cached(self):
        pl = AdaptivePlanner(MIXTRAL)
        assert pl.frontier() is pl.frontier()
        assert pl.frontier(batch_size=4) is not pl.frontier()

    def test_sweep_rebased_on_frontier(self):
        pl = AdaptivePlanner(MIXTRAL)
        res, pareto = pl.sweep(40 * GIB)
        assert len(res) == MIXTRAL.moe.num_experts + 1
        assert all(r.qos.device_bytes <= 40 * GIB for r in res)
        assert pareto  # nonempty frontier

    def test_dense_arch_rejected(self):
        with pytest.raises(ValueError):
            ParetoFrontier(get_config("qwen3-8b"))
