"""EP serving subsystem (DESIGN.md §16): peer placement tier in the
cost model/frontier/planner, EP layout validation, mesh builders, and
the DP replica group + autoscaler integration."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import cost_model as CM
from repro.core.cost_model import HardwareModel, estimate_qos
from repro.core.pareto import ParetoFrontier
from repro.core.planner import AdaptivePlanner
from repro.core.precision_plan import (DEVICE, HOST, PEER,
                                       balanced_ladder_plan)
from repro.serving.api import ServeResult
from repro.serving.control_plane.autoscale import ReplicaAutoscaler
from repro.serving.ep.mesh_engine import validate_ep_layout
from repro.serving.ep.replica import DPReplicaGroup


@pytest.fixture(scope="module")
def cfg():
    return reduce_for_smoke(get_config("mixtral-8x7b"))   # L=2, E=8


@pytest.fixture(scope="module")
def cfg_full():
    # full-size config: analytic cost model only, nothing is built
    return get_config("mixtral-8x7b")


def _plan(cfg, counts, resident=None, peer=0):
    return balanced_ladder_plan(
        cfg.num_layers, cfg.moe.num_experts, counts,
        group_size=cfg.mop.group_size,
        resident_experts=resident, peer_experts=peer)


class TestPeerCostModel:
    def test_peer_terms_zero_without_peer_experts(self, cfg):
        plan = _plan(cfg, {4: 8}, resident=8)
        frac, by, layers = CM.peer_access_stats(cfg, plan)
        assert (frac, by, layers) == (0.0, 0.0, 0)
        assert estimate_qos(cfg, plan).t_peer_ms == 0.0

    def test_ep1_t_token_exact_under_any_peer_hardware(self, cfg):
        """No PEER experts => the peer hw fields must not perturb ANY
        output bit (the frontier golden fixture depends on this)."""
        plan = _plan(cfg, {4: 8}, resident=8)
        a = estimate_qos(cfg, plan, HardwareModel())
        b = estimate_qos(cfg, plan, HardwareModel(
            interconnect_bw=1.0, all2all_latency_s=123.0))
        assert a == b
        assert a.tokens_per_s == b.tokens_per_s

    def test_peer_charged_at_interconnect_not_host_link(self, cfg):
        """Peer tier moves ACTIVATION bytes at interconnect bw (+ layer
        latency), never expert weights at host-link bw."""
        hw = HardwareModel()
        plan = _plan(cfg, {4: 8}, resident=8, peer=4)
        frac, peer_bytes, layers = CM.peer_access_stats(cfg, plan)
        assert frac > 0 and peer_bytes > 0 and layers > 0
        itemsize = 2 if cfg.dtype in ("bfloat16", "float16") else 4
        per_access = 2 * cfg.d_model * itemsize
        assert peer_bytes == pytest.approx(
            int((plan.location == PEER).sum()) * per_access
            * cfg.moe.top_k / cfg.moe.num_experts)
        est = estimate_qos(cfg, plan, hw)
        assert est.t_peer_ms == pytest.approx(
            (peer_bytes / hw.interconnect_bw
             + layers * hw.all2all_latency_s) * 1e3)
        # slower interconnect -> strictly more peer time
        slow = estimate_qos(cfg, plan, HardwareModel(interconnect_bw=1e9))
        assert slow.t_peer_ms > est.t_peer_ms

    def test_peer_faster_than_host_streaming(self, cfg_full):
        """Same bits, same local residency: parking the overflow on a
        PEER device beats streaming it over the host link. At real
        expert sizes the tier gap is orders of magnitude — weight bytes
        at host-link bw vs activation bytes at interconnect bw (the
        smoke config's toy experts would NOT show this: its fixed
        all2all latency outweighs streaming 24 KiB experts)."""
        half = cfg_full.num_layers * cfg_full.moe.num_experts // 2
        peer = _plan(cfg_full, {4: half}, resident=half, peer=half)
        host = _plan(cfg_full, {4: half}, resident=half, peer=0)
        qp = estimate_qos(cfg_full, peer)
        qh = estimate_qos(cfg_full, host)
        assert qp.tokens_per_s > qh.tokens_per_s
        assert qp.hit_rate == 1.0 and qh.hit_rate < 1.0
        assert qp.t_peer_ms < qh.t_exposed_ms

    def test_device_bytes_excludes_peer(self, cfg):
        peer = _plan(cfg, {4: 8}, resident=8, peer=8)
        local = _plan(cfg, {4: 8}, resident=16, peer=0)
        assert CM.device_bytes(cfg, peer) < CM.device_bytes(cfg, local)
        assert (peer.location == DEVICE).sum() == 8
        assert (peer.location == PEER).sum() == 8
        assert peer.placement_counts() == {"device": 8, "peer": 8,
                                           "host": 0}

    def test_peer_requires_resident(self, cfg):
        with pytest.raises(ValueError):
            _plan(cfg, {4: 8}, resident=None, peer=4)


class TestEPFrontierPlanner:
    def test_ep1_records_byte_identical_to_default(self, cfg):
        hw = HardwareModel()
        base = ParetoFrontier(cfg, hw).records()
        ep1 = ParetoFrontier(cfg, hw, ep=1).records()
        assert base == ep1
        assert all("ep" not in r and "peer_experts" not in r
                   for r in ep1)

    def test_ep_divisibility_rejected_everywhere(self, cfg):
        with pytest.raises(ValueError):
            ParetoFrontier(cfg, ep=3)
        with pytest.raises(ValueError):
            AdaptivePlanner(cfg, ep=5)
        with pytest.raises(ValueError):
            validate_ep_layout(cfg, 3)
        with pytest.raises(ValueError):
            validate_ep_layout(dataclasses.replace(cfg, moe=None), 2)
        validate_ep_layout(cfg, 4)   # 8 % 4 == 0: fine

    def test_ep_frontier_peer_points_and_rounded_counts(self, cfg):
        f = ParetoFrontier(cfg, ep=4)
        assert any(p.peer_experts > 0 for p in f.points)
        for p in f.points:
            assert p.num_q_experts % 4 == 0 \
                or p.num_q_experts == f.num_experts
            # resident splits into local (budget-checked) + peer
            assert 0 <= p.peer_experts <= p.resident_experts
            if p.resident_experts:
                local = p.resident_experts - p.peer_experts
                assert local == -(-p.resident_experts // 4)
        recs = f.records()
        assert all(r["ep"] == 4 for r in recs)

    def test_planner_rounds_counts_to_ep_multiples(self, cfg):
        pl = AdaptivePlanner(cfg, ep=4)
        full = pl.size_ne + pl.num_experts_total * pl.size_e16
        res = pl.plan(full, "quality", num_q_experts=6)
        for b in (4,):
            per_layer = (res.plan.bits == b).sum(axis=1)
            assert np.all(per_layer % 4 == 0)

    def test_device_assignment_contiguous_and_validated(self, cfg):
        plan = _plan(cfg, {4: 8})
        ranks = plan.device_assignment(4)
        assert ranks.shape == plan.bits.shape
        # balanced: every rank owns E/ep experts of every layer
        for r in range(4):
            assert np.all((ranks == r).sum(axis=1) == 2)
        # a bank that does not divide by ep must refuse
        odd = _plan(cfg, {4: 6})        # 3 q4 + 5 f16 per layer
        with pytest.raises(ValueError):
            odd.device_assignment(2)


@pytest.mark.skipif(jax.device_count() != 1,
                    reason="exercises the too-few-devices error path")
class TestMeshBuilders:
    def test_test_mesh_raises_actionable_xla_flags_error(self):
        from repro.launch.mesh import make_test_mesh
        with pytest.raises(RuntimeError, match="XLA_FLAGS"):
            make_test_mesh((2, 2))

    def test_ep_mesh_raises_actionable_xla_flags_error(self):
        from repro.launch.mesh import make_ep_mesh
        with pytest.raises(RuntimeError,
                           match="xla_force_host_platform_device_count"):
            make_ep_mesh(4)
        with pytest.raises(RuntimeError, match="XLA_FLAGS"):
            make_ep_mesh(1, replica=1)   # replica 1 needs devices [1, 2)

    def test_ep1_mesh_builds_on_one_device(self):
        from repro.launch.mesh import make_ep_mesh
        mesh = make_ep_mesh(1)
        assert dict(mesh.shape) == {"data": 1, "model": 1}


class _FakeScheduler:
    def __init__(self):
        self.queue = []
        self.num_active = 0


class _FakeEngine:
    """Engine-shaped stub: one queued request retires per iteration."""

    def __init__(self, slot):
        self.slot = slot
        self.scheduler = _FakeScheduler()
        self.max_slots = 2
        self.metrics = {"tokens_generated": 0, "iterations": 0}
        self.closed = False
        self.target = None
        self._next = 0

    def submit_request(self, request):
        rid = self._next
        self._next += 1
        self.scheduler.queue.append(rid)
        return rid

    def has_work(self):
        return bool(self.scheduler.queue)

    def run_iteration(self, **kw):
        self.metrics["iterations"] += 1
        if not self.scheduler.queue:
            return []
        rid = self.scheduler.queue.pop(0)
        self.metrics["tokens_generated"] += 4
        return [rid]

    def result(self, rid):
        return ServeResult(rid=rid, tokens=[1, 2, 3, 4], latency_s=0.1,
                           ttft_s=None, priority=0, deadline_s=None,
                           deadline_met=None)

    def apply_target(self, target):
        self.target = target
        return ("point", self.slot)

    def throughput_tokens_per_s(self, include_transfer=True):
        return 10.0

    def close(self):
        self.closed = True


class TestDPReplicaGroup:
    def _group(self, n=2, max_replicas=4):
        return DPReplicaGroup(_FakeEngine, replicas=n,
                              max_replicas=max_replicas)

    def test_least_loaded_routing_and_global_rids(self):
        g = self._group(2)
        rids = [g.submit_request(object()) for _ in range(4)]
        assert rids == [0, 1, 2, 3]
        # balanced: 2 requests per replica
        assert [len(e.scheduler.queue) for e in g.engines] == [2, 2]
        retired = []
        while g.has_work():
            retired += g.run_iteration()
        assert sorted(retired) == rids
        # results survive with the GLOBAL rid, no cross-replica collision
        assert [g.result(r).rid for r in rids] == rids
        with pytest.raises(KeyError):
            g.result(99)

    def test_scale_down_drains_never_drops(self):
        g = self._group(2)
        for _ in range(4):
            g.submit_request(object())
        g.scale_to(1)
        assert g.n_replicas == 1          # victim no longer serves...
        assert len(g.engines) == 2        # ...but finishes its work
        new_rid = g.submit_request(object())
        done = []
        while g.has_work():
            done += g.run_iteration()
        assert len(g.engines) == 1 and g.n_replicas == 1
        assert sorted(done) == [0, 1, 2, 3, new_rid]

    def test_scale_up_inherits_target_and_reuses_slots(self):
        g = self._group(2)
        g.apply_target("TARGET")
        g.scale_to(1)
        g.run_iteration()
        assert len(g.engines) == 1
        g.scale_to(3)
        assert sorted(e.slot for e in g.engines) == [0, 1, 2]
        assert all(e.target == "TARGET" for e in g.engines)
        with pytest.raises(ValueError):
            g.scale_to(5)                 # beyond max_replicas
        with pytest.raises(ValueError):
            g.scale_to(0)

    def test_metrics_and_throughput_aggregate(self):
        g = self._group(2)
        for _ in range(2):
            g.submit_request(object())
        while g.has_work():
            g.run_iteration()
        m = g.metrics
        assert m["tokens_generated"] == 8
        assert m["replicas"] == 2 and m["draining"] == 0
        assert g.throughput_tokens_per_s() == 20.0

    def test_autoscaler_decisions_drive_real_engines(self):
        g = self._group(1, max_replicas=2)
        auto = ReplicaAutoscaler(patience_ticks=2, cooldown_s=10.0,
                                 max_replicas=2)
        # saturate: queue >> capacity -> util 1.0 -> +1 after patience
        for _ in range(6):
            g.submit_request(object())
        assert g.demand_util() == 1.0
        decisions = [g.autoscale_step(float(t), auto) for t in range(3)]
        assert 1 in decisions and g.n_replicas == 2
        # drain the queue, then idle -> -1 after cooldown + patience
        while g.has_work():
            g.run_iteration()
        assert g.demand_util() == 0.0
        decisions = [g.autoscale_step(100.0 + t, auto) for t in range(4)]
        assert -1 in decisions and g.n_replicas == 1

    def test_close_closes_every_replica(self):
        g = self._group(2)
        engines = list(g.engines)
        g.close()
        assert all(e.closed for e in engines) and not g.engines
