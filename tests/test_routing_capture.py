"""capture_routing hook + cache_sim plumbing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core.mixed_moe import capture_routing, route


class TestCaptureRouting:
    def test_eager_capture(self):
        moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8)
        w = jax.random.normal(jax.random.key(0), (16, 4), jnp.float32)
        x = jax.random.normal(jax.random.key(1), (6, 16), jnp.float32)
        with capture_routing() as ids:
            route(w, x, moe, train=False)
            route(w, x, moe, train=False)
        assert len(ids) == 2
        assert ids[0].shape == (6, 2)
        assert ids[0].dtype == np.int32
        assert (ids[0] >= 0).all() and (ids[0] < 4).all()

    def test_no_capture_outside_context(self):
        moe = MoEConfig(num_experts=4, top_k=1, d_ff_expert=8)
        w = jnp.zeros((16, 4))
        x = jnp.ones((2, 16))
        route(w, x, moe, train=False)   # must not raise / leak state

    def test_jitted_route_not_captured(self):
        """Tracers are skipped — jit under the context stays silent."""
        moe = MoEConfig(num_experts=4, top_k=1, d_ff_expert=8)
        w = jnp.zeros((16, 4))
        x = jnp.ones((2, 16))
        f = jax.jit(lambda w, x: route(w, x, moe, train=False)[1])
        with capture_routing() as ids:
            f(w, x)
        assert ids == []
