"""capture_routing / capture_moe_inputs hooks + cache_sim plumbing."""
import jax
import jax.numpy as jnp
import numpy as np

from helpers import assert_valid_route_trace, route_histogram, routed_trace
from repro.configs.base import MoEConfig
from repro.core.mixed_moe import capture_moe_inputs, capture_routing, route


class TestCaptureRouting:
    def test_eager_capture(self):
        moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8)
        w = jax.random.normal(jax.random.key(0), (16, 4), jnp.float32)
        x = jax.random.normal(jax.random.key(1), (6, 16), jnp.float32)
        with capture_routing() as ids:
            route(w, x, moe, train=False)
            route(w, x, moe, train=False)
        assert len(ids) == 2
        for trace in ids:
            assert_valid_route_trace(trace, tokens=6, top_k=2,
                                     num_experts=4)

    def test_no_capture_outside_context(self):
        moe = MoEConfig(num_experts=4, top_k=1, d_ff_expert=8)
        w = jnp.zeros((16, 4))
        x = jnp.ones((2, 16))
        route(w, x, moe, train=False)   # must not raise / leak state

    def test_jitted_route_not_captured(self):
        """Tracers are skipped — jit under the context stays silent."""
        moe = MoEConfig(num_experts=4, top_k=1, d_ff_expert=8)
        w = jnp.zeros((16, 4))
        x = jnp.ones((2, 16))
        f = jax.jit(lambda w, x: route(w, x, moe, train=False)[1])
        with capture_routing() as ids:
            f(w, x)
        assert ids == []


class TestCaptureMoEInputs:
    """The calibration hook (DESIGN.md §15): per-layer (x, probs)."""

    def test_eager_capture_shapes(self):
        moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8)
        w = jax.random.normal(jax.random.key(0), (16, 4), jnp.float32)
        x = jax.random.normal(jax.random.key(1), (6, 16), jnp.float32)
        with capture_moe_inputs() as cap:
            route(w, x, moe, train=False)
        assert len(cap) == 1
        xs, probs = cap[0]
        assert xs.shape == (6, 16) and xs.dtype == np.float32
        assert probs.shape == (6, 4) and probs.dtype == np.float32
        np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)

    def test_jitted_not_captured_and_no_leak(self):
        moe = MoEConfig(num_experts=4, top_k=1, d_ff_expert=8)
        w = jnp.zeros((16, 4))
        x = jnp.ones((2, 16))
        f = jax.jit(lambda w, x: route(w, x, moe, train=False)[1])
        with capture_moe_inputs() as cap:
            f(w, x)
        assert cap == []
        route(w, x, moe, train=False)   # outside: must not capture
        assert cap == []


class TestRoutedTraceBuilder:
    """The shared synthetic-stream builder validates its own contract."""

    def test_trace_is_deterministic_and_valid(self):
        a = routed_trace(32, 8, 2, alpha=1.2, seed=7)
        b = routed_trace(32, 8, 2, alpha=1.2, seed=7)
        np.testing.assert_array_equal(a, b)
        assert_valid_route_trace(a, tokens=32, top_k=2, num_experts=8)

    def test_skew_concentrates_on_hot_experts(self):
        uniform = routed_trace(512, 8, 2, alpha=0.0, seed=0)
        skewed = routed_trace(512, 8, 2, alpha=2.0, seed=0)
        h_u = route_histogram([uniform], 8)[0]
        h_s = route_histogram([skewed], 8)[0]
        assert h_s[:2].sum() > h_u[:2].sum()
        assert h_s[0] == h_s.max()

    def test_histogram_counts_every_access(self):
        traces = [routed_trace(16, 4, 2, seed=li) for li in range(3)]
        h = route_histogram(traces, 4)
        assert h.shape == (3, 4)
        assert h.sum() == 3 * 16 * 2
