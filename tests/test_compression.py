"""int8 + error-feedback gradient compression: convergence & invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.training.compression import (compress_grads, init_error_feedback,
                                        quantize_grad, wire_bytes)
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)
from tests.test_training import make_problem, quad_loss


class TestQuantize:
    def test_roundtrip_error_bounded(self):
        g = jax.random.normal(jax.random.key(0), (256, 64)) * 3.0
        q, scale = quantize_grad(g)
        err = jnp.abs(q.astype(jnp.float32) * scale - g)
        assert float(err.max()) <= float(scale) / 2 + 1e-6
        assert q.dtype == jnp.int8

    @settings(deadline=None, max_examples=20)
    @given(st.floats(min_value=1e-6, max_value=1e6),
           st.integers(min_value=0, max_value=2**31 - 1))
    def test_scale_invariance_property(self, scale_f, seed):
        g = jax.random.normal(jax.random.key(seed), (64,)) * scale_f
        q, s = quantize_grad(g)
        rel = jnp.abs(q.astype(jnp.float32) * s - g) / (jnp.max(jnp.abs(g))
                                                        + 1e-12)
        assert float(rel.max()) < 1.0 / 127 + 1e-5

    def test_zero_grad(self):
        q, s = quantize_grad(jnp.zeros((8,)))
        assert float(jnp.abs(q).max()) == 0

    def test_error_feedback_catches_residual(self):
        g = {"w": jnp.asarray([1e-4, 2e-4, 127.0])}  # tiny values crushed
        ef = init_error_feedback(g)
        g_hat, new_ef = compress_grads(g, ef)
        # residual = what quantization lost, exactly
        np.testing.assert_allclose(
            np.asarray(g_hat["w"] + new_ef["w"]), np.asarray(g["w"]),
            rtol=1e-6)


class TestConvergence:
    @pytest.mark.parametrize("optname", ["adamw", "adafactor"])
    def test_compressed_training_converges(self, optname):
        params, batch = make_problem()
        cfg = OptConfig(lr=0.05, warmup_steps=5, total_steps=200,
                        weight_decay=0.0)
        tcfg = TrainConfig(opt=cfg, optimizer=optname,
                           grad_compression="int8")
        state = init_train_state(params, tcfg)
        assert "ef" in state
        step = jax.jit(make_train_step(quad_loss, tcfg))
        losses = []
        for _ in range(60):
            params, state, m = step(params, state, batch)
            losses.append(float(m["nll"]))
        assert losses[-1] < 0.05 * losses[0]

    def test_compressed_close_to_uncompressed(self):
        params, batch = make_problem()
        cfg = OptConfig(lr=0.02, warmup_steps=0, weight_decay=0.0)
        outs = {}
        for comp in (None, "int8"):
            p = jax.tree_util.tree_map(lambda x: x, params)
            tcfg = TrainConfig(opt=cfg, grad_compression=comp,
                               grad_dtype=jnp.float32)
            st_ = init_train_state(p, tcfg)
            step = jax.jit(make_train_step(quad_loss, tcfg))
            for _ in range(30):
                p, st_, m = step(p, st_, batch)
            outs[comp] = float(m["nll"])
        # error feedback keeps the trajectory close
        assert abs(outs["int8"] - outs[None]) < 0.1 * (outs[None] + 1e-3)

    def test_wire_bytes_quartered(self):
        params = {"w": jnp.zeros((1024, 1024))}
        assert wire_bytes(params, True) < 0.26 * wire_bytes(params, False)
