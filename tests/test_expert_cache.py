"""LRU expert cache + swap space semantics (paper §3 runtime path)."""
import numpy as np
import pytest

from repro.core.expert_cache import ExpertCache, PrefetchingExpertCache


def make_cache(capacity_experts=4, expert_kb=1, cls=ExpertCache):
    nbytes = expert_kb * 1024
    store = {}

    def fetch(key):
        store.setdefault(key, np.zeros(nbytes, np.uint8) + (key[1] % 250))
        return store[key]

    return cls(fetch, capacity_bytes=capacity_experts * nbytes), store


class TestLRU:
    def test_hit_miss_accounting(self):
        c, _ = make_cache()
        c.get(("l0", 0))
        c.get(("l0", 0))
        c.get(("l0", 1))
        assert c.stats.hits == 1
        assert c.stats.misses == 2

    def test_eviction_order_lru(self):
        c, _ = make_cache(capacity_experts=2)
        c.get(("l", 0))
        c.get(("l", 1))
        c.get(("l", 0))          # 0 now MRU
        c.get(("l", 2))          # evicts 1
        assert ("l", 1) not in c.resident_keys()
        assert ("l", 0) in c.resident_keys()
        assert c.stats.evictions == 1

    def test_capacity_respected(self):
        c, _ = make_cache(capacity_experts=3)
        for i in range(10):
            c.get(("l", i))
        assert len(c.resident_keys()) <= 3
        assert c.used_bytes <= c.capacity

    def test_resize_evicts(self):
        c, _ = make_cache(capacity_experts=4)
        for i in range(4):
            c.get(("l", i))
        c.resize(2 * 1024)
        assert len(c.resident_keys()) <= 2

    def test_pin_and_invalidate(self):
        c, _ = make_cache(capacity_experts=4)
        c.pin([("l", i) for i in range(3)])
        assert len(c.resident_keys()) == 3
        c.invalidate([("l", 0)])
        assert ("l", 0) not in c.resident_keys()
        c.invalidate()
        assert not c.resident_keys()
        assert c.used_bytes == 0

    def test_bytes_in_tracks_transfers(self):
        c, _ = make_cache(capacity_experts=2, expert_kb=2)
        c.get(("l", 0))
        c.get(("l", 1))
        assert c.stats.bytes_in == 2 * 2048

    def test_hit_rate_uniform_access_matches_capacity_ratio(self):
        """Paper assumption: uniform access -> hit rate ~= resident/total."""
        n_experts, capacity = 16, 8
        c, _ = make_cache(capacity_experts=capacity)
        rng = np.random.default_rng(0)
        for _ in range(2000):
            c.get(("l", int(rng.integers(n_experts))))
        assert c.stats.hit_rate == pytest.approx(capacity / n_experts,
                                                 abs=0.06)


class TestPrefetch:
    def test_hint_avoids_demand_miss(self):
        c, _ = make_cache(capacity_experts=4, cls=PrefetchingExpertCache)
        c.hint([("l1", 0), ("l1", 1)])
        before = c.stats.misses
        c.get(("l1", 0))
        c.get(("l1", 1))
        assert c.stats.misses == before
        assert c.stats.hits >= 2
