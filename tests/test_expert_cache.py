"""LRU expert cache + swap space semantics (paper §3 runtime path)."""
import numpy as np
import pytest

from repro.core.expert_cache import ExpertCache, PrefetchingExpertCache


def make_cache(capacity_experts=4, expert_kb=1, cls=ExpertCache):
    nbytes = expert_kb * 1024
    store = {}

    def fetch(key):
        store.setdefault(key, np.zeros(nbytes, np.uint8) + (key[1] % 250))
        return store[key]

    return cls(fetch, capacity_bytes=capacity_experts * nbytes), store


class TestLRU:
    def test_hit_miss_accounting(self):
        c, _ = make_cache()
        c.get(("l0", 0))
        c.get(("l0", 0))
        c.get(("l0", 1))
        assert c.stats.hits == 1
        assert c.stats.misses == 2

    def test_eviction_order_lru(self):
        c, _ = make_cache(capacity_experts=2)
        c.get(("l", 0))
        c.get(("l", 1))
        c.get(("l", 0))          # 0 now MRU
        c.get(("l", 2))          # evicts 1
        assert ("l", 1) not in c.resident_keys()
        assert ("l", 0) in c.resident_keys()
        assert c.stats.evictions == 1

    def test_capacity_respected(self):
        c, _ = make_cache(capacity_experts=3)
        for i in range(10):
            c.get(("l", i))
        assert len(c.resident_keys()) <= 3
        assert c.used_bytes <= c.capacity

    def test_resize_evicts(self):
        c, _ = make_cache(capacity_experts=4)
        for i in range(4):
            c.get(("l", i))
        c.resize(2 * 1024)
        assert len(c.resident_keys()) <= 2

    def test_resize_shrink_below_used_evicts_immediately(self):
        """A shrink below used_bytes must evict down IN the resize call
        (LRU order) — the over-budget state must not persist until the
        next admission."""
        c, _ = make_cache(capacity_experts=4)
        for i in range(4):
            c.get(("l", i))
        assert c.used_bytes == 4 * 1024
        c.get(("l", 0))                        # 0 now MRU
        c.resize(1024)
        assert c.used_bytes <= c.capacity == 1024
        assert c.resident_keys() == [("l", 0)]  # LRU three evicted
        assert c.stats.evictions == 3

    def test_pin_and_invalidate(self):
        c, _ = make_cache(capacity_experts=4)
        c.pin([("l", i) for i in range(3)])
        assert len(c.resident_keys()) == 3
        c.invalidate([("l", 0)])
        assert ("l", 0) not in c.resident_keys()
        c.invalidate()
        assert not c.resident_keys()
        assert c.used_bytes == 0

    def test_bytes_in_tracks_transfers(self):
        c, _ = make_cache(capacity_experts=2, expert_kb=2)
        c.get(("l", 0))
        c.get(("l", 1))
        assert c.stats.bytes_in == 2 * 2048

    def test_hit_rate_uniform_access_matches_capacity_ratio(self):
        """Paper assumption: uniform access -> hit rate ~= resident/total."""
        n_experts, capacity = 16, 8
        c, _ = make_cache(capacity_experts=capacity)
        rng = np.random.default_rng(0)
        for _ in range(2000):
            c.get(("l", int(rng.integers(n_experts))))
        assert c.stats.hit_rate == pytest.approx(capacity / n_experts,
                                                 abs=0.06)


class TestLadderPromote:
    """Mixed-precision entries under the ladder (DESIGN.md §11): a rung
    flip of a swap-resident expert is an IN-PLACE update charging
    exactly the byte delta."""

    @staticmethod
    def make_rung_cache(capacity_kb=16):
        sizes = {}     # key -> current rung blob size

        def fetch(key):
            return np.zeros(sizes[key], np.uint8)

        return ExpertCache(fetch, capacity_bytes=capacity_kb * 1024), sizes

    def test_promote_4_to_8_charges_exact_delta(self):
        c, sizes = self.make_rung_cache()
        s4, s8 = 1024, 2048                       # int4 vs int8 blob
        sizes[(0, 0)] = s4
        c.get((0, 0))
        used_before = c.used_bytes
        delta = c.update((0, 0), np.zeros(s8, np.uint8))
        assert delta == s8 - s4
        assert c.used_bytes - used_before == s8 - s4
        assert c.resident_keys() == [(0, 0)]      # in place, no eviction
        assert c.stats.evictions == 0

    def test_demote_8_to_4_returns_negative_delta(self):
        c, sizes = self.make_rung_cache()
        sizes[(0, 1)] = 2048
        c.get((0, 1))
        delta = c.update((0, 1), np.zeros(1024, np.uint8))
        assert delta == -1024
        assert c.used_bytes == 1024

    def test_update_admits_absent_key(self):
        c, _ = self.make_rung_cache()
        delta = c.update((3, 3), np.zeros(512, np.uint8))
        assert delta == 512 and c.used_bytes == 512

    def test_scoped_view_update_stays_namespaced(self):
        parent = ExpertCache(capacity_bytes=16 * 1024)
        a = parent.scoped("A", lambda k: np.zeros(1024, np.uint8))
        b = parent.scoped("B", lambda k: np.zeros(1024, np.uint8))
        a.get((0, 0))
        b.get((0, 0))
        delta = a.update((0, 0), np.zeros(2048, np.uint8))
        assert delta == 1024
        assert a.used_bytes == 2048
        assert b.used_bytes == 1024               # other namespace untouched

    def test_promotion_delta_reaches_replan_report(self):
        """End to end through the multi-tenant diff path: two plans that
        differ ONLY by one layer's experts flipping 4->8 bits must
        report exactly those experts, each charged at the NEW (8-bit)
        size, in ReplanReport.migrated_bytes
        (delta_cost_bytes semantics)."""
        import dataclasses
        from repro.configs import get_config
        from repro.core.precision_plan import (balanced_ladder_plan,
                                               delta_cost_bytes,
                                               migrated_expert_keys,
                                               reconfig_delta)
        cfg = get_config("mixtral-8x7b")
        a = balanced_ladder_plan(4, 8, {4: 8}, ladder=(16, 8, 4), seed=0,
                                 resident_experts=32)
        b_bits = a.bits.copy()
        b_bits[2][b_bits[2] == 4] = 8             # promote layer 2 in place
        b = dataclasses.replace(a, bits=b_bits)
        delta = reconfig_delta(a, b)
        keys = migrated_expert_keys(delta, b)
        assert keys == [(2, int(e)) for e in np.where(a.bits[2] == 4)[0]]
        cost = delta_cost_bytes(delta, cfg.expert_param_bytes, b)
        assert cost == len(keys) * cfg.expert_param_bytes(8)


class TestPrefetch:
    def test_hint_avoids_demand_miss(self):
        c, _ = make_cache(capacity_experts=4, cls=PrefetchingExpertCache)
        c.hint([("l1", 0), ("l1", 1)])
        before = c.stats.misses
        c.get(("l1", 0))
        c.get(("l1", 1))
        assert c.stats.misses == before
        assert c.stats.hits >= 2

    def test_hint_traffic_split_from_demand(self):
        """Speculative staging reports as prefetch_bytes/prefetch_s and
        must NOT pollute the demand counters — miss_rate and transfer_s
        stay demand-only (DESIGN.md §12 satellite)."""
        c, _ = make_cache(capacity_experts=4, expert_kb=2,
                          cls=PrefetchingExpertCache)
        c.hint([("l1", 0), ("l1", 1)])
        assert c.stats.prefetch_bytes == 2 * 2048
        assert c.stats.prefetch_s >= 0.0
        assert c.stats.bytes_in == 0
        assert c.stats.transfer_s == 0.0
        assert c.stats.misses == 0 and c.stats.hits == 0
        # a real demand miss lands in the demand bucket only
        c.get(("l1", 2))
        assert c.stats.bytes_in == 2048
        assert c.stats.misses == 1
        assert c.stats.prefetch_bytes == 2 * 2048   # unchanged
        # hinting a resident key counts a prefetch hit, no traffic
        c.hint([("l1", 0)])
        assert c.prefetch_hits == 1
        assert c.stats.prefetch_bytes == 2 * 2048


def make_shared(capacity_experts=4, expert_kb=1):
    """Shared parent + one distinct host store per owner: identical
    (layer, expert) keys map to DIFFERENT blobs per owner — exactly the
    collision the namespace field exists to prevent."""
    nbytes = expert_kb * 1024
    parent = ExpertCache(capacity_bytes=capacity_experts * nbytes)

    def mk_fetch(owner_fill):
        def fetch(key):
            return np.full(nbytes, owner_fill, np.uint8)
        return fetch

    a = parent.scoped("A", mk_fetch(1))
    b = parent.scoped("B", mk_fetch(2))
    return parent, a, b


class TestNamespaces:
    def test_same_key_different_owners_no_collision(self):
        parent, a, b = make_shared()
        va = a.get((0, 3))
        vb = b.get((0, 3))
        # two distinct entries, two distinct blobs — no cross-tenant reuse
        assert parent.stats.misses == 2 and parent.stats.hits == 0
        assert int(np.asarray(va)[0]) == 1 and int(np.asarray(vb)[0]) == 2
        assert a.resident_keys() == [(0, 3)]
        assert b.resident_keys() == [(0, 3)]
        assert len(parent.resident_keys()) == 2

    def test_hits_stay_per_owner(self):
        _, a, b = make_shared()
        a.get((0, 0))
        a.get((0, 0))
        b.get((0, 0))
        assert a.stats.hits == 1 and a.stats.misses == 1
        assert b.stats.hits == 0 and b.stats.misses == 1

    def test_invalidate_scoped_to_owner(self):
        parent, a, b = make_shared()
        a.get((0, 0))
        a.get((0, 1))
        b.get((0, 0))
        a.invalidate([(0, 0)])
        assert a.resident_keys() == [(0, 1)]
        assert b.resident_keys() == [(0, 0)]       # untouched
        a.invalidate()                              # full namespace clear
        assert a.resident_keys() == []
        assert b.resident_keys() == [(0, 0)]
        assert a.stats.evictions == 2 and b.stats.evictions == 0
        assert parent.stats.evictions == 2
        assert parent.used_bytes == 1024

    def test_cross_owner_lru_eviction_credited_to_loser(self):
        """The byte budget is jointly shared: B's miss may evict A's LRU
        entry, and the eviction is charged to A's accounting."""
        parent, a, b = make_shared(capacity_experts=2)
        a.get((0, 0))
        a.get((0, 1))
        b.get((0, 0))          # budget full -> evicts A's LRU (0,0)
        assert a.resident_keys() == [(0, 1)]
        assert b.resident_keys() == [(0, 0)]
        assert a.stats.evictions == 1
        assert b.stats.evictions == 0
        assert parent.stats.evictions == 1
        assert parent.used_bytes <= parent.capacity

    def test_owner_used_bytes(self):
        parent, a, b = make_shared(capacity_experts=4, expert_kb=2)
        a.get((0, 0))
        a.get((0, 1))
        b.get((0, 0))
        assert a.used_bytes == 2 * 2048
        assert b.used_bytes == 2048
        assert parent.used_bytes == 3 * 2048

    def test_duplicate_owner_rejected(self):
        parent, _, _ = make_shared()
        with pytest.raises(ValueError, match="already has a scoped view"):
            parent.scoped("A")

    def test_unbound_fetch_raises_then_bind_fetch(self):
        parent = ExpertCache(capacity_bytes=4096)
        v = parent.scoped("late")
        with pytest.raises(RuntimeError, match="no fetch"):
            v.get((0, 0))
        v.bind_fetch(lambda key: np.zeros(16, np.uint8))
        assert np.asarray(v.get((0, 0))).shape == (16,)

    def test_shared_parent_get_requires_view(self):
        parent = ExpertCache(capacity_bytes=4096)
        with pytest.raises(RuntimeError, match="scoped"):
            parent.get((0, 0))

    def test_zero_capacity_rejected(self):
        """A 0-byte cache would silently thrash every access."""
        with pytest.raises(ValueError, match="capacity"):
            ExpertCache(lambda k: None)
        with pytest.raises(ValueError, match="capacity"):
            ExpertCache(lambda k: None, capacity_bytes=0)
