"""Online hotness-driven dynamic precision (DESIGN.md §15): the
controller folds measured routing into the sensitivity profile and
issues hysteresis-guarded byte-neutral rung swaps.

Covers the ISSUE's acceptance criteria: under Zipf traffic the
controller lands hot experts on higher rungs AND reaches strictly lower
measured quality cost than the static balanced plan at the SAME byte
budget; alternating hotness does not flip-flap; cache byte accounting
is conserved through ``ExpertCache.update()``; a uniform profile keeps
the frontier bit-identical; and the routing histogram survives
placement-only replans (the ``_prev_demanded``-reset regression).
"""
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import cost_model
from repro.core.cost_model import HardwareModel
from repro.core.dynamic_precision import (DynamicPrecisionConfig,
                                          DynamicPrecisionController)
from repro.core.pareto import ParetoFrontier
from repro.core.precision_plan import HOST
from repro.core.sensitivity import SensitivityProfile
from repro.serving.simulator import SimulatedEngine, zipf_route_fn

MIXTRAL = get_config("mixtral-8x7b")
#: the dynamic-control tests run on the reduced config: with few layers
#: a single hot/cold rung swap is a meaningful fraction of the plan's
#: quality cost, so the hysteresis margin plays at realistic scale.
SMOKE = reduce_for_smoke(get_config("mixtral-8x7b"))


@pytest.fixture(scope="module")
def frontier():
    return ParetoFrontier(MIXTRAL, HardwareModel())


@pytest.fixture(scope="module")
def smoke_frontier():
    return ParetoFrontier(SMOKE, HardwareModel())


def mixed_point(frontier):
    """A frontier point with BOTH rungs present and full residency: rung
    swaps are then pure quality moves (no byte or placement effects)."""
    pts = [p for p in frontier.all_points
           if 0 < p.num_q_experts < p.plan.bits.size
           and p.plan.resident_fraction() == 1.0]
    assert pts, "frontier must enumerate mixed-rung fully-resident points"
    return pts[len(pts) // 2]


def run_dynamic(point, route_fn, iterations, config=DynamicPrecisionConfig()):
    eng = SimulatedEngine(batch=4, route_fn=route_fn)
    eng.apply_frontier_point(point)
    ctl = DynamicPrecisionController(
        eng, SensitivityProfile.uniform(SMOKE), config)
    swaps_per_step = []
    for _ in range(iterations):
        eng.run_iteration()
        before = ctl.metrics["swaps"]
        ctl.step()
        swaps_per_step.append(int(ctl.metrics["swaps"] - before))
    return eng, ctl, swaps_per_step


class TestZipfHotness:
    """Acceptance criterion: Zipf traffic => hot experts on higher
    rungs and strictly lower measured quality cost, equal bytes."""

    def test_hot_experts_promoted_and_quality_cost_drops(self, smoke_frontier):
        point = mixed_point(smoke_frontier)
        L, E = point.plan.bits.shape
        eng, ctl, _ = run_dynamic(
            point, zipf_route_fn(L, E, seed=3), iterations=40)
        static, final = point.plan, eng.current_plan
        assert ctl.metrics["swaps"] > 0
        assert ctl.metrics["rung_promotions"] > 0
        assert ctl.metrics["rung_demotions"] > 0
        # Zipf rank order: low indices are the hot experts
        hot, cold = final.bits[:, :E // 2], final.bits[:, E // 2:]
        assert hot.mean() > cold.mean()
        assert hot.mean() > static.bits[:, :E // 2].mean()
        # strictly lower measured quality cost under the SAME
        # traffic-folded profile the controller descends...
        assert ctl.profile.quality_cost(final) \
            < ctl.profile.quality_cost(static)
        # ...at the exact same byte budget (swaps are byte-neutral)
        assert cost_model.device_bytes(SMOKE, final) \
            == cost_model.device_bytes(SMOKE, static)
        np.testing.assert_array_equal(final.location, static.location)
        # per-layer rung counts preserved (bank shapes intact)
        for li in range(L):
            for b in static.ladder:
                assert (final.bits[li] == b).sum() \
                    == (static.bits[li] == b).sum()

    def test_placement_only_replan_reports_emitted(self, smoke_frontier):
        point = mixed_point(smoke_frontier)
        L, E = point.plan.bits.shape
        _, ctl, _ = run_dynamic(
            point, zipf_route_fn(L, E, seed=3), iterations=40)
        assert ctl.reports
        for rr in ctl.reports:
            assert rr.placement_only
            assert rr.tenant == "default"
        assert len(ctl.reports) == ctl.metrics["updates"]

    def test_route_counts_survive_placement_only_replan_sim(
            self, smoke_frontier):
        """Regression: the accumulated routing histogram must NOT reset
        on a placement-only replan (same plan shape)."""
        point = mixed_point(smoke_frontier)
        L, E = point.plan.bits.shape
        eng = SimulatedEngine(batch=4, route_fn=zipf_route_fn(L, E, seed=0))
        eng.apply_frontier_point(point)
        for _ in range(3):
            eng.run_iteration()
        counts = eng.route_counts.copy()
        assert counts.sum() > 0
        eng.apply_frontier_point(point)        # placement-only replan
        np.testing.assert_array_equal(eng.route_counts, counts)
        eng.run_iteration()                    # and keeps accumulating
        assert eng.route_counts.sum() > counts.sum()


class TestHysteresis:
    """Alternating hotness must not make the controller flip-flap."""

    def test_alternating_hotness_does_not_flip_flap(self, smoke_frontier):
        """Hotness flipping EVERY iteration is pure noise to the EMA: a
        naive controller would chase it forever (one flip per dwell
        window, ~iterations/min_dwell_steps flips per expert); the
        guards must instead pin the plan still after a short transient."""
        point = mixed_point(smoke_frontier)
        L, E = point.plan.bits.shape
        iters = 40
        eng = SimulatedEngine(
            batch=4, route_fn=zipf_route_fn(L, E, seed=3, hot_rotation=1))
        eng.apply_frontier_point(point)
        ctl = DynamicPrecisionController(
            eng, SensitivityProfile.uniform(SMOKE))
        flips = np.zeros((L, E), np.int64)
        swaps_per_step = []
        prev = point.plan.bits.copy()
        for _ in range(iters):
            eng.run_iteration()
            before = ctl.metrics["swaps"]
            ctl.step()
            swaps_per_step.append(int(ctl.metrics["swaps"] - before))
            cur = eng.current_plan.bits
            flips += cur != prev
            prev = cur.copy()
        # no sustained oscillation: an unguarded chaser would flip hot
        # experts once per dwell window (= iters / min_dwell_steps times)
        assert flips.max() <= 2
        # and the second half is completely still
        assert sum(swaps_per_step[iters // 2:]) == 0

    def test_margin_guard_blocks_marginal_swaps(self, smoke_frontier):
        """An (effectively) infinite margin freezes the plan entirely —
        the hysteresis knob is load-bearing, not decorative."""
        point = mixed_point(smoke_frontier)
        L, E = point.plan.bits.shape
        eng, ctl, _ = run_dynamic(
            point, zipf_route_fn(L, E, seed=3), iterations=20,
            config=DynamicPrecisionConfig(margin=1e9))
        assert ctl.metrics["swaps"] == 0
        np.testing.assert_array_equal(eng.current_plan.bits,
                                      point.plan.bits)

    def test_empty_window_is_noop(self, smoke_frontier):
        point = mixed_point(smoke_frontier)
        eng = SimulatedEngine(batch=4)     # no route_fn: no traffic
        eng.apply_frontier_point(point)
        ctl = DynamicPrecisionController(
            eng, SensitivityProfile.uniform(SMOKE))
        eng.run_iteration()
        ctl.step()
        assert ctl.metrics["swaps"] == 0
        assert ctl.measured_freq() is None
        np.testing.assert_array_equal(eng.current_plan.bits,
                                      point.plan.bits)


class TestFrontierBitCompat:
    def test_uniform_profile_frontier_bit_identical(self, frontier):
        """The golden guarantee: a uniform profile prices exactly like
        the legacy flat table — records() (float.hex serialization) must
        be BYTE-identical to the profile-free frontier."""
        prof = SensitivityProfile.uniform(MIXTRAL)
        with_prof = ParetoFrontier(MIXTRAL, HardwareModel(), profile=prof)
        assert with_prof.records() == frontier.records()


# ---------------------------------------------------------------------------
# Real-engine integration: byte conservation + histogram persistence
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    import jax
    from repro.models.model import build_model
    from repro.serving.engine import AdaptiveServingEngine
    cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
    params = build_model(cfg).init(jax.random.key(0))
    return AdaptiveServingEngine(cfg, params, max_batch=2, max_len=24)


def offloaded_mixed_pair(plan):
    """(li, e_lo, e_hi): two same-layer HOST experts at different rungs
    — the byte-neutral swap pair that exercises the cache restage path."""
    L = plan.bits.shape[0]
    for li in range(L):
        host = np.flatnonzero(plan.location[li] == HOST)
        rungs = {int(plan.bits[li, e]) for e in host}
        if len(rungs) < 2:
            continue
        lo, hi = min(rungs), max(rungs)
        e_lo = next(int(e) for e in host if plan.bits[li, e] == lo)
        e_hi = next(int(e) for e in host if plan.bits[li, e] == hi)
        return li, e_lo, e_hi
    return None


@pytest.fixture()
def mixed_offload_engine(engine, smoke_frontier):
    """The engine on a partial-residency frontier plan that has a
    mixed-rung offloaded pair — the cache-restage swap scenario."""
    point = pair = None
    for p in smoke_frontier.all_points:
        if p.plan.resident_fraction() >= 1.0:
            continue
        pair = offloaded_mixed_pair(p.plan)
        if pair is not None:
            point = p
            break
    assert pair is not None, "frontier has no mixed-rung HOST pair"
    engine.apply_frontier_point(point)
    return engine, pair, point


class TestByteConservation:
    def test_swap_conserves_cache_and_plan_bytes(self, mixed_offload_engine):
        """Sum of ``ExpertCache.update()`` deltas == plan byte diff == 0
        for a rung swap, with both flipped entries actually re-staged."""
        engine, (li, e_lo, e_hi), _ = mixed_offload_engine
        old_plan = engine.current_plan
        # stage both swap candidates into the cache (demand-fetch path)
        engine.expert_cache.get((li, e_lo))
        engine.expert_cache.get((li, e_hi))
        used0 = engine.expert_cache.used_bytes
        new_bits = old_plan.bits.copy()
        new_bits[li, e_lo], new_bits[li, e_hi] = \
            old_plan.bits[li, e_hi], old_plan.bits[li, e_lo]
        report = engine.apply_bits_update(new_bits)
        assert report["flipped"] == 2
        assert report["promotions"] == 1 and report["demotions"] == 1
        assert report["restaged"] == 2
        # byte conservation: the summed update deltas are the cache's
        # own accounting change, and a swap nets to exactly zero
        assert report["cache_bytes_delta"] == \
            engine.expert_cache.used_bytes - used0
        assert report["cache_bytes_delta"] == 0
        new_plan = engine.current_plan
        assert cost_model.device_bytes(engine.cfg, new_plan) \
            == cost_model.device_bytes(engine.cfg, old_plan)
        np.testing.assert_array_equal(new_plan.location, old_plan.location)

    def test_single_cached_restage_charges_exact_delta(
            self, mixed_offload_engine):
        """With only ONE side of the swap cached, the reported byte
        delta is that entry's rung-size change — nonzero, and exactly
        the cache accounting movement (conservation at entry grain)."""
        engine, (li, e_lo, e_hi), _ = mixed_offload_engine
        old_plan = engine.current_plan
        engine.expert_cache.invalidate()
        engine.expert_cache.get((li, e_lo))   # low-rung side only
        used0 = engine.expert_cache.used_bytes
        new_bits = old_plan.bits.copy()
        new_bits[li, e_lo], new_bits[li, e_hi] = \
            old_plan.bits[li, e_hi], old_plan.bits[li, e_lo]
        report = engine.apply_bits_update(new_bits)
        assert report["restaged"] == 1
        # e_lo was promoted to the bigger rung: the delta is positive
        assert report["cache_bytes_delta"] > 0
        assert report["cache_bytes_delta"] == \
            engine.expert_cache.used_bytes - used0

    def test_replan_after_swap_drops_stale_rung_blobs(
            self, mixed_offload_engine):
        """Regression: a placement-only replan (same bank sizes) after a
        rung swap reverts to the planner's canonical bits assignment —
        cache entries staged at the swapped rung must be invalidated,
        not served stale."""
        engine, (li, e_lo, e_hi), point = mixed_offload_engine
        old_plan = engine.current_plan
        engine.expert_cache.invalidate()
        engine.expert_cache.get((li, e_lo))
        new_bits = old_plan.bits.copy()
        new_bits[li, e_lo], new_bits[li, e_hi] = \
            old_plan.bits[li, e_hi], old_plan.bits[li, e_lo]
        engine.apply_bits_update(new_bits)
        # back to the canonical assignment: (li, e_lo) flips rung again,
        # so its freshly restaged entry is stale under the new plan
        engine.apply_frontier_point(point)
        assert (li, e_lo) not in engine.expert_cache.resident_keys()
        # a re-fetch stages it at the plan's (restored) rung size
        engine.expert_cache.get((li, e_lo))
        rung = int(engine.current_plan.bits[li, e_lo])
        assert rung == int(old_plan.bits[li, e_lo])
        assert engine.expert_cache.used_bytes \
            <= engine.planner.expert_bytes(rung) * 1.5

    def test_rejects_rung_count_changes(self, mixed_offload_engine):
        """A bits update that changes per-layer rung counts is a bank
        split, not a swap — must be refused (that path is
        apply_frontier_point)."""
        engine, (li, e_lo, e_hi), _ = mixed_offload_engine
        old_plan = engine.current_plan
        bad = old_plan.bits.copy()
        bad[li, e_lo] = old_plan.bits[li, e_hi]   # promote w/o demoting
        with pytest.raises(ValueError, match="rung counts"):
            engine.apply_bits_update(bad)

    def test_generation_still_works_after_swap(self, mixed_offload_engine):
        engine, (li, e_lo, e_hi), _ = mixed_offload_engine
        old_plan = engine.current_plan
        new_bits = old_plan.bits.copy()
        new_bits[li, e_lo], new_bits[li, e_hi] = \
            old_plan.bits[li, e_hi], old_plan.bits[li, e_lo]
        engine.apply_bits_update(new_bits)
        rid = engine.submit(np.array([5, 6, 7]), max_new_tokens=3)
        engine.step()
        out = engine.done[rid].out_tokens
        assert len(out) == 3
        assert all(0 <= t < engine.cfg.vocab_size for t in out)


class TestRouteCountsSurviveReplan:
    def test_histogram_survives_placement_only_replan(
            self, engine, smoke_frontier):
        """The satellite regression: ``_prev_demanded`` IS reset on a
        replan but the routing histogram must NOT be — the dynamic
        controller's traffic window spans placement-only replans."""
        # two frontier points with IDENTICAL rung counts, different
        # residency: moving between them is a placement-only replan
        by_q = {}
        for p in smoke_frontier.all_points:
            by_q.setdefault(p.num_q_experts, []).append(p)
        pts = next(v for q, v in sorted(by_q.items())
                   if q > 0 and len({p.resident_experts for p in v}) > 1)
        pts = sorted(pts, key=lambda p: p.resident_experts)
        a, b = pts[0], pts[-1]
        engine.apply_frontier_point(a)
        engine.reset_route_counts()
        rid = engine.submit(np.array([1, 2, 3, 4]), max_new_tokens=3)
        engine.step()
        counts = engine.route_counts.copy()
        assert counts.sum() > 0
        engine.apply_frontier_point(b)         # placement-only replan
        np.testing.assert_array_equal(engine.route_counts, counts)
        # and the histogram keeps growing afterwards
        rid = engine.submit(np.array([9, 8, 7]), max_new_tokens=2)
        engine.step()
        assert engine.route_counts.sum() > counts.sum()
        assert len(engine.done[rid].out_tokens) == 2
