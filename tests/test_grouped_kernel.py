"""Grouped multi-expert kernel parity vs the per-expert loop
(DESIGN.md §13).

The grouped kernel fuses a whole precision bank into ONE pallas_call with
the expert group as the leading grid axis; the contract is that it is
BIT-IDENTICAL to looping ``q_matmul`` over experts (the spelling it
replaces) for the quantized rungs, and allclose vs the einsum reference
for the bf16 bank (f32 VMEM accumulation vs XLA's reduction order).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantization import QTensor, quantize
from repro.kernels.ops import (
    grouped_bf16_matmul, grouped_q_matmul, q_expert_matmul, q_matmul,
)


def make_bank(e, c, k, n, bits, group, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((e, c, k)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((e, k, n)) / np.sqrt(k),
                    jnp.float32)
    return x, quantize(w, bits, group)


def loop_ref(x, qt):
    """The per-expert spelling the grouped kernel replaces — shares
    q_matmul's tile-selection logic, which is what makes the grouped
    path's bit-identity a meaningful (and testable) contract."""
    outs = [q_matmul(x[e], QTensor(q=qt.q[e], scales=qt.scales[e],
                                   bits=qt.bits, group_size=qt.group_size))
            for e in range(x.shape[0])]
    return jnp.stack(outs)


def assert_bit_equal(got, want):
    assert got.dtype == want.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got.view(jnp.uint16)),
                                  np.asarray(want.view(jnp.uint16)))


class TestGroupedQuantParity:
    #: (experts_in_group, capacity, K, N, group_size) — capacity sweeps
    #: unaligned M tiles; K/N=192 force _largest_divisor tile shrinking;
    #: group_size=32 exercises a non-default scale granularity
    CASES = [
        (1, 8, 128, 128, 64),
        (3, 5, 128, 256, 64),
        (8, 16, 256, 128, 64),
        (4, 8, 192, 192, 64),
        (2, 20, 128, 128, 32),
        (6, 1, 128, 128, 64),
    ]

    @pytest.mark.parametrize("bits", [4, 8])
    @pytest.mark.parametrize("e,c,k,n,group", CASES)
    def test_bit_exact_vs_expert_loop(self, e, c, k, n, group, bits):
        x, qt = make_bank(e, c, k, n, bits, group)
        got = grouped_q_matmul(x, qt)
        assert got.shape == (e, c, n)
        assert_bit_equal(got, loop_ref(x, qt))

    @pytest.mark.parametrize("bits", [4, 8])
    def test_dispatch_spellings_agree(self, bits):
        """q_expert_matmul(grouped=True) == the legacy vmap spelling
        (grouped=False), bit for bit — the A/B the benchmark times."""
        x, qt = make_bank(4, 8, 128, 128, bits, 64)
        assert_bit_equal(q_expert_matmul(x, qt, grouped=True),
                         q_expert_matmul(x, qt, grouped=False))

    def test_bf16_grouped_allclose_einsum(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((5, 8, 128)), jnp.bfloat16)
        w = jnp.asarray(rng.standard_normal((5, 128, 256)) / np.sqrt(128),
                        jnp.bfloat16)
        got = grouped_bf16_matmul(x, w)
        ref = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                         w.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref), rtol=5e-2, atol=5e-2)

    @given(st.integers(2, 6), st.integers(1, 12), st.sampled_from([4, 8]),
           st.integers(0, 5))
    @settings(max_examples=8, deadline=None)
    def test_empty_group_contributes_exact_zeros(self, e, c, bits, which):
        """An expert with no routed tokens (all-zero activation rows — how
        the capacity-grouped layout encodes an empty group) must produce
        EXACT zeros: 0 @ dequant(W) has no rounding path."""
        which = which % e
        x, qt = make_bank(e, c, 128, 128, bits, 64, seed=e * 100 + c)
        x = x.at[which].set(0)
        out = grouped_q_matmul(x, qt)
        np.testing.assert_array_equal(
            np.asarray(out[which], np.float32),
            np.zeros((c, 128), np.float32))

    def test_shape_validation(self):
        x, qt = make_bank(4, 8, 128, 128, 4, 64)
        bad = QTensor(q=qt.q[:3], scales=qt.scales[:3], bits=4,
                      group_size=64)
        with pytest.raises((ValueError, TypeError)):
            grouped_q_matmul(x, bad)
