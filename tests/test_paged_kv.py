"""Paged KV cache (DESIGN.md §13): allocator bookkeeping, bit-identical
decode vs the bucketed slot cache (including slot retire/rejoin
mid-flight), padding-waste accounting, and the kv_reserve feedback into
the frontier's residency budget."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core.cost_model import (kv_bytes_bucketed, kv_bytes_paged,
                                   kv_token_bytes)
from repro.core.pareto import QoSTarget
from repro.models.model import build_model, init_paged_cache
from repro.serving.api import EngineConfig, RequestSLO, ServeRequest
from repro.serving.engine import AdaptiveServingEngine
from repro.serving.paged_kv import PageAllocator


class TestPageAllocator:
    def make(self, slots=2, chunks=4, pages=9, ps=4):
        return PageAllocator(slots, chunks, pages, ps)

    def test_null_page_never_handed_out(self):
        al = self.make()
        got = {al.ensure(s, c) for s in range(2) for c in range(4)}
        assert 0 not in got and len(got) == 8
        assert al.free_pages == 0 and al.pages_in_use == 8

    def test_ensure_idempotent(self):
        al = self.make()
        p = al.ensure(0, 2)
        assert al.ensure(0, 2) == p and al.pages_in_use == 1

    def test_ensure_prefix_rounds_to_pages(self):
        al = self.make()
        assert len(al.ensure_prefix(0, 5)) == 2      # ceil(5/4) chunks
        assert len(al.ensure_prefix(1, 4)) == 1
        assert al.pages_in_use == 3

    def test_ensure_index_maps_ring_write(self):
        al = self.make()
        p = al.ensure_index(0, 7)                    # chunk 1
        assert al.table[0, 1] == p and al.table[0, 0] == 0

    def test_free_slot_recycles(self):
        al = self.make()
        pages = al.ensure_prefix(0, 16)
        freed = al.free_slot(0)
        assert sorted(freed) == sorted(pages)
        assert al.pages_in_use == 0
        assert not al.table[0].any()
        # freed pages are reusable by another slot
        assert set(al.ensure_prefix(1, 16)) <= set(range(1, 9))

    def test_exhaustion_raises(self):
        al = PageAllocator(2, 4, num_pages=3, page_size=4)
        al.ensure(0, 0)
        al.ensure(0, 1)
        with pytest.raises(RuntimeError, match="exhausted"):
            al.ensure(0, 2)

    def test_truncate_frees_tail_chunks_only(self):
        al = self.make()
        pages = al.ensure_prefix(0, 16)              # all 4 chunks
        freed = al.truncate(0, 9)                    # keep ceil(9/4)=3
        assert freed == [pages[3]]
        assert al.slot_pages(0) == pages[:3]
        assert al.free_pages == 8 - 4 + 1          # 8 usable, 4 held, 1 back
        # a prefix already covering every mapped chunk is a no-op
        assert al.truncate(0, 12) == []
        assert al.slot_pages(0) == pages[:3]

    def test_truncate_page_boundary_and_zero(self):
        al = self.make()
        pages = al.ensure_prefix(0, 16)
        # exactly on a page boundary keeps that many whole chunks
        assert al.truncate(0, 8) == pages[2:]
        assert al.slot_pages(0) == pages[:2]
        # 0 (and negative, defensively) frees everything
        assert al.truncate(0, 0) == pages[:2]
        assert al.truncate(1, -3) == []
        assert al.pages_in_use == 0

    def test_truncate_freed_pages_reusable(self):
        """Free-list reuse: pages released by one slot's speculative
        rollback are immediately allocatable by another slot."""
        al = PageAllocator(2, 4, num_pages=5, page_size=4)   # 4 usable
        al.ensure_prefix(0, 16)                              # pool dry
        assert al.free_pages == 0
        freed = al.truncate(0, 4)                            # drop 3 tail
        assert len(freed) == 3
        got = al.ensure_prefix(1, 12)
        assert sorted(got) == sorted(freed)
        with pytest.raises(RuntimeError, match="exhausted"):
            al.ensure(1, 3)


class TestKvCostModel:
    def test_bucketed_vs_paged_pricing(self):
        cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
        tb = kv_token_bytes(cfg)
        assert tb == cfg.num_layers * 2 * \
            cfg.attention.num_kv_heads * cfg.attention.head_dim * 2
        assert kv_bytes_bucketed(cfg, 4, 32) == 4 * 32 * tb
        assert kv_bytes_paged(cfg, 6, 8) == 6 * 8 * tb

    def test_with_kv_reclaimed(self):
        t = QoSTarget(mem_budget_bytes=1000.0)
        assert t.with_kv_reclaimed(0) is t
        assert t.with_kv_reclaimed(256).mem_budget_bytes == 1256.0
        unbounded = QoSTarget(mem_budget_bytes=None)
        assert unbounded.with_kv_reclaimed(256).mem_budget_bytes is None

    def test_paged_pool_init_shapes(self):
        cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
        pool, meta = init_paged_cache(cfg, 2, 24, page_size=4,
                                      abstract=True)
        assert meta.page_size == 4
        assert meta.window == min(24, cfg.attention.sliding_window or 24)
        assert meta.num_pages == 2 * meta.chunks_per_slot + 1
        assert pool["k"].shape == (
            cfg.num_layers, meta.num_pages, 4,
            cfg.attention.num_kv_heads, cfg.attention.head_dim)
        with pytest.raises(ValueError):
            init_paged_cache(cfg, 2, 24, page_size=4, num_pages=2)


@pytest.fixture(scope="module")
def smoke():
    cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


def _full_size(engine):
    return engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16


def _run_stream(cfg, params, econf, n_req=3, max_new=6):
    """Serve a deterministic request stream (3 requests over 2 slots, so
    one slot retires and is rejoined mid-flight) and return the per-rid
    token lists."""
    engine = AdaptiveServingEngine(cfg, params, config=econf)
    engine.configure(_full_size(engine) * 1.1, "throughput")
    rng = np.random.default_rng(0)
    rids = [engine.submit_request(ServeRequest(
        prompt=rng.integers(1, cfg.vocab_size, 5 + 2 * i),
        max_new_tokens=max_new, slo=RequestSLO()))
        for i in range(n_req)]
    while engine.has_work():
        engine.run_iteration(temperature=0.0)
    toks = {rid: list(engine.done[rid].out_tokens) for rid in rids}
    engine.close()
    return toks, engine


class TestPagedEngineEquivalence:
    def test_decode_bit_identical_to_slot_cache(self, smoke):
        """Greedy decode through pages == through the bucketed slot cache
        for the same stream, including retire/rejoin (3 reqs, 2 slots)."""
        cfg, params = smoke
        base = dict(max_slots=2, max_len=24)
        paged, ep = _run_stream(cfg, params, EngineConfig(
            **base, paged_kv=True, page_size=4))
        slots, es = _run_stream(cfg, params, EngineConfig(
            **base, paged_kv=False))
        assert paged == slots
        assert ep.paged and not es.paged

    def test_overlap_pipeline_equivalence(self, smoke):
        """The per-layer lookahead pipeline (DESIGN.md §12) through pages
        == through slot rows."""
        cfg, params = smoke
        base = dict(max_slots=2, max_len=24, overlap=True)
        paged, ep = _run_stream(cfg, params, EngineConfig(
            **base, paged_kv=True, page_size=4))
        slots, _ = _run_stream(cfg, params, EngineConfig(
            **base, paged_kv=False))
        assert paged == slots
        ep.close()

    def test_waste_accounting(self, smoke):
        """Paged allocation tracks actual tokens (waste < slot cache's
        bucket padding) and both spellings expose the kv column."""
        cfg, params = smoke
        base = dict(max_slots=2, max_len=24)
        _, ep = _run_stream(cfg, params, EngineConfig(
            **base, paged_kv=True, page_size=4))
        _, es = _run_stream(cfg, params, EngineConfig(
            **base, paged_kv=False))
        assert 0.0 <= ep.kv_waste_fraction() < es.kv_waste_fraction()
        assert "kv[paged" in ep.summary()
        assert "kv[slots" in es.summary()
        assert ep.metrics["kv_capacity_bytes"] <= \
            es.metrics["kv_capacity_bytes"]

    def test_sub_worst_case_pool_admission_cap(self, smoke):
        """A pool smaller than worst case derives an admission cap and
        never exhausts mid-flight; outputs stay bit-identical."""
        cfg, params = smoke
        # window=24, page_size=4 -> 6 chunks/slot; worst case 2*6+1=13
        # pages. 8 pages (7 usable) < worst case -> cap kicks in.
        paged, ep = _run_stream(cfg, params, EngineConfig(
            max_slots=2, max_len=24, paged_kv=True, page_size=4,
            kv_pool_pages=8))
        slots, _ = _run_stream(cfg, params, EngineConfig(
            max_slots=2, max_len=24, paged_kv=False))
        assert paged == slots
        assert ep.scheduler.cfg.max_active_tokens is not None
        assert ep.kv_reclaimed_bytes() > 0

    def test_kv_reserve_widens_residency_budget(self, smoke):
        """kv_reserve credits the reclaimed HBM to the frontier's memory
        budget: the selected plan can afford at least as many resident
        experts as without the credit."""
        cfg, params = smoke
        mk = lambda reserve: AdaptiveServingEngine(
            cfg, params, config=EngineConfig(
                max_slots=2, max_len=24, paged_kv=True, page_size=4,
                kv_pool_pages=8, kv_reserve=reserve))
        ea, eb = mk(False), mk(True)
        assert ea.kv_reclaimed_bytes() == eb.kv_reclaimed_bytes() > 0
        budget = _full_size(ea) * 0.7
        target = QoSTarget(min_tokens_per_s=float("inf"),
                           mem_budget_bytes=budget)
        pa = ea.apply_target(target)
        pb = eb.apply_target(target)
        assert pb.plan.resident_fraction() >= pa.plan.resident_fraction()
        ea.close()
        eb.close()
